"""repro — On-Device Qwen2.5 (AWQ + fused dequant-MAC) as a multi-pod JAX framework."""
__version__ = "0.1.0"
