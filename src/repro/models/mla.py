"""Multi-head Latent Attention (DeepSeek-V2) with absorbed decode.

Train/prefill uses the explicit form (latent → per-head K/V expansion).
Decode uses the **absorbed** form from the DeepSeek-V2 paper (arXiv:
2405.04434 §2.1.2): the per-head up-projections W_UK/W_UV are folded into
the query/output sides so the cache stays in the compressed latent space —
``[B, S, kv_lora + rope_dim]`` instead of ``[B, S, H, 2·hd]``. For
deepseek-v2-lite that is (512+64) vs 16·(192+128) = 5120 floats/token: an
8.9× cache-byte reduction, which compounds with the paper's INT4 weight
stream on the decode roofline.

The latent cache is sequence-sharded over `model` like every other decode
cache (SP-decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers
from repro.models.layers import apply_rope, linear, rmsnorm, rope_cos_sin


def mla_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    h, r, vdim = cfg.num_heads, cfg.kv_lora_rank, cfg.v_head_dim
    return {
        "q_proj": layers.linear_init(ks[0], d, h * (nope + rope), dtype=dtype),
        "kv_down": layers.linear_init(ks[1], d, r + rope, dtype=dtype),
        "kv_norm": layers.norm_init(r, dtype=dtype),
        "kv_up": layers.linear_init(ks[2], r, h * (nope + vdim), dtype=dtype),
        "wo": layers.linear_init(ks[3], h * vdim, d, dtype=dtype),
    }


def _project_q(p, x, cfg, positions, name):
    nm = (lambda s: None) if name is None else name
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    lead = x.shape[:-1]
    q = linear(p["q_proj"], x, nm("q_proj"))
    q = q.reshape(*lead, cfg.num_heads, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_cos_sin(positions, rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin, rope)
    return q_nope, q_rope


def _project_latent(p, x, cfg, positions, name):
    """x → (c_kv [.., r] post-norm, k_rope [.., rope] rope'd, shared)."""
    nm = (lambda s: None) if name is None else name
    r, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv = linear(p["kv_down"], x, nm("kv_down"))
    c, k_pe = ckv[..., :r], ckv[..., r:]
    c = rmsnorm(p["kv_norm"], c, eps=cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, rope, cfg.rope_theta)
    k_pe = apply_rope(k_pe[..., None, :], cos, sin, rope)[..., 0, :]
    return c, k_pe


def mla_attention(p, x, cfg, *, positions, name=None) -> jax.Array:
    """Train/prefill MLA (explicit form). x [B, S, D] → [B, S, D]."""
    b, s, _ = x.shape
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    h, vdim = cfg.num_heads, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, cfg, positions, name)
    c, k_pe = _project_latent(p, x, cfg, positions, name)
    nm = (lambda s_: None) if name is None else name
    kv = linear(p["kv_up"], c, nm("kv_up")).reshape(b, s, h, nope + vdim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    scale = (nope + rope) ** -0.5

    def qk_scores(qn, qr):
        # [B, C, H, *] vs keys [B, S, H/1, *]
        sc = jnp.einsum("bqhd,bshd->bhqs", qn, k_nope,
                        preferred_element_type=jnp.float32)
        sc += jnp.einsum("bqhd,bsd->bhqs", qr, k_pe,
                         preferred_element_type=jnp.float32)
        return sc * scale

    chunk = cfg.attn_chunk
    kpos = positions

    def attend(qn, qr, qpos):
        sc = qk_scores(qn, qr)
        mask = kpos[:, None, :] <= qpos[:, :, None]
        sc = jnp.where(mask[:, None, :, :], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", pr, v)

    if s > chunk and s % chunk == 0:
        nc = s // chunk
        qn = jnp.moveaxis(q_nope.reshape(b, nc, chunk, h, nope), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(b, nc, chunk, h, rope), 1, 0)
        pc = jnp.moveaxis(positions.reshape(b, nc, chunk), 1, 0)
        _, out = jax.lax.scan(lambda _, t: (None, attend(*t)), None,
                              (qn, qr, pc))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, h * vdim)
    else:
        out = attend(q_nope, q_rope, positions).reshape(b, s, h * vdim)
    return linear(p["wo"], out, nm("wo"))


# ---------------------------------------------------------------------------
# Decode (absorbed) + latent cache
# ---------------------------------------------------------------------------

def init_mla_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }


def fill_mla_cache_from_prefill(cache, c, k_pe):
    ck = jax.lax.dynamic_update_slice(
        cache["ckv"], c.astype(cache["ckv"].dtype), (0, 0, 0))
    kp = jax.lax.dynamic_update_slice(
        cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, 0, 0))
    return {"ckv": ck, "kpe": kp}


def _packed_col_block(pl, heads: int, width: int, sl: slice):
    """Per-head column block of a packed [r, heads*width] linear, WITHOUT
    dequantizing: qweight/scales/zeros all carry N in their last axis, so
    slicing output columns commutes with the K-dim int4 packing."""
    from repro.core.packing import PackedLinear

    def take(a):
        a3 = a.reshape(a.shape[0], heads, width)[..., sl]
        return a3.reshape(a.shape[0], -1)

    return PackedLinear(take(pl.qweight), take(pl.scales), take(pl.zeros),
                        pl.input_scale, None, pl.group_size)


def mla_decode(p, cache, x, cfg, *, pos, name=None):
    """Absorbed single-token decode. x [B, D], pos [B] → (y, cache)."""
    b = x.shape[0]
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    h, r, vdim = cfg.num_heads, cfg.kv_lora_rank, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, cfg, pos, name)         # [B, H, *]
    c1, kpe1 = _project_latent(p, x, cfg, pos, name)          # [B, r]/[B, rope]

    bidx = jnp.arange(b)
    ckv = cache["ckv"].at[bidx, pos].set(c1.astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[bidx, pos].set(kpe1.astype(cache["kpe"].dtype))
    ckv = constrain(ckv, ("batch", "cache_seq", None))
    kpe = constrain(kpe, ("batch", "cache_seq", None))

    # Absorb W_UK into the query: q_abs[h, r] = q_nope[h, nope] · W_UK[r, h, nope]
    from repro.core.packing import PackedLinear, dequantize_packed
    pk = p["kv_up"]
    if isinstance(pk, PackedLinear):
        # Quantized serving: dequantize PER BLOCK at each use point — the
        # W_UK columns here for query absorption, the W_UV columns only
        # after attention — so peak live bytes are one block's dense
        # weight (effective weight = diag(input_scale) @ dequant),
        # never the full [r, h*(nope+vdim)] expansion.
        def _up_block(sl, width):
            blk = _packed_col_block(pk, h, nope + vdim, sl)
            w = dequantize_packed(blk, jnp.float32) * pk.input_scale[:, None]
            return w.reshape(r, h, width)

        w_uk = _up_block(slice(None, nope), nope)
        w_uv_fn = lambda: _up_block(slice(nope, None), vdim)  # noqa: E731
    else:
        w_up = pk["w"].reshape(r, h, nope + vdim)
        w_uk = w_up[..., :nope]
        w_uv_fn = lambda: w_up[..., nope:]  # noqa: E731
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    s_max = ckv.shape[1]
    scale = (nope + rope) ** -0.5
    scores = jnp.einsum("bhr,bsr->bhs", q_abs, ckv.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                         kpe.astype(jnp.float32))
    scores *= scale
    k_pos = jnp.arange(s_max)[None, :]
    scores = jnp.where((k_pos <= pos[:, None])[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv_fn().astype(jnp.float32))
    out = out.reshape(b, h * vdim).astype(x.dtype)
    nm = (lambda s_: None) if name is None else name
    y = linear(p["wo"], out, nm("wo"))
    return y, {"ckv": ckv, "kpe": kpe}
