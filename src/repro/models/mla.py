"""Multi-head Latent Attention (DeepSeek-V2) with absorbed decode.

Train/prefill uses the explicit form (latent → per-head K/V expansion).
Decode uses the **absorbed** form from the DeepSeek-V2 paper (arXiv:
2405.04434 §2.1.2): the per-head up-projections W_UK/W_UV are folded into
the query/output sides so the cache stays in the compressed latent space —
``[B, S, kv_lora + rope_dim]`` instead of ``[B, S, H, 2·hd]``. For
deepseek-v2-lite that is (512+64) vs 16·(192+128) = 5120 floats/token: an
8.9× cache-byte reduction, which compounds with the paper's INT4 weight
stream on the decode roofline.

The latent cache is sequence-sharded over `model` like every other decode
cache (SP-decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers
from repro.models.layers import apply_rope, linear, rmsnorm, rope_cos_sin


def mla_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    h, r, vdim = cfg.num_heads, cfg.kv_lora_rank, cfg.v_head_dim
    return {
        "q_proj": layers.linear_init(ks[0], d, h * (nope + rope), dtype=dtype),
        "kv_down": layers.linear_init(ks[1], d, r + rope, dtype=dtype),
        "kv_norm": layers.norm_init(r, dtype=dtype),
        "kv_up": layers.linear_init(ks[2], r, h * (nope + vdim), dtype=dtype),
        "wo": layers.linear_init(ks[3], h * vdim, d, dtype=dtype),
    }


def _project_q(p, x, cfg, positions, name):
    nm = (lambda s: None) if name is None else name
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    lead = x.shape[:-1]
    q = linear(p["q_proj"], x, nm("q_proj"))
    q = q.reshape(*lead, cfg.num_heads, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_cos_sin(positions, rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin, rope)
    return q_nope, q_rope


def _project_latent(p, x, cfg, positions, name):
    """x → (c_kv [.., r] post-norm, k_rope [.., rope] rope'd, shared)."""
    nm = (lambda s: None) if name is None else name
    r, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv = linear(p["kv_down"], x, nm("kv_down"))
    c, k_pe = ckv[..., :r], ckv[..., r:]
    c = rmsnorm(p["kv_norm"], c, eps=cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, rope, cfg.rope_theta)
    k_pe = apply_rope(k_pe[..., None, :], cos, sin, rope)[..., 0, :]
    return c, k_pe


def mla_attention(p, x, cfg, *, positions, name=None) -> jax.Array:
    """Train/prefill MLA (explicit form). x [B, S, D] → [B, S, D]."""
    b, s, _ = x.shape
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    h, vdim = cfg.num_heads, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, cfg, positions, name)
    c, k_pe = _project_latent(p, x, cfg, positions, name)
    nm = (lambda s_: None) if name is None else name
    kv = linear(p["kv_up"], c, nm("kv_up")).reshape(b, s, h, nope + vdim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    scale = (nope + rope) ** -0.5

    def qk_scores(qn, qr):
        # [B, C, H, *] vs keys [B, S, H/1, *]
        sc = jnp.einsum("bqhd,bshd->bhqs", qn, k_nope,
                        preferred_element_type=jnp.float32)
        sc += jnp.einsum("bqhd,bsd->bhqs", qr, k_pe,
                         preferred_element_type=jnp.float32)
        return sc * scale

    chunk = cfg.attn_chunk
    kpos = positions

    def attend(qn, qr, qpos):
        sc = qk_scores(qn, qr)
        mask = kpos[:, None, :] <= qpos[:, :, None]
        sc = jnp.where(mask[:, None, :, :], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", pr, v)

    if s > chunk and s % chunk == 0:
        nc = s // chunk
        qn = jnp.moveaxis(q_nope.reshape(b, nc, chunk, h, nope), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(b, nc, chunk, h, rope), 1, 0)
        pc = jnp.moveaxis(positions.reshape(b, nc, chunk), 1, 0)
        _, out = jax.lax.scan(lambda _, t: (None, attend(*t)), None,
                              (qn, qr, pc))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, h * vdim)
    else:
        out = attend(q_nope, q_rope, positions).reshape(b, s, h * vdim)
    return linear(p["wo"], out, nm("wo"))


# ---------------------------------------------------------------------------
# Decode (absorbed) + latent cache
# ---------------------------------------------------------------------------

def init_mla_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }


def fill_mla_cache_from_prefill(cache, c, k_pe):
    ck = jax.lax.dynamic_update_slice(
        cache["ckv"], c.astype(cache["ckv"].dtype), (0, 0, 0))
    kp = jax.lax.dynamic_update_slice(
        cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, 0, 0))
    return {"ckv": ck, "kpe": kp}


def mla_decode(p, cache, x, cfg, *, pos, name=None):
    """Absorbed single-token decode. x [B, D], pos [B] → (y, cache)."""
    b = x.shape[0]
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    h, r, vdim = cfg.num_heads, cfg.kv_lora_rank, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, cfg, pos, name)         # [B, H, *]
    c1, kpe1 = _project_latent(p, x, cfg, pos, name)          # [B, r]/[B, rope]

    bidx = jnp.arange(b)
    ckv = cache["ckv"].at[bidx, pos].set(c1.astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[bidx, pos].set(kpe1.astype(cache["kpe"].dtype))
    ckv = constrain(ckv, ("batch", "cache_seq", None))
    kpe = constrain(kpe, ("batch", "cache_seq", None))

    # Absorb W_UK into the query: q_abs[h, r] = q_nope[h, nope] · W_UK[r, h, nope]
    from repro.core.packing import PackedLinear, dequantize_packed
    if isinstance(p["kv_up"], PackedLinear):
        # Quantized serving: expand the (small) up-projection once per step;
        # the scores/values stream stays in the compressed latent space.
        # effective float weight = diag(input_scale) @ dequant(qweight)
        w_up = dequantize_packed(p["kv_up"], jnp.float32)
        w_up = w_up * p["kv_up"].input_scale[:, None]
    else:
        w_up = p["kv_up"]["w"]
    w_up = w_up.reshape(r, h, nope + vdim)
    w_uk, w_uv = w_up[..., :nope], w_up[..., nope:]
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    s_max = ckv.shape[1]
    scale = (nope + rope) ** -0.5
    scores = jnp.einsum("bhr,bsr->bhs", q_abs, ckv.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                         kpe.astype(jnp.float32))
    scores *= scale
    k_pos = jnp.arange(s_max)[None, :]
    scores = jnp.where((k_pos <= pos[:, None])[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(b, h * vdim).astype(x.dtype)
    nm = (lambda s_: None) if name is None else name
    y = linear(p["wo"], out, nm("wo"))
    return y, {"ckv": ckv, "kpe": kpe}
