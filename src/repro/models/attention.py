"""Attention: GQA/MQA/MHA, sliding-window, chunked-long-seq, decode caches.

Execution regimes:

  * train/prefill — q-chunked attention (`attn_chunk` queries at a time, full
    key rows per chunk) so 32k-token prefill never materializes an S×S score
    matrix. Softmax rows are complete per chunk → exact, no online rescaling.
  * decode (full cache) — single-token GEMV attention against a
    ``[B, S_max, Hkv, hd]`` cache. The cache is **sequence-sharded over the
    `model` mesh axis** (SP-decode, DESIGN.md §5); the masked softmax reduces
    over the sharded axis, which XLA lowers to two small all-reduces.
  * decode (ring cache) — sliding-window layers keep a ``[B, W, Hkv, hd]``
    ring buffer; slot ``s`` holds absolute position ``p - ((p - s) mod W)``,
    reconstructed in closed form for masking.
  * paged chunk (serving) — `attention_chunk_paged`: the engine's unified
    prefill/decode step over the page pools (scatter the block's K/V, then
    attend causally per token); single-token paged decode is its C = 1 form.

Everything runs through `layers.linear`, so all four projections quantize
through the paper's AWQ pipeline untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers
from repro.models.layers import apply_rope, linear, rmsnorm, rope_cos_sin


def attn_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": layers.linear_init(ks[0], d, cfg.q_dim, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "wk": layers.linear_init(ks[1], d, cfg.kv_dim, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "wv": layers.linear_init(ks[2], d, cfg.kv_dim, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "wo": layers.linear_init(ks[3], cfg.q_dim, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.norm_init(cfg.head_dim, dtype=dtype,
                                       plus_one=cfg.rms_plus_one)
        p["k_norm"] = layers.norm_init(cfg.head_dim, dtype=dtype,
                                       plus_one=cfg.rms_plus_one)
    return p


def _rope_theta(cfg, window: int) -> float:
    if window > 0 and cfg.local_rope_theta:
        return cfg.local_rope_theta
    return cfg.rope_theta


def _rot_dim(cfg) -> int:
    rd = int(cfg.head_dim * cfg.rope_fraction)
    return rd - rd % 2


def _project_qkv(p, x, cfg, positions, window, name):
    """x [..., D] -> q [..., H, hd], k/v [..., Hkv, hd], rope'd + qk-norm'd."""
    nm = (lambda s: None) if name is None else name
    lead = x.shape[:-1]
    q = linear(p["wq"], x, nm("wq")).reshape(*lead, cfg.num_heads,
                                             cfg.head_dim)
    k = linear(p["wk"], x, nm("wk")).reshape(*lead, cfg.num_kv_heads,
                                             cfg.head_dim)
    v = linear(p["wv"], x, nm("wv")).reshape(*lead, cfg.num_kv_heads,
                                             cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, eps=cfg.norm_eps,
                    plus_one=cfg.rms_plus_one)
        k = rmsnorm(p["k_norm"], k, eps=cfg.norm_eps,
                    plus_one=cfg.rms_plus_one)
    rd = _rot_dim(cfg)
    if rd:
        cos, sin = rope_cos_sin(positions, rd, _rope_theta(cfg, window))
        q = apply_rope(q, cos, sin, rd)
        k = apply_rope(k, cos, sin, rd)
    return q, k, v


def _sdpa(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
          scale: float, vis: jax.Array | None = None) -> jax.Array:
    """Grouped scaled-dot-product attention over full key rows.

    q [B, C, Hkv, G, hd]; k/v [B, S, Hkv, hd]; *_pos [B, C]/[B, S] absolute
    positions (k_pos < 0 ⇒ invalid slot). Returns [B, C, Hkv, G, hd].

    An explicit ``vis [B, C, S]`` boolean mask overrides the positional
    causal/window mask entirely (the generalized ancestor-mask read);
    rows whose mask is empty then produce exactly 0, matching the Pallas
    kernel's ``l == 0`` flush.
    """
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if vis is not None:
        vism = vis[:, None, None, :, :]
        scores = jnp.where(vism, scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.where(vism, jnp.exp(scores - m), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        probs = (p / jnp.where(l == 0.0, 1.0, l)).astype(v.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    mask = k_pos[:, None, :] >= 0
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def attention(p, x, cfg, *, positions, window: int = 0, causal: bool = True,
              name=None) -> jax.Array:
    """Train/prefill attention. x [B, S, D] -> [B, S, D]."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, window, name)
    g = cfg.num_heads // cfg.num_kv_heads
    q = q.reshape(b, s, cfg.num_kv_heads, g, cfg.head_dim)
    q = constrain(q, ("batch", None, "kv_heads", "q_groups", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    scale = cfg.head_dim ** -0.5

    chunk = cfg.attn_chunk
    msize = 1
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if mesh is not None:
        msize = mesh.shape.get("model", 1)
    # §Perf C2: when heads don't divide the model axis (smollm 15H, hymba
    # 25H, gemma 8H, …) head-sharding falls back to replication — every
    # model rank would redo the full O(S²) attention. Instead shard the
    # QUERY CHUNKS over `model`: each rank attends its chunks against the
    # (replicated) K/V; the only added comm is the [B,S,q_dim] output
    # gather, ~16× smaller than the replicated compute it removes.
    shard_chunks = (msize > 1 and cfg.num_heads % msize != 0
                    and s % chunk == 0 and (s // chunk) % msize == 0)
    if shard_chunks:
        n_chunks = s // chunk
        qc = q.reshape(b, n_chunks, chunk, cfg.num_kv_heads, g, cfg.head_dim)
        qc = constrain(qc, ("batch", "model", None, None, None, None))
        pc = positions.reshape(b, n_chunks, chunk)
        out = jax.vmap(
            lambda q_i, p_i: _sdpa(q_i, k, v, p_i, positions, causal=causal,
                                   window=window, scale=scale),
            in_axes=(1, 1), out_axes=1)(qc, pc)
        out = out.reshape(b, s, cfg.q_dim)
    elif s > chunk and s % chunk == 0:
        n_chunks = s // chunk
        qc = q.reshape(b, n_chunks, chunk, cfg.num_kv_heads, g, cfg.head_dim)
        qc = jnp.moveaxis(qc, 1, 0)                       # [nc, B, C, ...]
        pc = jnp.moveaxis(positions.reshape(b, n_chunks, chunk), 1, 0)

        def body(_, qp):
            q_i, p_i = qp
            o = _sdpa(q_i, k, v, p_i, positions, causal=causal,
                      window=window, scale=scale)
            return None, o

        _, out = jax.lax.scan(body, None, (qc, pc))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.q_dim)
    else:
        out = _sdpa(q, k, v, positions, positions, causal=causal,
                    window=window, scale=scale).reshape(b, s, cfg.q_dim)
    nm = (lambda s_: None) if name is None else name
    return linear(p["wo"], out, nm("wo"))


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_seq: int, window: int,
                  dtype=jnp.bfloat16):
    s = min(window, max_seq) if window else max_seq
    shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_quant == "int8":
        # paper's bandwidth argument applied to the cache: INT8 codes +
        # per-(position, head) absmax scale — 2.1× fewer cache bytes/step.
        sshape = (batch, s, cfg.num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(sshape, jnp.float32),
                "vs": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [..., hd] → (int8 codes, per-[...] absmax scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def _ring_positions(pos: jax.Array, w: int) -> jax.Array:
    """Absolute position held by each ring slot; <0 ⇒ not yet written.

    Slot s (0..W-1) at current position ``pos`` (the token being written)
    holds the newest absolute position p ≤ pos with p ≡ s (mod W).
    """
    slots = jnp.arange(w)[None, :]
    p = pos[:, None]
    return p - ((p - slots) % w)


def fill_cache_from_prefill(cache, k, v, positions, window: int):
    """Write prefill keys/values [B, S, ...] into a fresh decode cache."""
    b, s = k.shape[0], k.shape[1]
    quant = "ks" in cache
    if quant:
        k, ks = _kv_quantize(k)
        v, vs = _kv_quantize(v)
    if not window or s <= window:
        out = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
        if quant:
            out["ks"] = jax.lax.dynamic_update_slice(cache["ks"], ks,
                                                     (0, 0, 0))
            out["vs"] = jax.lax.dynamic_update_slice(cache["vs"], vs,
                                                     (0, 0, 0))
        return out
    # ring: keep the last W tokens at slot = pos % W
    kw, vw = k[:, -window:], v[:, -window:]
    pw = positions[:, -window:] % window                  # [B, W]
    bidx = jnp.arange(b)[:, None]
    out = {"k": cache["k"].at[bidx, pw].set(kw.astype(cache["k"].dtype)),
           "v": cache["v"].at[bidx, pw].set(vw.astype(cache["v"].dtype))}
    if quant:
        out["ks"] = cache["ks"].at[bidx, pw].set(ks[:, -window:])
        out["vs"] = cache["vs"].at[bidx, pw].set(vs[:, -window:])
    return out


def init_paged_kv_cache(cfg, num_pages: int, page_size: int,
                        dtype=jnp.bfloat16, kv_quant: str | None = None):
    """Page pool for one layer: ``[num_pages, page_size, Hkv, hd]``.

    Physical pages are normally owned by one request slot; prefix sharing
    lets several slots alias read-only pages (the pager refcounts them).
    Logical order is reconstructed at read time by gathering through the
    per-slot page table. Page 0 is the pager's scratch page — inactive
    slots keep scattering into it so the jit'd decode step never
    re-specializes on batch composition.

    ``kv_quant`` overrides ``cfg.kv_quant`` for the pool only (the serving
    engine uses this to hold int8 pages under a float model config —
    quantize-on-commit / dequant-on-gather): int8 codes plus per-(position,
    head) float32 absmax scale strips ``ks``/``vs``.
    """
    kv_quant = cfg.kv_quant if kv_quant is None else kv_quant
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    if kv_quant == "int8":
        sshape = (num_pages, page_size, cfg.num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(sshape, jnp.float32),
                "vs": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_chunk_paged(p, pool, page_table, x, cfg, *, pos, rpos=None,
                          amask=None, window: int = 0, name=None):
    """Token-budget chunk step against a paged KV pool — the unified
    prefill/decode execution path.

    x ``[B, C, D]`` — each batch row is one request slot's contribution to
    this step: a prefill chunk of up to C tokens, a speculation tree, a
    single decode token (remaining positions padded), or nothing (all
    padding). pos ``[B, C]`` int32 absolute KV **slot** positions, ``-1``
    marking padding tokens (in-span tokens always occupy contiguous slots
    from the committed watermark ``pos[b, 0]``); page_table
    ``[B, pages_per_slot]`` int32 (row = slot). Returns (y [B, C, D],
    new pool).

    ``rpos`` is the **logical** position (RoPE angle + window anchor),
    defaulting to ``pos`` — the two differ only for tree-speculation
    rows, where sibling branches share a depth but not a slot. ``amask``
    ``[B, C, C]`` is the explicit intra-chunk ancestor-mask block (plain
    causality when ``None``); ``window`` masks committed positions that
    have slid out of a local-attention layer's window (their pages stay
    resident — the mask, not eviction, enforces locality, which is what
    lets windowed layers share the paged pools with global layers).

    Execution order is scatter-then-gather: every valid token's K/V is
    written into ``pool[table[b, pos // P], pos % P]`` first (padding
    redirected to the reserved scratch page 0), then each token attends
    over its slot's pages under the three-part visibility rule of
    `kernels.ref.chunk_visibility_ref`: committed pages pass the causal
    watermark (+ window) test — decode tokens, earlier chunks, and
    **aliased shared-prefix pages**, which are therefore read, never
    recomputed (prefix sharing saves prefill FLOPs, not just memory) —
    and in-span keys route through ``amask``. Stale table entries hold
    positions beyond the watermark + span and are always masked.

    Int8 pools quantize each token on write with the per-(position, head)
    absmax codec — identical to one-shot quantize-on-commit, so chunked
    and one-shot commits produce bit-identical pages — and dequantize at
    the point of use: on TPU via the fused multi-query Pallas kernel
    (`kernels.paged_attention.paged_attention_chunk` — page table in
    scalar-prefetch memory, dequant in VMEM, one page read amortized over
    the whole chunk), elsewhere via the jnp gather below, which doubles
    as the kernel's reference semantics.
    """
    b, c, _ = x.shape
    page_size = pool["k"].shape[1]
    valid = pos >= 0
    logical = pos if rpos is None else rpos
    rope_pos = jnp.where(valid, logical, 0)
    if amask is not None and window:
        # a supplied ancestor mask is authoritative for in-span keys (the
        # kernel applies ``window`` only to committed pages), so fold the
        # in-span locality bound in here — once, above both read paths.
        # Logical positions anchor the bound: tree siblings share a depth.
        amask = (amask.astype(jnp.bool_)
                 & (rope_pos[:, None, :] > rope_pos[:, :, None] - window))
    q, k1, v1 = _project_qkv(p, x, cfg, rope_pos, window,
                             name)                        # [B, C, H(kv), hd]
    k1 = constrain(k1, ("batch", None, "kv_heads", None))
    v1 = constrain(v1, ("batch", None, "kv_heads", None))
    slot_pos = jnp.where(valid, pos, 0)
    phys = jnp.take_along_axis(page_table, slot_pos // page_size, axis=1)
    phys = jnp.where(valid, phys, 0)          # padding → scratch page 0
    offset = jnp.where(valid, slot_pos % page_size, 0)
    fp, fo = phys.reshape(-1), offset.reshape(-1)
    quant = "ks" in pool
    new_pool = {}
    if quant:
        k1, ks1 = _kv_quantize(k1)
        v1, vs1 = _kv_quantize(v1)
        new_pool["ks"] = pool["ks"].at[fp, fo].set(
            ks1.reshape(b * c, cfg.num_kv_heads))
        new_pool["vs"] = pool["vs"].at[fp, fo].set(
            vs1.reshape(b * c, cfg.num_kv_heads))
    kv_shape = (b * c, cfg.num_kv_heads, cfg.head_dim)
    new_pool["k"] = pool["k"].at[fp, fo].set(
        k1.reshape(kv_shape).astype(pool["k"].dtype))
    new_pool["v"] = pool["v"].at[fp, fo].set(
        v1.reshape(kv_shape).astype(pool["v"].dtype))
    # keep the pool mesh-sharded through the scatter (pools stripe over KV
    # heads on the `model` axis — `distributed.paged_cache_pspec`); without
    # the constraint GSPMD may gather the whole pool onto every device
    new_pool["k"] = constrain(new_pool["k"], (None, None, "kv_heads", None))
    new_pool["v"] = constrain(new_pool["v"], (None, None, "kv_heads", None))
    if quant:
        new_pool["ks"] = constrain(new_pool["ks"], (None, None, "kv_heads"))
        new_pool["vs"] = constrain(new_pool["vs"], (None, None, "kv_heads"))

    g = cfg.num_heads // cfg.num_kv_heads
    nm = (lambda s_: None) if name is None else name
    if quant:
        from repro.distributed.sharding import current_mesh
        from repro.kernels import paged_attention as paged_kernel
        if paged_kernel.supported():
            qk = q.reshape(b, c, cfg.num_kv_heads, g, cfg.head_dim)
            mesh = current_mesh()
            if (mesh is not None and mesh.shape.get("model", 1) > 1
                    and cfg.num_kv_heads % mesh.shape["model"] == 0):
                # tensor-parallel: shard_map over the head axis — each
                # device runs the unmodified kernel on its local heads
                out = paged_kernel.paged_attention_chunk_sharded(
                    qk, new_pool["k"], new_pool["ks"], new_pool["v"],
                    new_pool["vs"], page_table, pos, mesh=mesh,
                    rpos=rpos, amask=amask, window=window,
                    scale=cfg.head_dim ** -0.5)
            else:
                out = paged_kernel.paged_attention_chunk(
                    qk, new_pool["k"], new_pool["ks"], new_pool["v"],
                    new_pool["vs"], page_table, pos,
                    rpos=rpos, amask=amask, window=window,
                    scale=cfg.head_dim ** -0.5)
            out = out.reshape(b, c, cfg.q_dim).astype(
                jnp.dtype(cfg.activation_dtype))
            return linear(p["wo"], out, nm("wo")), new_pool

    # gather-based read: page table → logical [B, S_slot, Hkv, hd] view
    # (the gathered view inherits the pool's head sharding, so each device
    # gathers and attends only its local heads — the reference semantics
    # of the shard_map'd kernel above)
    s_slot = page_table.shape[1] * page_size
    ck = new_pool["k"][page_table].reshape(b, s_slot, cfg.num_kv_heads,
                                           cfg.head_dim)
    cv = new_pool["v"][page_table].reshape(b, s_slot, cfg.num_kv_heads,
                                           cfg.head_dim)
    ck = constrain(ck, ("batch", None, "kv_heads", None))
    cv = constrain(cv, ("batch", None, "kv_heads", None))
    adt = jnp.dtype(cfg.activation_dtype)
    if quant:
        ks = new_pool["ks"][page_table].reshape(b, s_slot, cfg.num_kv_heads)
        vs = new_pool["vs"][page_table].reshape(b, s_slot, cfg.num_kv_heads)
        ck = _kv_dequant(ck, ks, adt)
        cv = _kv_dequant(cv, vs, adt)
    k_pos = jnp.broadcast_to(jnp.arange(s_slot)[None, :], (b, s_slot))
    qg = q.reshape(b, c, cfg.num_kv_heads, g, cfg.head_dim)
    qg = constrain(qg, ("batch", None, "kv_heads", None, None))
    if rpos is None and amask is None and not window:
        # plain linear chunk: the arange causal mask is exact (see above)
        out = _sdpa(qg, ck, cv, pos, k_pos, causal=True, window=0,
                    scale=cfg.head_dim ** -0.5)
    else:
        from repro.kernels.ref import chunk_visibility_ref
        vis = chunk_visibility_ref(pos, s_slot=s_slot, rpos=rpos,
                                   amask=amask, window=window)
        out = _sdpa(qg, ck, cv, pos, k_pos, causal=True, window=0,
                    scale=cfg.head_dim ** -0.5, vis=vis)
    out = out.reshape(b, c, cfg.q_dim)
    y = linear(p["wo"], out, nm("wo"))
    return y, new_pool


def attention_decode_paged(p, pool, page_table, x, cfg, *, pos,
                           window: int = 0, name=None):
    """Single-token decode against a paged KV pool: the C = 1 form of
    `attention_chunk_paged` (one implementation serves both regimes).

    pool leaves ``[num_pages, P, ...]``; page_table ``[B, pages_per_slot]``
    int32; x ``[B, D]``, pos ``[B]``. Returns (y [B, D], new pool). The
    chunk path's causal arange mask reduces to exactly the old
    ``k_pos <= pos`` decode mask at C = 1, so the gathered logical view
    stays laid out like the dense ``[B, S, Hkv, hd]`` cache and paged and
    dense decode produce bitwise-identical attention outputs (same kv
    regime). A sliding ``window`` masks committed positions at or below
    ``pos - window`` in the paged read (pages stay resident).
    """
    y, new_pool = attention_chunk_paged(p, pool, page_table, x[:, None],
                                        cfg, pos=pos[:, None], window=window,
                                        name=name)
    return y[:, 0], new_pool


def attention_decode(p, cache, x, cfg, *, pos, window: int = 0, name=None):
    """Single-token decode. x [B, D], pos [B] -> (y [B, D], new cache).

    Cache layout + sharding: see module docstring. The update is a per-sample
    scatter (continuous batching keeps per-request positions).
    """
    b = x.shape[0]
    q, k1, v1 = _project_qkv(p, x, cfg, pos, window, name)  # [B, H(kv), hd]
    slot = (pos % window) if window else pos
    bidx = jnp.arange(b)
    quant = "ks" in cache
    new_cache = {}
    if quant:
        k1, ks1 = _kv_quantize(k1)
        v1, vs1 = _kv_quantize(v1)
        new_cache["ks"] = constrain(
            cache["ks"].at[bidx, slot].set(ks1),
            ("batch", "cache_seq", None))
        new_cache["vs"] = constrain(
            cache["vs"].at[bidx, slot].set(vs1),
            ("batch", "cache_seq", None))
    ck = cache["k"].at[bidx, slot].set(k1.astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slot].set(v1.astype(cache["v"].dtype))
    ck = constrain(ck, ("batch", "cache_seq", None, None))
    cv = constrain(cv, ("batch", "cache_seq", None, None))
    new_cache["k"], new_cache["v"] = ck, cv
    adt = jnp.dtype(cfg.activation_dtype)
    if quant:
        # dequant at point of use — on TPU this fuses into the attention
        # dots (same role as the AWQ weight dequant in the MAC pipeline)
        ck = _kv_dequant(ck, new_cache["ks"], adt)
        cv = _kv_dequant(cv, new_cache["vs"], adt)

    if window:
        k_pos = _ring_positions(pos, ck.shape[1])
    else:
        s_max = ck.shape[1]
        k_pos = jnp.where(jnp.arange(s_max)[None, :] <= pos[:, None],
                          jnp.arange(s_max)[None, :], -1)

    g = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, 1, cfg.num_kv_heads, g, cfg.head_dim)
    out = _sdpa(qg, ck, cv, pos[:, None], k_pos, causal=bool(window),
                window=window, scale=cfg.head_dim ** -0.5)
    out = out.reshape(b, cfg.q_dim)
    nm = (lambda s_: None) if name is None else name
    y = linear(p["wo"], out, nm("wo"))
    return y, new_cache
