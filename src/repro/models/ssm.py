"""Mamba-2 (SSD — state-space duality) mixer: chunked train/prefill + O(1)
recurrent decode.

Implements the minimal SSD form of arXiv:2405.21060: scalar decay per head
(A = -exp(a_log)), per-head dt via softplus, grouped B/C (n_groups), short
depthwise causal conv on x/B/C, gated RMSNorm output.

Chunked algorithm (chunk length Q): within a chunk the token mixing is the
"attention-like" quadratic form masked by the cumulative decay; across
chunks a scan carries the [nh, hd, ds] state. Decode is the pure recurrence
h ← h·exp(dA) + dt·B⊗x — attention-free, constant state, which is why
mamba2-130m (and hymba's SSM branch) run the long_500k cell that pure
full-attention architectures skip.

Projections (wz/wx/wb/wc/wdt, out_proj) are separate linears (not the fused
in_proj of the reference CUDA impl) so TP sharding and AWQ quantization see
clean per-role matrices — DESIGN.md §2 hardware-adaptation note.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers
from repro.models.layers import linear


def ssm_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    gdim = cfg.ssm_ngroups * ds
    dc = cfg.ssm_conv
    p = {
        "wz": layers.linear_init(ks[0], d, di, dtype=dtype),
        "wx": layers.linear_init(ks[1], d, di, dtype=dtype),
        "wb": layers.linear_init(ks[2], d, gdim, dtype=dtype),
        "wc": layers.linear_init(ks[3], d, gdim, dtype=dtype),
        "wdt": layers.linear_init(ks[4], d, nh, dtype=dtype),
        "conv_x": {"k": (jax.random.normal(ks[5], (dc, di)) / dc).astype(dtype),
                   "b": jnp.zeros((di,), dtype)},
        "conv_b": {"k": (jax.random.normal(ks[6], (dc, gdim)) / dc).astype(dtype),
                   "b": jnp.zeros((gdim,), dtype)},
        "conv_c": {"k": (jax.random.normal(ks[7], (dc, gdim)) / dc).astype(dtype),
                   "b": jnp.zeros((gdim,), dtype)},
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "ssm_d": jnp.ones((nh,), jnp.float32),
        "out_norm": layers.norm_init(di, dtype=dtype),
        "out_proj": layers.linear_init(ks[8], di, d, dtype=dtype),
    }
    return p


def _causal_conv(u: jax.Array, kern: dict) -> jax.Array:
    """Depthwise causal conv1d + silu. u [B, S, C], kernel [dc, C]."""
    dc = kern["k"].shape[0]
    pad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * kern["k"][i][None, None, :]
              for i in range(dc))
    return jax.nn.silu(out + kern["b"][None, None, :])


def _conv_step(u1: jax.Array, conv_cache: jax.Array, kern: dict):
    """One-token causal conv. u1 [B, C]; cache [B, dc-1, C] (past inputs)."""
    window = jnp.concatenate([conv_cache, u1[:, None, :]], axis=1)  # [B,dc,C]
    out = jnp.einsum("bdc,dc->bc", window, kern["k"]) + kern["b"][None, :]
    return jax.nn.silu(out), window[:, 1:, :]


def _heads(x, nh, hd):
    return x.reshape(*x.shape[:-1], nh, hd)


def ssm_mixer(p, x_in: jax.Array, cfg, name=None) -> jax.Array:
    """Train/prefill SSD. x_in [B, S, D] → [B, S, D]."""
    nm = (lambda s: None) if name is None else name
    b, s, _ = x_in.shape
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    hd, ng = cfg.ssm_headdim, cfg.ssm_ngroups

    z = linear(p["wz"], x_in, nm("wz"))
    x = _causal_conv(linear(p["wx"], x_in, nm("wx")), p["conv_x"])
    bb = _causal_conv(linear(p["wb"], x_in, nm("wb")), p["conv_b"])
    cc = _causal_conv(linear(p["wc"], x_in, nm("wc")), p["conv_c"])
    dt = jax.nn.softplus(
        linear(p["wdt"], x_in, nm("wdt")).astype(jnp.float32)
        + p["dt_bias"][None, None, :])                       # [B, S, nh]
    x = constrain(x, ("batch", None, "ssm_inner"))

    xh = _heads(x, nh, hd).astype(jnp.float32)               # [B,S,nh,hd]
    # broadcast groups → heads
    bg = _heads(bb, ng, ds).astype(jnp.float32)              # [B,S,ng,ds]
    cg = _heads(cc, ng, ds).astype(jnp.float32)
    rep = nh // ng
    bh = jnp.repeat(bg, rep, axis=2)                         # [B,S,nh,ds]
    ch = jnp.repeat(cg, rep, axis=2)

    a = -jnp.exp(p["a_log"])[None, None, :]                  # [1,1,nh]
    da = dt * a                                              # [B,S,nh]

    q = min(cfg.ssm_chunk, s)
    if s % q:
        q = s  # fallback: single chunk
    nc = s // q

    def reshape_c(t):
        return t.reshape(b, nc, q, *t.shape[2:])

    xc, bc, cc_, dac, dtc = map(reshape_c, (xh, bh, ch, da, dt))
    seg = jnp.cumsum(dac, axis=2)                            # [B,nc,Q,nh]

    # intra-chunk (quadratic, decay-masked). Mask the EXPONENT, not the
    # result: exp() of anti-causal entries overflows and poisons the
    # gradient through jnp.where (inf * 0 = NaN in the cotangent).
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]       # [B,nc,Qi,Qj,nh]
    causal = jnp.tril(jnp.ones((q, q), bool))
    li = jnp.where(causal[None, None, :, :, None], li, -1e30)
    decay = jnp.exp(li)
    scores = jnp.einsum("bnihs,bnjhs->bnijh", cc_, bc) * decay \
        * dtc[:, :, None, :, :]                              # [B,nc,Qi,Qj,nh]
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", scores, xc)

    # chunk states + inter-chunk scan
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)          # [B,nc,Q,nh]
    state_c = jnp.einsum("bnjhs,bnjh,bnjhd->bnhds",
                         bc, dtc * decay_to_end, xc)         # [B,nc,nh,hd,ds]
    chunk_decay = jnp.exp(seg[:, :, -1, :])                  # [B,nc,nh]

    def scan_body(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_body, h0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # [B,nc,nh,hd,ds]

    y_inter = jnp.einsum("bnihs,bnhds->bnihd", cc_ * jnp.exp(seg)[..., None],
                         h_prev)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    y = y + xh.reshape(b, s, nh, hd) * p["ssm_d"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x_in.dtype)

    y = layers.rmsnorm(p["out_norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    return linear(p["out_proj"], y, nm("out_proj"))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    gdim = cfg.ssm_ngroups * ds
    dc = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, dc - 1, di), dtype),
        "conv_b": jnp.zeros((batch, dc - 1, gdim), dtype),
        "conv_c": jnp.zeros((batch, dc - 1, gdim), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_headdim, ds), jnp.float32),
    }


def ssm_decode(p, cache, x_in: jax.Array, cfg, name=None):
    """One-token recurrence. x_in [B, D] → (y [B, D], new cache)."""
    nm = (lambda s: None) if name is None else name
    b = x_in.shape[0]
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    hd, ng = cfg.ssm_headdim, cfg.ssm_ngroups

    z = linear(p["wz"], x_in, nm("wz"))
    x, cx = _conv_step(linear(p["wx"], x_in, nm("wx")), cache["conv_x"],
                       p["conv_x"])
    bb, cb = _conv_step(linear(p["wb"], x_in, nm("wb")), cache["conv_b"],
                        p["conv_b"])
    cc, ccs = _conv_step(linear(p["wc"], x_in, nm("wc")), cache["conv_c"],
                         p["conv_c"])
    dt = jax.nn.softplus(
        linear(p["wdt"], x_in, nm("wdt")).astype(jnp.float32)
        + p["dt_bias"][None, :])                              # [B, nh]

    xh = _heads(x, nh, hd).astype(jnp.float32)                # [B,nh,hd]
    rep = nh // ng
    bh = jnp.repeat(_heads(bb, ng, ds).astype(jnp.float32), rep, axis=1)
    ch = jnp.repeat(_heads(cc, ng, ds).astype(jnp.float32), rep, axis=1)

    a = -jnp.exp(p["a_log"])[None, :]                         # [1,nh]
    da = jnp.exp(dt * a)                                      # [B,nh]
    h = cache["state"] * da[:, :, None, None] + \
        jnp.einsum("bh,bhs,bhd->bhds", dt, bh, xh)            # [B,nh,hd,ds]
    y = jnp.einsum("bhds,bhs->bhd", h, ch)                    # [B,nh,hd]
    y = y + xh * p["ssm_d"][None, :, None]
    y = y.reshape(b, di).astype(x_in.dtype)
    y = layers.rmsnorm(p["out_norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = linear(p["out_proj"], y, nm("out_proj"))
    return out, {"conv_x": cx, "conv_b": cb, "conv_c": ccs,
                 "state": h}
