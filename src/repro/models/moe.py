"""Mixture-of-Experts: top-k routing with capacity-based scatter dispatch.

GShard-style algorithm (shardable under pure pjit):
  1. router logits → top-k (gates, expert ids) per token,
  2. position-in-expert via k sequential cumsums over the one-hot assignment
     (tokens beyond an expert's capacity are dropped — training-standard),
  3. scatter tokens into an ``[E, C, D]`` buffer (capacity sharded over the
     DP axes, expert FFN dim over `model` → the expert matmuls run without
     any collective),
  4. batched expert GLU via einsum over stacked ``[E, D, F]`` weights,
  5. gather back per (token, k) slot, combine with gate weights.

Qwen2-MoE specifics supported: 4 shared experts applied to every token with
a sigmoid gate, routed top-4 over 60 experts, optional top-k prob
normalization. DeepSeek-V2-lite reuses the same module (2 shared, top-6).

Expert linears are stacked ``[E, K, N]`` and quantize through the AWQ
pipeline like any other linear (per-expert groups — the stacked dim is just
extra leading layers to `quantize_params`). The tiny router stays FP
(AWQ convention; it is salience-critical and <0.01% of bytes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackedLinear, dequantize_packed
from repro.distributed import shard_map
from repro.models import layers
from repro.models.layers import activation, linear


def moe_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    scale = 1.0 / np.sqrt(d)

    def stacked(k_, a, b_, s):
        return {"w": (jax.random.normal(k_, (e, a, b_)) * s).astype(dtype)}

    p = {
        "router": layers.linear_init(ks[0], d, e, dtype=jnp.float32),
        "experts": {
            "gate": stacked(ks[1], d, f, scale),
            "up": stacked(ks[2], d, f, scale),
            "down": stacked(ks[3], f, d, 1.0 / np.sqrt(f)),
        },
    }
    if cfg.num_shared_experts:
        sf = cfg.shared_d_ff
        p["shared"] = {
            "gate": layers.linear_init(ks[4], d, sf, dtype=dtype),
            "up": layers.linear_init(ks[5], d, sf, dtype=dtype),
            "down": layers.linear_init(ks[6], sf, d, dtype=dtype),
        }
        if cfg.shared_expert_gate:
            p["shared_gate"] = layers.linear_init(ks[7], d, 1,
                                                  dtype=jnp.float32)
    return p


def _expert_weight(node, name: str) -> jax.Array:
    """[E, K, N] float weights — EAGER all-expert dequant if packed.

    Only the data-parallel float dispatch still uses this (it replicates
    float weights into the manual region). The packed hot paths go
    through `_glu_ffn_packed` / the per-expert maps in `body_q`, which
    keep one expert's dense weight live at a time.
    """
    leaf = node[name]
    if isinstance(leaf, PackedLinear):
        e = leaf.qweight.shape[0]
        w = jax.vmap(lambda q, s, z, isc: dequantize_packed(
            PackedLinear(q, s, z, isc, None, leaf.group_size), jnp.float32)
            * isc[:, None])(leaf.qweight, leaf.scales, leaf.zeros,
                            leaf.input_scale)
        return w
    return leaf["w"]


def _dequant_block(q, s, z):
    """ONE [K//PACK, N] packed block + [G, N] meta → [K, N] f32."""
    from repro.core.packing import unpack_int4
    qi = unpack_int4(q).astype(jnp.float32)           # [K, N]
    g, n = s.shape
    qg = qi.reshape(g, qi.shape[0] // g, n)
    w = (qg - z[:, None, :].astype(jnp.float32)) \
        * s[:, None, :].astype(jnp.float32)
    return w.reshape(qi.shape[0], n)


def _glu_ffn(buf, wg, wu, wd, act):
    """Batched expert GLU over the [E, C, D] capacity buffer (float)."""
    h = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
    h = activation(act, h) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(buf.dtype))


def _glu_ffn_packed(experts, buf, act):
    """Expert GLU with PER-EXPERT lazy dequant.

    ``lax.map`` (a sequential scan) dequantizes one expert inside each
    iteration, so peak live weight bytes are ONE expert's dense [K, N] —
    not the full [E, K, N] stack the eager path materialized, which
    erased the W4 bandwidth win exactly on the decode hot path.
    """
    pg, pu, pd = experts["gate"], experts["up"], experts["down"]

    def one(args):
        b, g_, u_, d_ = args
        wg = _dequant_block(*g_[:3]) * g_[3][:, None]
        wu = _dequant_block(*u_[:3]) * u_[3][:, None]
        wd = _dequant_block(*d_[:3]) * d_[3][:, None]
        h = activation(act, b @ wg.astype(b.dtype)) * (b @ wu.astype(b.dtype))
        return h @ wd.astype(b.dtype)

    def leaves(pl):
        return (pl.qweight, pl.scales, pl.zeros, pl.input_scale)

    return jax.lax.map(one, (buf, leaves(pg), leaves(pu), leaves(pd)))


def capacity(cfg, n_tokens: int) -> int:
    # Small batches (decode / short prefill) run DROPLESS: per-expert load is
    # bounded by n_tokens (top-k indices are distinct), so cap = T suffices —
    # serving never silently drops tokens. Large training batches use the
    # standard capacity-factor formula (GShard dropping).
    if n_tokens <= 1024:
        return n_tokens
    c = int(np.ceil(cfg.top_k * n_tokens / cfg.num_experts
                    * cfg.capacity_factor))
    return max(8, int(np.ceil(c / 8)) * 8)


def _dp_groups(n_tokens: int) -> int:
    """Dispatch group count = DP degree (gcd'd against the token count).

    Grouped dispatch is what keeps the GShard algorithm SPMD-local: tokens
    are reshaped [G, T/G, D] with G sharded over the DP axes, so the
    one-hot/cumsum/scatter machinery runs independently per data shard —
    no cross-shard replication of the expert buffer (the naive global-
    capacity formulation made XLA replicate a [E, C, D] buffer per device).
    """
    from repro.distributed.sharding import batch_axes, current_mesh
    import math
    mesh = current_mesh()
    if mesh is None:
        return 1
    dp = 1
    for a in batch_axes(mesh):
        dp *= mesh.shape[a]
    return math.gcd(n_tokens, dp)


def _dispatch_compute_combine(xt, idx, gates, ffn, cfg, cap):
    """Scatter → expert FFN (``ffn(buf) -> out_buf``) → gather, LOCAL.

    xt [T, D] (local tokens), idx/gates [T, k]. ``ffn`` maps the
    [E, C, D] capacity buffer to [E, C, D'] — `_glu_ffn` for float
    stacks, `_glu_ffn_packed` for lazy per-expert dequant. Pure local
    computation — no collective ops; designed to run inside `shard_map`.
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.top_k
    buf = jnp.zeros((e, cap, d), xt.dtype)
    counts = jnp.zeros((e,), jnp.int32)
    slot_list, keep_list = [], []
    for j in range(k):
        onehot = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)    # [T, E]
        pos_in = jnp.cumsum(onehot, axis=0) - onehot
        slot = jnp.take_along_axis(pos_in, idx[:, j:j + 1],
                                   axis=1)[:, 0] + counts[idx[:, j]]
        keep = slot < cap
        slot = jnp.where(keep, slot, cap - 1)
        buf = buf.at[idx[:, j], slot].add(
            jnp.where(keep[:, None], xt, 0), mode="drop")
        counts = counts + jnp.sum(onehot, axis=0)
        slot_list.append(slot)
        keep_list.append(keep)

    out_buf = ffn(buf)

    y = jnp.zeros_like(xt)
    for j in range(k):
        got = out_buf[idx[:, j], slot_list[j]]                    # [T, D]
        y += jnp.where(keep_list[j][:, None], got, 0) \
            * gates[:, j:j + 1].astype(xt.dtype)
    return y


def moe_apply(p, x: jax.Array, cfg, name=None) -> tuple[jax.Array, jax.Array]:
    """x [B, S, D] (or [T, D]) → (y, aux_loss).

    Distribution (§Perf B2, DESIGN §5): the dispatch/combine runs MANUALLY
    per device via `shard_map` — tokens stay on their DP shard, the expert
    FFN dim is TP-sharded over `model`, and the only collective is one
    explicit psum of the token outputs over `model` (the row-parallel
    partial sum). Under auto-SPMD the data-dependent scatter/gather made
    XLA shard the scatter updates and all-reduce the full [E, C, D] buffer
    per layer (~100 GB/chip/step on deepseek-v2 train_4k — the dominant
    §Roofline term before this change, 29× over the DP-gradient floor).
    """
    from repro.distributed.sharding import batch_axes, current_mesh
    nm = (lambda s: None) if name is None else name
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)                         # [T, D] global tokens
    t = xt.shape[0]
    e, k = cfg.num_experts, cfg.top_k

    logits = linear(p["router"], xt.astype(jnp.float32))      # [T, E] fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gates_t, idx_t = jax.lax.top_k(probs, k)                  # [T, k]
    if cfg.norm_topk_prob:
        gates_t = gates_t / jnp.clip(jnp.sum(gates_t, -1, keepdims=True),
                                     1e-9)

    packed = isinstance(p["experts"]["gate"], PackedLinear)
    mesh = current_mesh()
    dp_size = 1
    if mesh is not None:
        for a in batch_axes(mesh):
            dp_size *= mesh.shape[a]
    # manual dispatch requires one whole token-group per DP shard
    if mesh is not None and dp_size > 1 and t % dp_size == 0:
        from jax.sharding import PartitionSpec as P
        dp = batch_axes(mesh)
        g = dp_size
        tg = t // g
        cap = capacity(cfg, tg)
        xg = xt.reshape(g, tg, d)
        idx = idx_t.reshape(g, tg, k)
        gates = gates_t.reshape(g, tg, k)
        has_model = "model" in mesh.axis_names

        if packed and has_model:
            # §Perf B4 (quantized serving): expert weights enter the manual
            # region PACKED — gate/up F-sharded, down D-sharded (F-sharding
            # would split quant groups; see sharding.py) — and dequantize
            # shard-locally. Comm: all-gather of h over F and of y over D,
            # both tiny at decode token counts. No weight ever crosses ICI.
            pg, pu, pd = (p["experts"][n] for n in ("gate", "up", "down"))

            def body_q(xg_l, idx_l, gates_l, qg, sg, zg, isg, qu, su, zu,
                       isu, qd, sd, zd, isd):
                xt_l, idx_ll, gates_ll = xg_l[0], idx_l[0], gates_l[0]
                e = cfg.num_experts
                buf = jnp.zeros((e, cap, d), xt_l.dtype)
                counts = jnp.zeros((e,), jnp.int32)
                slots, keeps = [], []
                for j in range(k):
                    onehot = jax.nn.one_hot(idx_ll[:, j], e,
                                            dtype=jnp.int32)
                    pos_in = jnp.cumsum(onehot, axis=0) - onehot
                    slot = jnp.take_along_axis(
                        pos_in, idx_ll[:, j:j + 1], axis=1)[:, 0] \
                        + counts[idx_ll[:, j]]
                    keep = slot < cap
                    slot = jnp.where(keep, slot, cap - 1)
                    buf = buf.at[idx_ll[:, j], slot].add(
                        jnp.where(keep[:, None], xt_l, 0), mode="drop")
                    counts = counts + jnp.sum(onehot, axis=0)
                    slots.append(slot)
                    keeps.append(keep)
                # Per-expert lazy dequant: effective weight =
                # diag(input_scale) @ dequant(qweight), one LOCAL expert
                # shard live at a time (lax.map = sequential scan).
                def gateup_one(args):
                    b, g_, u_ = args
                    wg_e = _dequant_block(*g_[:3]) * g_[3][:, None]
                    wu_e = _dequant_block(*u_[:3]) * u_[3][:, None]
                    return activation(cfg.act, b @ wg_e.astype(b.dtype)) \
                        * (b @ wu_e.astype(b.dtype))
                h = jax.lax.map(gateup_one,
                                (buf, (qg, sg, zg, isg),
                                 (qu, su, zu, isu)))          # [E,C,F/m]
                h = jax.lax.all_gather(h, "model", axis=2, tiled=True)

                def down_one(args):
                    hh, d_ = args
                    wd_e = _dequant_block(*d_[:3]) * d_[3][:, None]
                    return hh @ wd_e.astype(hh.dtype)
                out_buf = jax.lax.map(down_one,
                                      (h, (qd, sd, zd, isd)))  # [E,C,D/m]
                y_l = jnp.zeros((tg, out_buf.shape[-1]), xt_l.dtype)
                for j in range(k):
                    got = out_buf[idx_ll[:, j], slots[j]]
                    y_l += jnp.where(keeps[j][:, None], got, 0) \
                        * gates_ll[:, j:j + 1].astype(xt_l.dtype)
                y_l = jax.lax.all_gather(y_l, "model", axis=1, tiled=True)
                return y_l[None]

            wsp = P(None, None, "model")
            y = shard_map(
                body_q, mesh=mesh,
                in_specs=(P(dp), P(dp), P(dp),
                          wsp, wsp, wsp, P(),
                          wsp, wsp, wsp, P(),
                          wsp, wsp, wsp, P()),
                out_specs=P(dp),
                check_vma=False,  # all_gather'd y IS replicated over model
            )(xg, idx, gates,
              pg.qweight, pg.scales, pg.zeros, pg.input_scale,
              pu.qweight, pu.scales, pu.zeros, pu.input_scale,
              pd.qweight, pd.scales, pd.zeros, pd.input_scale)
            y = y.reshape(t, d)
        else:
            wg = _expert_weight(p["experts"], "gate")
            wu = _expert_weight(p["experts"], "up")
            wd = _expert_weight(p["experts"], "down")

            def body(xg_l, idx_l, gates_l, wg_l, wu_l, wd_l):
                y_l = _dispatch_compute_combine(
                    xg_l[0], idx_l[0], gates_l[0],
                    lambda b: _glu_ffn(b, wg_l, wu_l, wd_l, cfg.act),
                    cfg, cap)
                if has_model:
                    y_l = jax.lax.psum(y_l, "model")  # row-parallel psum
                return y_l[None]

            wspec = P(None, None, "model") if has_model else P()
            wspec_d = P(None, "model", None) if has_model else P()
            y = shard_map(
                body, mesh=mesh,
                in_specs=(P(dp), P(dp), P(dp), wspec, wspec, wspec_d),
                out_specs=P(dp),
            )(xg, idx, gates, wg, wu, wd)
            y = y.reshape(t, d)
    else:
        cap = capacity(cfg, t)
        if packed:
            ffn = lambda b: _glu_ffn_packed(p["experts"], b, cfg.act)  # noqa: E731
        else:
            wg = _expert_weight(p["experts"], "gate")
            wu = _expert_weight(p["experts"], "up")
            wd = _expert_weight(p["experts"], "down")
            ffn = lambda b: _glu_ffn(b, wg, wu, wd, cfg.act)  # noqa: E731
        y = _dispatch_compute_combine(xt, idx_t, gates_t, ffn, cfg, cap)

    # shared experts (dense path over every token)
    if "shared" in p:
        sh = p["shared"]
        g = activation(cfg.act, linear(sh["gate"], xt, nm("shared/gate")))
        u2 = linear(sh["up"], xt, nm("shared/up"))
        s_out = linear(sh["down"], g * u2, nm("shared/down"))
        if "shared_gate" in p:
            sg = jax.nn.sigmoid(linear(p["shared_gate"],
                                       xt.astype(jnp.float32)))
            s_out = s_out * sg.astype(s_out.dtype)
        y = y + s_out

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                                 # [E]
    ce = jnp.mean(jax.nn.one_hot(idx_t[:, 0], e, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)
    return y.reshape(*lead, d), aux
