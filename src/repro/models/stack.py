"""Layer-stack executor: scan over segments of identical block kinds.

Uniform stacks (most archs) compile as ONE scanned block regardless of depth;
non-uniform stacks (gemma3's 5:1 local:global, hymba's 3 global layers,
deepseek's first dense layer) break into consecutive-run segments, each
scanned — compile time is O(#segments), not O(#layers), which keeps the
512-device dry-run tractable (DESIGN.md §7).

Calibration mode (`CalibrationCapture` active) switches to an eager python
loop so activation statistics are concrete; capture names follow the
``<param-path>@<layer-idx>`` convention consumed by `core.pipeline`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import calibration
from repro.models import blocks


def seg_name(si: int) -> str:
    return f"seg_{si}"


def stack_init(key, cfg, dtype=jnp.float32):
    """Params: {"seg_0": stacked block params [L0, ...], "seg_1": ...}."""
    segs = cfg.segments()
    keys = jax.random.split(key, len(segs))
    out = {}
    for si, ((kind, n), k) in enumerate(zip(segs, keys)):
        layer_keys = jax.random.split(k, n)
        stacked = jax.vmap(
            lambda kk: blocks.block_init(kk, cfg, kind, dtype))(layer_keys)
        out[seg_name(si)] = stacked
    return out


def stack_init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    out = {}
    for si, (kind, n) in enumerate(cfg.segments()):
        one = blocks.init_block_cache(cfg, kind, batch, max_seq, dtype)
        out[seg_name(si)] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)
    return out


def stack_init_paged_cache(cfg, num_slots: int, num_pages: int,
                           page_size: int, slot_seq: int,
                           dtype=jnp.bfloat16, kv_quant: str | None = None):
    """Paged decode cache: page pools (full attention) + per-slot state."""
    out = {}
    for si, (kind, n) in enumerate(cfg.segments()):
        one = blocks.init_block_cache_paged(cfg, kind, num_slots, num_pages,
                                            page_size, slot_seq, dtype,
                                            kv_quant=kv_quant)
        out[seg_name(si)] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)
    return out


def _take(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def stack_apply(params, x, cfg, *, mode: str, positions, cache=None,
                page_table=None, rpos=None, amask=None):
    """Run all segments. Returns (x, cache_out, aux_loss_sum).

    ``page_table`` ([B, pages_per_slot] int32) is only consulted by paged
    decode caches (``kv_pool`` entries); it is layer-invariant, so the scan
    closes over it rather than scanning it. ``rpos`` ([B, C] logical
    positions) and ``amask`` ([B, C, C] intra-chunk ancestor mask) ride
    the same way for chunk mode (tree-speculation rows); ``None`` keeps
    plain linear-chunk semantics.
    """
    segs = cfg.segments()
    aux_total = jnp.zeros((), jnp.float32)
    cache_out = {} if cache is not None else None

    if calibration.capture_active():
        # eager per-layer loop with capture names
        for si, (kind, n) in enumerate(segs):
            p_seg = params[seg_name(si)]
            c_seg = cache[seg_name(si)] if cache is not None else None
            new_layers = []
            for i in range(n):
                nm = (lambda local, _si=si, _i=i:
                      f"segments/{seg_name(_si)}/{local}@{_i}")
                c_i = _take(c_seg, i) if c_seg is not None else None
                x, c_new, aux = blocks.block_apply(
                    _take(p_seg, i), x, cfg, kind, mode=mode,
                    positions=positions, cache=c_i, name=nm,
                    page_table=page_table, rpos=rpos, amask=amask)
                aux_total += aux
                new_layers.append(c_new)
            if cache_out is not None:
                cache_out[seg_name(si)] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_layers)
        return x, cache_out, aux_total

    for si, (kind, n) in enumerate(segs):
        p_seg = params[seg_name(si)]
        c_seg = cache[seg_name(si)] if cache is not None else None

        def body(carry, xs, _kind=kind):
            xc, aux_c = carry
            p_i, c_i = xs
            xc, c_new, aux = blocks.block_apply(
                p_i, xc, cfg, _kind, mode=mode, positions=positions,
                cache=c_i, page_table=page_table, rpos=rpos, amask=amask)
            return (xc, aux_c + aux), c_new

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)

        (x, aux_total), c_out = jax.lax.scan(
            body, (x, aux_total), (p_seg, c_seg))
        if cache_out is not None:
            cache_out[seg_name(si)] = c_out
    return x, cache_out, aux_total
