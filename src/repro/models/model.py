"""Top-level model: embeddings/frontends + stack + head; train/prefill/decode.

One `Model` class serves all 11 configs (10 assigned + the paper's
qwen2.5-0.5b). Family differences are entirely data-driven:

  * decoder LMs      — token embedding → causal stack → (tied) lm head,
  * encoder (hubert) — stub frame features → `frame_proj` → bidirectional
                       stack → classification head over the codebook vocab,
  * vlm (phi3-v)     — stub patch embeddings → `patch_proj`, prepended to
                       the token embeddings (labels masked over the image
                       span); decode is a plain LM step once prefilled.

The loss is chunked-vocab cross-entropy: logits are materialized
``logits_chunk`` tokens at a time inside a scan, so ``[B, S, V]`` never
exists (gemma's V=256k × 1M-token batch would be ~2 PB in f32).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import constrain
from repro.models import layers, stack
from repro.models.layers import embed_lookup, linear, norm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        k_embed, k_stack, k_head, k_front = jax.random.split(key, 4)
        params: dict = {
            "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                                       dtype),
            "segments": stack.stack_init(k_stack, cfg, dtype),
            "final_norm": layers.norm_init(cfg.d_model,
                                           norm_type=cfg.norm_type,
                                           dtype=dtype,
                                           plus_one=cfg.rms_plus_one),
        }
        if cfg.frontend == "audio":
            params["frontend"] = {"frame_proj": layers.linear_init(
                k_front, cfg.frontend_dim, cfg.d_model, bias=True,
                dtype=dtype)}
        elif cfg.frontend == "vision":
            params["frontend"] = {"patch_proj": layers.linear_init(
                k_front, cfg.frontend_dim, cfg.d_model, bias=True,
                dtype=dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.linear_init(
                k_head, cfg.d_model, cfg.vocab_size, dtype=dtype)
        return params

    # ------------------------------------------------------------ embeddings
    def _embed(self, params, batch: dict) -> tuple[jax.Array, jax.Array,
                                                   jax.Array | None]:
        """→ (x [B,S,D], positions [B,S], labels or None)."""
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        labels = batch.get("labels")
        if cfg.frontend == "audio":
            feats = batch["features"].astype(adt)
            x = linear(params["frontend"]["frame_proj"], feats)
        else:
            x = embed_lookup(params["embed"], batch["tokens"],
                             scale=cfg.scale_embed).astype(adt)
            if cfg.frontend == "vision" and "images" in batch:
                img = linear(params["frontend"]["patch_proj"],
                             batch["images"].astype(adt))
                x = jnp.concatenate([img, x], axis=1)
                if labels is not None:
                    pad = jnp.full(img.shape[:2], -1, labels.dtype)
                    labels = jnp.concatenate([pad, labels], axis=1)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        x = constrain(x, ("batch", None, None))
        return x, positions, labels

    def _head_logits(self, params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.tie_embeddings:
            table = params["embed"]["table"]
            logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                                table.astype(jnp.float32))
        else:
            logits = linear(params["lm_head"],
                            x.astype(jnp.float32))
        return logits  # f32

    # ---------------------------------------------------------------- train
    def loss(self, params, batch: dict) -> tuple[jax.Array, dict]:
        """Chunked-vocab causal-LM / masked-classification loss."""
        cfg = self.cfg
        x, positions, labels = self._embed(params, batch)
        x, _, aux = stack.stack_apply(params["segments"], x, cfg,
                                      mode="train", positions=positions)
        x = norm(params["final_norm"], x, cfg)
        x = constrain(x, ("batch", None, None))

        if labels is None:
            raise ValueError("training batch needs labels")
        b, s, d = x.shape
        chunk = min(cfg.logits_chunk, s)
        if s % chunk:
            chunk = s
        nc = s // chunk
        xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

        def body(carry, xs):
            tot, cnt = carry
            xi, li = xs
            logits = self._head_logits(params, xi)          # [B,c,V] f32
            logits = constrain(logits, ("batch", None, "vocab"))
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, jnp.clip(li, 0)[..., None], axis=-1)[..., 0]
            valid = (li >= 0).astype(jnp.float32)
            tot += jnp.sum((logz - ll) * valid)
            cnt += jnp.sum(valid)
            return (tot, cnt), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xc, lc))
        ce = tot / jnp.maximum(cnt, 1.0)
        return ce + aux, {"ce": ce, "aux": aux, "tokens": cnt}

    # ---------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_seq: int | None = None,
                   dtype=jnp.bfloat16) -> Any:
        cfg = self.cfg
        return stack.stack_init_cache(cfg, batch,
                                      max_seq or cfg.max_seq_len, dtype)

    def init_paged_cache(self, num_slots: int, num_pages: int,
                         page_size: int, slot_seq: int,
                         dtype=jnp.bfloat16,
                         kv_quant: str | None = None) -> Any:
        """Decode cache for the continuous-batching engine (serving/).

        ``kv_quant`` ("none" | "int8" | None = follow ``cfg.kv_quant``)
        selects the page-pool storage regime independently of the model
        config — the serving engine's KV-quantization knob.
        """
        return stack.stack_init_paged_cache(self.cfg, num_slots, num_pages,
                                            page_size, slot_seq, dtype,
                                            kv_quant=kv_quant)

    def prefill(self, params, batch: dict, cache: Any
                ) -> tuple[Any, jax.Array, jax.Array]:
        """Full-sequence prefill → (cache, last-token logits, next pos [B])."""
        cfg = self.cfg
        x, positions, _ = self._embed(params, batch)
        x, cache, _ = stack.stack_apply(params["segments"], x, cfg,
                                        mode="prefill", positions=positions,
                                        cache=cache)
        x = norm(params["final_norm"], x, cfg)
        if cfg.is_encoder:
            logits = self._head_logits(params, x)   # [B, S, V] (tiny V)
            return cache, logits, positions[:, -1] + 1
        logits = self._head_logits(params, x[:, -1])
        return cache, logits, positions[:, -1] + 1

    def decode_step(self, params, cache: Any, token: jax.Array,
                    pos: jax.Array, page_table: jax.Array | None = None
                    ) -> tuple[jax.Array, Any]:
        """One token: token [B] int32, pos [B] → (logits [B, V], cache).

        ``page_table`` [B, pages_per_slot] routes paged-cache reads/writes
        when ``cache`` came from `init_paged_cache`.
        """
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        x = embed_lookup(params["embed"], token,
                         scale=cfg.scale_embed).astype(adt)   # [B, D]
        x, cache, _ = stack.stack_apply(params["segments"], x, cfg,
                                        mode="decode", positions=pos,
                                        cache=cache, page_table=page_table)
        x = norm(params["final_norm"], x, cfg)
        logits = self._head_logits(params, x)
        logits = constrain(logits, ("batch", "vocab"))
        return logits, cache

    def chunk_step(self, params, cache: Any, tokens: jax.Array,
                   pos: jax.Array, sample_idx: jax.Array,
                   page_table: jax.Array,
                   num_logits: int = 1, rpos: jax.Array | None = None,
                   amask: jax.Array | None = None) -> tuple[jax.Array, Any]:
        """One token-budget step: the serving engine's unified
        prefill-chunk + decode dispatch.

        tokens ``[B, C]`` int32 — row b is slot b's contribution (a
        prefill chunk, a variable-length decode/verify token run, a
        speculation tree, or padding); pos ``[B, C]`` absolute KV slot
        positions with ``-1`` padding; sample_idx ``[B]`` — the first
        in-row index whose logits feed sampling (a decode token's
        successor, or the first token when a row's last prompt chunk
        lands); page_table ``[B, pages_per_slot]``. ``num_logits``
        (static) is the number of consecutive in-row positions whose
        logits are materialized, starting at ``sample_idx`` and clipped
        to the row — speculative verify runs need the distribution after
        every draft token, plain decode needs one. ``rpos``/``amask``
        carry the logical positions and intra-chunk ancestor-mask block
        for tree-speculation rows (see `attention.attention_chunk_paged`);
        ``None`` keeps plain linear-chunk semantics. Returns (logits
        [B, V] for ``num_logits == 1`` or [B, num_logits, V] otherwise,
        cache) — the full ``[B, C, V]`` logits are never materialized.

        Only supported for caches whose every entry is a ``kv_pool``
        (full-attention archs, global or sliding-window); see
        `blocks._mixer_chunk`.
        """
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        x = embed_lookup(params["embed"], tokens,
                         scale=cfg.scale_embed).astype(adt)  # [B, C, D]
        # under a serving mesh the embedding table is vocab-sharded; pin
        # the gathered activations replicated before they enter the stack
        # (the block mixers re-shard K/V/heads per their own constraints)
        x = constrain(x, ("batch", None, None))
        x, cache, _ = stack.stack_apply(params["segments"], x, cfg,
                                        mode="chunk", positions=pos,
                                        cache=cache, page_table=page_table,
                                        rpos=rpos, amask=amask)
        x = norm(params["final_norm"], x, cfg)
        c = x.shape[1]
        if num_logits == 1:
            x = jnp.take_along_axis(
                x, sample_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
            logits = self._head_logits(params, x)
            logits = constrain(logits, ("batch", "vocab"))
            return logits, cache
        idx = jnp.clip(sample_idx[:, None].astype(jnp.int32)
                       + jnp.arange(num_logits, dtype=jnp.int32)[None, :],
                       0, c - 1)
        x = jnp.take_along_axis(x, idx[..., None], axis=1)  # [B, R, D]
        logits = self._head_logits(params, x)
        logits = constrain(logits, ("batch", None, "vocab"))
        return logits, cache

    def forward_logits(self, params, batch: dict) -> jax.Array:
        """Full logits [B,S,V] (small models / eval only)."""
        cfg = self.cfg
        x, positions, _ = self._embed(params, batch)
        x, _, _ = stack.stack_apply(params["segments"], x, cfg,
                                    mode="train", positions=positions)
        x = norm(params["final_norm"], x, cfg)
        return self._head_logits(params, x)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
