"""Primitive modules (pure-JAX: init -> nested dict, apply -> array).

The `linear` apply is the framework's single matmul entry point: it
dispatches float weights vs `PackedLinear` (AWQ-quantized) weights, and
records calibration activations when a `CalibrationCapture` is active — this
is how the paper's fully-automated PTQ flow hooks every projection in every
architecture without per-model code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import calibration
from repro.core.packing import PackedLinear
from repro.core.qlinear import qlinear_apply


# ---------------------------------------------------------------------- init

def linear_init(key, k: int, n: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(k))
    p = {"w": (jax.random.normal(key, (k, n)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


def norm_init(d: int, *, norm_type: str = "rmsnorm", dtype=jnp.float32,
              plus_one: bool = False):
    gamma = jnp.zeros((d,), dtype) if plus_one else jnp.ones((d,), dtype)
    p = {"gamma": gamma}
    if norm_type == "layernorm":
        p["beta"] = jnp.zeros((d,), dtype)
    return p


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


# --------------------------------------------------------------------- apply

def linear(p, x: jax.Array, name: str | None = None) -> jax.Array:
    """``y = x @ w (+ b)`` with quantized dispatch + calibration capture."""
    if isinstance(p, PackedLinear):
        lead = x.shape[:-1]
        y = qlinear_apply(p, x.reshape(-1, x.shape[-1]))
        return y.reshape(*lead, y.shape[-1])
    calibration.record_linear_input(name, x)
    w = p["w"]
    y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32)
    y = y.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm(p, x: jax.Array, *, eps: float = 1e-6,
            plus_one: bool = False) -> jax.Array:
    """RMSNorm in f32 (the paper's PS-side non-linear op — VPU territory)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    g = p["gamma"].astype(jnp.float32)
    if plus_one:
        g = 1.0 + g
    return (xf * g).astype(dt)


def layernorm(p, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["gamma"].astype(jnp.float32)
            + p["beta"].astype(jnp.float32)).astype(dt)


def norm(p, x: jax.Array, cfg) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layernorm(p, x, eps=cfg.norm_eps)
    return rmsnorm(p, x, eps=cfg.norm_eps, plus_one=cfg.rms_plus_one)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name}")


def embed_lookup(p, tokens: jax.Array, *, scale: bool = False) -> jax.Array:
    table = p["table"]
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.sqrt(jnp.asarray(table.shape[-1], x.dtype))
    return x


# ---------------------------------------------------------------------- RoPE

def rope_cos_sin(positions: jax.Array, rot_dim: int, theta: float,
                 dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables ``[..., rot_dim/2]`` for integer positions."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rot_dim: int) -> jax.Array:
    """Rotate the first ``rot_dim`` channels of ``x [..., H, hd]``.

    cos/sin are [..., rot_dim/2] broadcast over the head axis. Partial rotary
    (glm4: rot_dim = hd/2) leaves the tail channels untouched.
    """
    half = rot_dim // 2
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., :half], xr[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    if rot_dim < x.shape[-1]:
        out = jnp.concatenate([out, xp], axis=-1)
    return out
