"""Transformer block assembly: mixer (attn/MLA/mamba/hymba) + MLP (glu/plain/
moe), pre-norm residual wiring, per-kind decode caches.

`block_apply` is mode-polymorphic:
  * mode="train"   — full-sequence forward, no cache.
  * mode="prefill" — full-sequence forward, returns a populated decode cache.
  * mode="decode"  — single token [B, D], consumes + returns the cache.
  * mode="chunk"   — token-budget block [B, C, D] against the paged cache
    (serving's unified prefill/decode step): every row is one slot's
    prefill chunk or decode token, positions carry ``-1`` padding. Only
    pure paged-attention blocks support it — bounded per-slot state
    (sliding-window rings, SSM recurrences, MLA latents) is inherently
    sequential per token and stays on the one-shot prefill path.

Hymba (arXiv:2411.13676) blocks run attention and the Mamba2 SSD branch in
parallel on the same normed input, each branch output re-normalized then
averaged — the paper's "parallel attn∥SSM heads" fusion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LayerKind
from repro.models import attention as attn_mod
from repro.models import layers, mla as mla_mod, moe as moe_mod, ssm as ssm_mod
from repro.models.layers import activation, linear, norm


# ---------------------------------------------------------------------- init

def mlp_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {"up": layers.linear_init(ks[1], d, f, dtype=dtype),
         "down": layers.linear_init(ks[2], f, d, dtype=dtype)}
    if cfg.mlp_type == "glu":
        p["gate"] = layers.linear_init(ks[0], d, f, dtype=dtype)
    return p


def block_init(key, cfg, kind: LayerKind, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p: dict = {"pre_norm": layers.norm_init(
        cfg.d_model, norm_type=cfg.norm_type, dtype=dtype,
        plus_one=cfg.rms_plus_one)}
    if kind.mixer == "attn":
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    elif kind.mixer == "mla":
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    elif kind.mixer == "mamba":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
    elif kind.mixer == "hymba":
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg, dtype)
        p["attn_out_norm"] = layers.norm_init(cfg.d_model,
                                              norm_type=cfg.norm_type,
                                              dtype=dtype,
                                              plus_one=cfg.rms_plus_one)
        p["ssm_out_norm"] = layers.norm_init(cfg.d_model,
                                             norm_type=cfg.norm_type,
                                             dtype=dtype,
                                             plus_one=cfg.rms_plus_one)
    if kind.mlp != "none":
        p["mlp_norm"] = layers.norm_init(cfg.d_model, norm_type=cfg.norm_type,
                                         dtype=dtype,
                                         plus_one=cfg.rms_plus_one)
        if kind.mlp == "moe":
            p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[2], cfg, dtype)
    return p


def init_block_cache(cfg, kind: LayerKind, batch: int, max_seq: int,
                     dtype=jnp.bfloat16):
    c: dict = {}
    if kind.mixer in ("attn", "hymba"):
        c["kv"] = attn_mod.init_kv_cache(cfg, batch, max_seq, kind.window,
                                         dtype)
    if kind.mixer == "mla":
        c["mla"] = mla_mod.init_mla_cache(cfg, batch, max_seq, dtype)
    if kind.mixer in ("mamba", "hymba"):
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, batch)
    return c


def init_block_cache_paged(cfg, kind: LayerKind, num_slots: int,
                           num_pages: int, page_size: int, slot_seq: int,
                           dtype=jnp.bfloat16, kv_quant: str | None = None):
    """Per-layer decode cache for the continuous-batching engine.

    Unbounded full-attention KV goes into a shared **page pool** (key
    ``kv_pool``; read/written through per-slot page tables). Bounded state —
    sliding-window rings, SSM states, MLA latents — stays dense with the
    slot index as the batch dim, since its footprint is fixed per slot.
    ``slot_seq`` is the per-slot capacity (pages_per_slot × page_size).
    ``kv_quant`` overrides ``cfg.kv_quant`` for the page pools only (the
    engine's serving-scale KV quantization knob); bounded dense state
    keeps the config's regime.
    """
    c: dict = {}
    if kind.mixer == "attn":
        # sliding-window layers share the page pools with global layers:
        # the paged read masks positions that slid out of the window (the
        # mask, not eviction, enforces locality), so windowed archs ride
        # the chunked serving path — mesh, spec decode, preemption — with
        # no dense ring special case
        c["kv_pool"] = attn_mod.init_paged_kv_cache(
            cfg, num_pages, page_size, dtype, kv_quant=kv_quant)
    elif kind.mixer == "hymba":
        # hymba keeps per-slot SSM state → one-shot path; its windowed
        # attention branch keeps the dense ring alongside
        if kind.window:
            c["kv"] = attn_mod.init_kv_cache(cfg, num_slots, slot_seq,
                                             kind.window, dtype)
        else:
            c["kv_pool"] = attn_mod.init_paged_kv_cache(
                cfg, num_pages, page_size, dtype, kv_quant=kv_quant)
    if kind.mixer == "mla":
        c["mla"] = mla_mod.init_mla_cache(cfg, num_slots, slot_seq, dtype)
    if kind.mixer in ("mamba", "hymba"):
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, num_slots)
    return c


# --------------------------------------------------------------------- apply

def _mixer_train(p, x, cfg, kind: LayerKind, positions, name):
    causal = not cfg.is_encoder
    if kind.mixer == "attn":
        sub = (lambda s: name(f"attn/{s}")) if name else None
        return attn_mod.attention(p["attn"], x, cfg, positions=positions,
                                  window=kind.window, causal=causal,
                                  name=sub)
    if kind.mixer == "mla":
        sub = (lambda s: name(f"attn/{s}")) if name else None
        return mla_mod.mla_attention(p["attn"], x, cfg, positions=positions,
                                     name=sub)
    if kind.mixer == "mamba":
        sub = (lambda s: name(f"ssm/{s}")) if name else None
        return ssm_mod.ssm_mixer(p["ssm"], x, cfg, name=sub)
    if kind.mixer == "hymba":
        sub_a = (lambda s: name(f"attn/{s}")) if name else None
        sub_s = (lambda s: name(f"ssm/{s}")) if name else None
        ya = attn_mod.attention(p["attn"], x, cfg, positions=positions,
                                window=kind.window, causal=causal,
                                name=sub_a)
        ys = ssm_mod.ssm_mixer(p["ssm"], x, cfg, name=sub_s)
        ya = norm(p["attn_out_norm"], ya, cfg)
        ys = norm(p["ssm_out_norm"], ys, cfg)
        return (ya + ys) * 0.5
    raise ValueError(kind.mixer)


def _attn_decode(p, cache, x, cfg, kind: LayerKind, pos, page_table):
    """Dispatch dense/ring vs. paged full-attention decode by cache key."""
    if "kv_pool" in cache:
        y, pool = attn_mod.attention_decode_paged(p["attn"], cache["kv_pool"],
                                                  page_table, x, cfg, pos=pos,
                                                  window=kind.window)
        return y, ("kv_pool", pool)
    y, kv = attn_mod.attention_decode(p["attn"], cache["kv"], x, cfg,
                                      pos=pos, window=kind.window)
    return y, ("kv", kv)


def _mixer_chunk(p, cache, x, cfg, kind: LayerKind, pos, name, page_table,
                 rpos=None, amask=None):
    """Chunked (multi-token) mixer step — paged attention only (global or
    sliding-window; locality comes from the masked read)."""
    if kind.mixer != "attn" or "kv_pool" not in cache:
        raise ValueError(
            f"chunked execution needs a pure paged-attention cache; "
            f"{kind.tag!r} keeps per-slot sequential state — serve it "
            f"through the one-shot prefill path")
    sub = (lambda s: name(f"attn/{s}")) if name else None
    y, pool = attn_mod.attention_chunk_paged(p["attn"], cache["kv_pool"],
                                             page_table, x, cfg, pos=pos,
                                             rpos=rpos, amask=amask,
                                             window=kind.window, name=sub)
    return y, {"kv_pool": pool}


def _mixer_decode(p, cache, x, cfg, kind: LayerKind, pos, name,
                  page_table=None):
    if kind.mixer == "attn":
        y, (ck, kv) = _attn_decode(p, cache, x, cfg, kind, pos, page_table)
        return y, {ck: kv}
    if kind.mixer == "mla":
        y, mc = mla_mod.mla_decode(p["attn"], cache["mla"], x, cfg, pos=pos)
        return y, {"mla": mc}
    if kind.mixer == "mamba":
        y, sc = ssm_mod.ssm_decode(p["ssm"], cache["ssm"], x, cfg)
        return y, {"ssm": sc}
    if kind.mixer == "hymba":
        ya, (ck, kv) = _attn_decode(p, cache, x, cfg, kind, pos, page_table)
        ys, sc = ssm_mod.ssm_decode(p["ssm"], cache["ssm"], x, cfg)
        ya = norm(p["attn_out_norm"], ya, cfg)
        ys = norm(p["ssm_out_norm"], ys, cfg)
        return (ya + ys) * 0.5, {ck: kv, "ssm": sc}
    raise ValueError(kind.mixer)


def _mlp_apply(p, x, cfg, kind: LayerKind, name):
    if kind.mlp == "moe":
        sub = (lambda s: name(f"moe/{s}")) if name else None
        return moe_mod.moe_apply(p["moe"], x, cfg, name=sub)
    mp = p["mlp"]
    nm = (lambda s: name(f"mlp/{s}")) if name else (lambda s: None)
    if kind.mlp == "glu":
        h = activation(cfg.act, linear(mp["gate"], x, nm("gate"))) \
            * linear(mp["up"], x, nm("up"))
    else:  # plain
        h = activation(cfg.act, linear(mp["up"], x, nm("up")))
    return linear(mp["down"], h, nm("down")), jnp.zeros((), jnp.float32)


def block_apply(p, x, cfg, kind: LayerKind, *, mode: str, positions=None,
                cache=None, name=None, page_table=None, rpos=None,
                amask=None):
    """Returns (x_out, cache_out, aux_loss). name: callable local→str or None."""
    h = norm(p["pre_norm"], x, cfg)
    if mode == "decode":
        y, cache = _mixer_decode(p, cache, h, cfg, kind, positions, name,
                                 page_table)
    elif mode == "chunk":
        y, cache = _mixer_chunk(p, cache, h, cfg, kind, positions, name,
                                page_table, rpos, amask)
    else:
        y = _mixer_train(p, h, cfg, kind, positions, name)
        if mode == "prefill" and kind.mixer in ("attn", "mla", "hymba"):
            cache = _prefill_cache(p, h, cfg, kind, positions, cache)
        if mode == "prefill" and kind.mixer in ("mamba", "hymba"):
            cache = _prefill_ssm_cache(p, h, cfg, kind, cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if kind.mlp != "none":
        h2 = norm(p["mlp_norm"], x, cfg)
        y2, aux = _mlp_apply(p, h2, cfg, kind, name)
        x = x + y2
    return x, cache, aux


# ------------------------------------------------------------ prefill caches

def _prefill_cache(p, h, cfg, kind, positions, cache):
    """Recompute K/V (or latent) for the prefilled tokens and fill the cache."""
    cache = dict(cache or {})
    if kind.mixer == "mla":
        c, k_pe = mla_mod._project_latent(p["attn"], h, cfg, positions, None)
        cache["mla"] = mla_mod.fill_mla_cache_from_prefill(
            cache["mla"], c, k_pe)
        return cache
    _, k, v = attn_mod._project_qkv(p["attn"], h, cfg, positions,
                                    kind.window, None)
    cache["kv"] = attn_mod.fill_cache_from_prefill(cache["kv"], k, v,
                                                   positions, kind.window)
    return cache


def _prefill_ssm_cache(p, h, cfg, kind, cache):
    """Run the SSD recurrence over the prefill to the final state.

    Reuses the chunked state computation: final state = scan carry after the
    last chunk; conv caches take the last (d_conv-1) pre-conv inputs.
    """
    cache = dict(cache or {})
    nm = None
    b, s, _ = h.shape
    sp = p["ssm"]
    dc = cfg.ssm_conv
    ux = linear(sp["wx"], h)
    ub = linear(sp["wb"], h)
    uc = linear(sp["wc"], h)
    old = cache["ssm"]
    conv_x = ux[:, -(dc - 1):, :].astype(old["conv_x"].dtype) if s >= dc - 1 \
        else old["conv_x"]
    conv_b = ub[:, -(dc - 1):, :].astype(old["conv_b"].dtype) if s >= dc - 1 \
        else old["conv_b"]
    conv_c = uc[:, -(dc - 1):, :].astype(old["conv_c"].dtype) if s >= dc - 1 \
        else old["conv_c"]

    # final SSM state via the same chunked recurrence
    x = _ssm_final_state(sp, h, ux, cfg)
    return {**cache, "ssm": {"conv_x": conv_x, "conv_b": conv_b,
                             "conv_c": conv_c, "state": x}}


def _ssm_final_state(sp, h, ux, cfg):
    b, s, _ = h.shape
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    hd, ng = cfg.ssm_headdim, cfg.ssm_ngroups
    x = ssm_mod._causal_conv(ux, sp["conv_x"])
    bb = ssm_mod._causal_conv(linear(sp["wb"], h), sp["conv_b"])
    dt = jax.nn.softplus(linear(sp["wdt"], h).astype(jnp.float32)
                         + sp["dt_bias"][None, None, :])
    xh = x.reshape(b, s, nh, hd).astype(jnp.float32)
    rep = nh // ng
    bh = jnp.repeat(bb.reshape(b, s, ng, ds).astype(jnp.float32), rep, axis=2)
    a = -jnp.exp(sp["a_log"])[None, None, :]
    da = dt * a
    seg = jnp.cumsum(da, axis=1)                       # [B,S,nh]
    decay_to_end = jnp.exp(seg[:, -1:, :] - seg)       # [B,S,nh]
    state = jnp.einsum("bjhs,bjh,bjhd->bhds", bh, dt * decay_to_end, xh)
    return state
