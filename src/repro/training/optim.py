"""AdamW in pure JAX (no optax in this container) with ZeRO-1 sharded moments.

Moments live in f32 regardless of param dtype; `zero1_pspec` in
`distributed.sharding` additionally shards them over the data axis on top of
the param's TP sharding, which is what makes the optimizer state scale to
the production mesh (moments are 2× params — the largest training tensor
after activations).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay → floor."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        gnorm


def adamw_update(params, grads, opt, step: jax.Array, cfg: AdamWConfig):
    """One AdamW step. grads may be bf16 (compressed DP reduce) — promoted
    here; params stay in their master dtype."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a); new_m.append(b); new_v.append(c)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v)},
            {"grad_norm": gnorm, "lr": lr})
