"""Data-parallel train step with explicit int8+EF gradient reduction.

The main train path (`training/train_step.py`) lets XLA insert the DP
all-reduce (bf16 via `grad_comm_dtype`). This variant makes the reduction
EXPLICIT so it can be compressed below bf16 — the pattern intended for the
cross-pod `pod` axis where DCI bandwidth, not ICI, bounds the collective
term (DESIGN.md §5):

  * the whole step runs under `shard_map` over the DP axes,
  * each shard computes grads on its micro-batch,
  * grads cross the wire as int8 codes + one f32 scale per tensor
    (`distributed.compression.int8_psum_mean`), error feedback carries the
    residual to the next step,
  * AdamW applies the reduced gradient identically on every shard
    (replicated params, deterministic),
  * the EF residual is genuinely per-worker state: it carries an explicit
    leading DP dim sharded over the mesh (never falsely "replicated").

Tested against the uncompressed reduction on an 8-device mesh
(tests/test_compression.py): descent parity within tolerance, EF bounded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import shard_map
from repro.distributed.compression import int8_psum_mean
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


def dp_degree(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def init_dp_state(model, key, mesh) -> tuple[dict, dict]:
    """→ (replicated train state, per-shard EF residuals [n_dp, ...])."""
    params = model.init(key)
    n = dp_degree(mesh)
    ef = jax.tree.map(lambda p: jnp.zeros((n,) + p.shape, jnp.float32),
                      params)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    return state, ef


def make_dp_train_step(model, mesh, opt_cfg: AdamWConfig,
                       compress: bool = True):
    """Returns jit'd ``step(state, ef, batch) -> (state, ef, metrics)``."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def shard_body(state, ef, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        loss = jax.lax.pmean(loss, dp_axes)

        if compress:
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(ef)
            red, new_e = [], []
            for g, e in zip(flat_g, flat_e):
                r, ne = int8_psum_mean(g, e[0], dp_axes)
                red.append(r)
                new_e.append(ne[None])
            grads = jax.tree.unflatten(tdef, red)
            ef = jax.tree.unflatten(tdef, new_e)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axes), grads)

        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], state["step"], opt_cfg)
        new_state = {"params": params, "opt": opt,
                     "step": state["step"] + 1}
        return new_state, ef, {"loss": loss, **opt_metrics}

    step = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(), P(dp_axes), P(dp_axes)),
        out_specs=(P(), P(dp_axes), P()),
        check_vma=False,
    )
    return jax.jit(step)
