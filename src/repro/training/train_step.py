"""jit-able train step: mixed-precision backward, optional bf16 gradient
communication, AdamW, metrics.

Distributed-optimization knobs (DESIGN.md §5):
  * ``grad_comm_dtype="bfloat16"`` — params are cast to bf16 *before* the
    loss, so backward (and therefore the implicit DP gradient all-reduce XLA
    emits over the pod/data axes) runs on bf16 tensors: half the gradient
    collective bytes. The f32 master copy lives only in the optimizer. The
    dry-run's collective-bytes parser sees this directly.
  * activation remat — per-block `jax.checkpoint` (models/stack.py).
  * ZeRO-1 — moment sharding handled by the caller via
    `sharding.zero1_pspec` out_shardings.
  * compute/comm overlap — XLA latency-hiding scheduler; we keep the loss a
    single fused graph (no host sync points) so the scheduler can overlap
    the gradient all-reduce of layer i with the backward of layer i-1.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.training.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_comm_dtype: str = "bfloat16"   # "float32" to disable compression


def init_train_state(model, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(model) -> dict:
    """ShapeDtypeStruct train state (for dry-run / checkpoint templates)."""
    return jax.eval_shape(lambda: init_train_state(model,
                                                   jax.random.PRNGKey(0)))


def make_train_step(model, tcfg: TrainConfig) -> Callable[[dict, dict],
                                                          tuple[dict, dict]]:
    comm_dtype = jnp.dtype(tcfg.grad_comm_dtype)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]

        def loss_fn(p):
            if comm_dtype != jnp.float32:
                # bf16 params ⇒ bf16 grads ⇒ bf16 DP all-reduce
                p = jax.tree.map(
                    lambda a: a.astype(comm_dtype)
                    if a.dtype == jnp.float32 and a.ndim >= 2 else a, p)
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], state["step"], tcfg.optimizer)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step
