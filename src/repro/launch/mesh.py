"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the 512-device dry-run sets XLA_FLAGS before
any jax import, and smoke tests keep their single real device.

Mesh semantics (DESIGN.md §5): DP spans pod×data, TP spans model. The `pod`
axis exists so the multi-pod dry-run proves gradient all-reduce shards over
the cross-pod (DCI) boundary; serving uses pods as independent replicas.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under launch/dryrun.py (sets xla_force_host_platform_device_"
            "count) or on real hardware")
    # more devices than the mesh (e.g. 512 placeholders, single-pod 256):
    # take a prefix — placement is irrelevant for lowering/compile analysis.
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over the locally available devices (tests, examples)."""
    dev = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(dev, ("data", "model"))
