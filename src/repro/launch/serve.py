"""Serving launcher: AWQ-quantize a model and serve batched requests.

The end-to-end path of the paper (§III-A "fully automated"): init (or load)
float params → calibration forward → AWQ search + pack (GS=64 INT4) → serve
with the fused dequant-matmul path. ``--quant none`` serves the float
baseline (the paper's 2.8 tok/s side of Table III).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25-05b --smoke \
      --batch 4 --prompt-len 32 --max-new 32 --quant awq
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import (AWQConfig, CalibrationCapture, QuantConfig,
                        quantize_params)
from repro.core.pipeline import model_size_bytes
from repro.data import make_dataset
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25-05b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="awq", choices=["awq", "none"])
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: fp16-serialized size "
          f"{model_size_bytes(params, quantized=False)/1e6:.2f} MB")

    if args.quant == "awq":
        ds = make_dataset(cfg, 2, min(64, cfg.max_seq_len), seed=123)
        calib = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
        t0 = time.time()
        with CalibrationCapture() as cap:
            model.loss(params, calib)
        qcfg = AWQConfig(quant=QuantConfig(group_size=args.group_size))
        params, report = quantize_params(params, cap.stats, qcfg)
        print(f"[serve] AWQ PTQ in {time.time()-t0:.1f}s: "
              f"{len(report.quantized)} linears quantized "
              f"({len(report.calibrated)} calibrated), "
              f"{len(report.skipped)} kept FP")
        print(f"[serve] AWQ_MACRO-serialized size "
              f"{model_size_bytes(params, quantized=True)/1e6:.2f} MB")

    engine = GenerationEngine(
        model, params, max_seq=args.prompt_len + args.max_new,
        sampler=SamplerConfig(temperature=args.temperature))
    ds = make_dataset(cfg, args.batch, args.prompt_len, seed=args.seed)
    prompt = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"])}

    t0 = time.time()
    out = engine.generate(prompt, args.max_new)
    dt = time.time() - t0
    tput = out.size / dt
    print(f"[serve] generated {out.shape} tokens in {dt:.2f}s "
          f"({tput:.1f} tok/s wall on {jax.default_backend()})")
    print(f"[serve] sample: {out[0][:16].tolist()}")
    return {"tokens_per_s": tput, "shape": list(out.shape)}


if __name__ == "__main__":
    main()
