"""Serving launcher: AWQ-quantize a model and serve batched requests.

The end-to-end path of the paper (§III-A "fully automated"): init (or load)
float params → calibration forward → AWQ search + pack (GS=64 INT4) → serve
with the fused dequant-matmul path. ``--quant none`` serves the float
baseline (the paper's 2.8 tok/s side of Table III).

With ``--replicas N`` (or any fleet flag) the launcher serves a
continuous-batching **fleet** instead: N `GenerationEngine` replicas —
each ``--mesh-axis``-wide TP, or ``--disagg`` prefill/decode pairs —
behind the prefix-affinity `serving.Router`, built declaratively from
`launch.specs.FleetSpec` (the k8s-style deployment description:
replica count, per-replica mesh shape, drain timeout).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25-05b --smoke \
      --batch 4 --prompt-len 32 --max-new 32 --quant awq
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25-05b --smoke \
      --replicas 2 --mesh-axis 1 --quant none
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import (AWQConfig, CalibrationCapture, QuantConfig,
                        quantize_params)
from repro.core.pipeline import model_size_bytes
from repro.data import make_dataset
from repro.launch.specs import FleetSpec, ReplicaSpec
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25-05b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="awq", choices=["awq", "none"])
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # fleet flags (k8s-style: scale + pod template + drain budget)
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve a Router fleet of N replicas instead of "
                         "one static-batch engine (0 = classic path)")
    ap.add_argument("--mesh-axis", type=int, default=1,
                    help="per-replica tensor-parallel 'model' axis width")
    ap.add_argument("--disagg", action="store_true",
                    help="each replica is a prefill/decode engine pair")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="drain_replica step budget (seconds) for elastic "
                         "scale-down")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: fp16-serialized size "
          f"{model_size_bytes(params, quantized=False)/1e6:.2f} MB")

    if args.quant == "awq":
        ds = make_dataset(cfg, 2, min(64, cfg.max_seq_len), seed=123)
        calib = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
        t0 = time.time()
        with CalibrationCapture() as cap:
            model.loss(params, calib)
        qcfg = AWQConfig(quant=QuantConfig(group_size=args.group_size))
        params, report = quantize_params(params, cap.stats, qcfg)
        print(f"[serve] AWQ PTQ in {time.time()-t0:.1f}s: "
              f"{len(report.quantized)} linears quantized "
              f"({len(report.calibrated)} calibrated), "
              f"{len(report.skipped)} kept FP")
        print(f"[serve] AWQ_MACRO-serialized size "
              f"{model_size_bytes(params, quantized=True)/1e6:.2f} MB")

    if args.replicas > 0:
        return serve_fleet(model, params, args)

    engine = GenerationEngine(
        model, params, max_seq=args.prompt_len + args.max_new,
        sampler=SamplerConfig(temperature=args.temperature))
    ds = make_dataset(cfg, args.batch, args.prompt_len, seed=args.seed)
    prompt = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"])}

    t0 = time.time()
    out = engine.generate(prompt, args.max_new)
    dt = time.time() - t0
    tput = out.size / dt
    print(f"[serve] generated {out.shape} tokens in {dt:.2f}s "
          f"({tput:.1f} tok/s wall on {jax.default_backend()})")
    print(f"[serve] sample: {out[0][:16].tolist()}")
    return {"tokens_per_s": tput, "shape": list(out.shape)}


def serve_fleet(model, params, args) -> dict:
    """Continuous-batching fleet: FleetSpec → Router → clustered burst.

    The burst shares one system prefix per cluster so the router's
    prefix-affinity scoring has something to aim at; the report prints
    per-replica prefill-skip and queue-depth so placement is visible.
    """
    cfg = model.cfg
    max_seq = args.prompt_len + args.max_new
    page = 8
    spec = FleetSpec(
        replicas=args.replicas,
        replica=ReplicaSpec(
            mesh_axis=args.mesh_axis, disagg=args.disagg,
            prefill_mesh_axis=args.mesh_axis,
            decode_mesh_axis=args.mesh_axis,
            engine_kwargs=dict(max_seq=max_seq, num_slots=args.batch,
                               page_size=page, prefill_chunk=page)),
        drain_timeout_s=args.drain_timeout)
    print(f"[serve] fleet: {spec.replicas} replica(s), mesh_axis="
          f"{args.mesh_axis}, disagg={args.disagg}, "
          f"drain_timeout={spec.drain_timeout_s:.0f}s")
    router = spec.build(model, params)
    router.warmup()

    rng = np.random.default_rng(args.seed)
    n_clusters = 2
    prefixes = [rng.integers(0, cfg.vocab_size, (args.prompt_len - 4,)
                             ).astype(np.int32) for _ in range(n_clusters)]
    # pin first (sticky), then warm one request per cluster so the burst
    # below has resident prefixes to route toward
    for c in range(n_clusters):
        router.pin_prefix(f"sys{c}")
        router.submit(np.concatenate(
            [prefixes[c],
             rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)]),
            2, prefix_id=f"sys{c}")
    router.drain()
    n_req = max(args.batch * args.replicas, 4)
    rids = []
    t0 = time.time()
    for i in range(n_req):
        c = i % n_clusters
        tail = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
        rids.append(router.submit(
            np.concatenate([prefixes[c], tail]), args.max_new,
            sampler=SamplerConfig(temperature=args.temperature),
            prefix_id=f"sys{c}", session_id=f"user{i % (2 * n_clusters)}"))
    out = router.drain()
    dt = time.time() - t0
    useful = sum(len(out[r]) for r in rids)
    tput = useful / dt
    skipped = sum(getattr(s, "prefill_tokens_skipped", 0)
                  for s in router.stats())  # DisaggStats has no such field
    print(f"[serve] fleet served {n_req} requests / {useful} tokens in "
          f"{dt:.2f}s ({tput:.1f} tok/s wall on {jax.default_backend()})")
    print(f"[serve] placement: {router.router_stats.placements} scored, "
          f"{router.router_stats.affinity_hits} affinity hits, "
          f"{router.router_stats.session_hits} session hits, "
          f"{skipped} prefill tokens skipped fleet-wide")
    return {"tokens_per_s": tput, "requests": n_req,
            "prefill_tokens_skipped": int(skipped),
            "replicas": args.replicas}


if __name__ == "__main__":
    main()
