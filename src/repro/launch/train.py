"""Training launcher: mesh-sharded train loop with fault tolerance.

Features exercised end-to-end (CPU-scale with smoke configs; the same code
path drives the production mesh):
  * pjit train step with param/ZeRO-1/batch shardings,
  * async atomic checkpointing + exact resume (pure-function data pipeline),
  * node-failure recovery: any step exception reloads the latest checkpoint
    and continues (``--simulate-failure-at`` injects one for testing),
  * straggler watchdog: per-step wall-clock vs running median; slow steps
    are logged with the step payload so an external scheduler can
    re-dispatch (single-process stand-in for the real-fleet mitigation).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen25-05b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data import make_dataset
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, make_train_step
from repro.training.train_step import init_train_state


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25-05b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = build_model(cfg)
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=args.lr, warmup_steps=args.warmup, decay_steps=args.steps,
        weight_decay=0.0))
    ds = make_dataset(cfg, args.batch, args.seq, args.seed)

    with shd.use_mesh(mesh):
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        state = init_train_state(model, jax.random.PRNGKey(args.seed))
        start = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = AsyncCheckpointer(args.ckpt_dir)
            if latest_step(args.ckpt_dir) is not None:
                tpl = jax.eval_shape(lambda: init_train_state(
                    model, jax.random.PRNGKey(args.seed)))
                state, start = restore(args.ckpt_dir, tpl)
                print(f"[train] resumed from step {start}")

        losses, times = [], []
        i = start
        failed_once = False
        while i < args.steps:
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
            t0 = time.time()
            try:
                if i == args.simulate_failure_at and not failed_once:
                    failed_once = True
                    raise RuntimeError("simulated node failure")
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception as e:  # node-failure path: reload + retry
                print(f"[train] step {i} failed ({e}); recovering from "
                      "latest checkpoint")
                if ckpt is None or latest_step(args.ckpt_dir) is None:
                    state = init_train_state(model,
                                             jax.random.PRNGKey(args.seed))
                    i = 0
                else:
                    ckpt.wait()
                    tpl = jax.eval_shape(lambda: init_train_state(
                        model, jax.random.PRNGKey(args.seed)))
                    state, i = restore(args.ckpt_dir, tpl)
                continue
            dt = time.time() - t0
            times.append(dt)
            if len(times) >= 5:
                med = statistics.median(times[-50:])
                if dt > args.straggler_factor * med:
                    print(f"[train] STRAGGLER step {i}: {dt:.3f}s vs median "
                          f"{med:.3f}s — flagged for re-dispatch")
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0:
                print(f"[train] step {i} loss={losses[-1]:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms")
            i += 1
            if ckpt and (i % args.ckpt_every == 0 or i == args.steps):
                ckpt.save(i, state)
        if ckpt:
            ckpt.close()
    print(f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f}")
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": len(losses)}


if __name__ == "__main__":
    main()
