"""ShapeDtypeStruct stand-ins for every (arch × shape-cell) input.

Nothing here allocates: params/optimizer/caches/batches are all
`jax.eval_shape`-derived structs with NamedShardings attached, which is what
lets the dry-run lower+compile 9B-param models on a CPU container
(DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeCell
from repro.configs.base import ModelConfig
from repro.core.awq import AWQConfig
from repro.core.pipeline import quantize_params
from repro.core.quantize import QuantConfig
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.training.train_step import init_train_state


def _sds(tree: Any, shardings: Any) -> Any:
    """Attach shardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> dict:
    """Global-batch input ShapeDtypeStructs for one shape cell."""
    b, s = cell.global_batch, cell.seq_len
    bp = NamedSharding(mesh, shd._resolve(mesh, ("batch", None), (b, s)))
    out: dict = {}
    if cfg.frontend == "audio":
        fshape = (b, s, cfg.frontend_dim)
        fsh = NamedSharding(mesh, shd._resolve(mesh, ("batch", None, None),
                                               fshape))
        out["features"] = jax.ShapeDtypeStruct(fshape, jnp.float32,
                                               sharding=fsh)
    else:
        if cfg.frontend == "vision":
            s_text = s - cfg.num_patches  # image span + text = cell seq_len
            ishape = (b, cfg.num_patches, cfg.frontend_dim)
            ish = NamedSharding(mesh, shd._resolve(
                mesh, ("batch", None, None), ishape))
            out["images"] = jax.ShapeDtypeStruct(ishape, jnp.float32,
                                                 sharding=ish)
        else:
            s_text = s
        tp = NamedSharding(mesh, shd._resolve(mesh, ("batch", None),
                                              (b, s_text)))
        out["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32,
                                             sharding=tp)
    if cell.step == "train" or cfg.is_encoder:
        lshape = (b, s if cfg.frontend != "vision" else s_text)
        lsh = NamedSharding(mesh, shd._resolve(mesh, ("batch", None), lshape))
        out["labels"] = jax.ShapeDtypeStruct(lshape, jnp.int32, sharding=lsh)
    if cell.step != "train":
        out.pop("labels", None)
    return out


def param_specs(cfg: ModelConfig, mesh: Mesh, quant: bool) -> Any:
    model = build_model(cfg)
    p_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if quant:
        qcfg = AWQConfig(quant=QuantConfig(group_size=64))
        p_shapes = jax.eval_shape(
            lambda: quantize_params(
                jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype),
                             p_shapes), None, qcfg)[0])
    shardings = shd.make_sharding(p_shapes, mesh, shd.param_pspec, cfg)
    return _sds(p_shapes, shardings)


def train_state_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    model = build_model(cfg)
    st = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))
    p_sh = shd.pspec_tree(st["params"], mesh, shd.param_pspec, cfg)
    m_sh = jax.tree.map(
        lambda spec, leaf: shd.zero1_pspec(spec, leaf.shape, mesh),
        p_sh, st["params"], is_leaf=lambda x: isinstance(x, P))
    specs = {"params": p_sh, "opt": {"m": m_sh, "v": m_sh}, "step": P()}
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return _sds(st, shardings), shardings


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                max_seq: int) -> Any:
    model = build_model(cfg)
    c_shapes = jax.eval_shape(
        lambda: model.init_cache(batch, max_seq))
    shardings = shd.make_sharding(c_shapes, mesh, shd.cache_pspec, cfg)
    return _sds(c_shapes, shardings)


def decode_token_specs(mesh: Mesh, batch: int) -> tuple:
    sh = NamedSharding(mesh, shd._resolve(mesh, ("batch",), (batch,)))
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=sh)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=sh)
    return tok, pos
