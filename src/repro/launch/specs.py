"""ShapeDtypeStruct stand-ins for every (arch × shape-cell) input.

Nothing here allocates: params/optimizer/caches/batches are all
`jax.eval_shape`-derived structs with NamedShardings attached, which is what
lets the dry-run lower+compile 9B-param models on a CPU container
(DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeCell
from repro.configs.base import ModelConfig
from repro.core.awq import AWQConfig
from repro.core.pipeline import quantize_params
from repro.core.quantize import QuantConfig
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.training.train_step import init_train_state


def _sds(tree: Any, shardings: Any) -> Any:
    """Attach shardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> dict:
    """Global-batch input ShapeDtypeStructs for one shape cell."""
    b, s = cell.global_batch, cell.seq_len
    bp = NamedSharding(mesh, shd._resolve(mesh, ("batch", None), (b, s)))
    out: dict = {}
    if cfg.frontend == "audio":
        fshape = (b, s, cfg.frontend_dim)
        fsh = NamedSharding(mesh, shd._resolve(mesh, ("batch", None, None),
                                               fshape))
        out["features"] = jax.ShapeDtypeStruct(fshape, jnp.float32,
                                               sharding=fsh)
    else:
        if cfg.frontend == "vision":
            s_text = s - cfg.num_patches  # image span + text = cell seq_len
            ishape = (b, cfg.num_patches, cfg.frontend_dim)
            ish = NamedSharding(mesh, shd._resolve(
                mesh, ("batch", None, None), ishape))
            out["images"] = jax.ShapeDtypeStruct(ishape, jnp.float32,
                                                 sharding=ish)
        else:
            s_text = s
        tp = NamedSharding(mesh, shd._resolve(mesh, ("batch", None),
                                              (b, s_text)))
        out["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32,
                                             sharding=tp)
    if cell.step == "train" or cfg.is_encoder:
        lshape = (b, s if cfg.frontend != "vision" else s_text)
        lsh = NamedSharding(mesh, shd._resolve(mesh, ("batch", None), lshape))
        out["labels"] = jax.ShapeDtypeStruct(lshape, jnp.int32, sharding=lsh)
    if cell.step != "train":
        out.pop("labels", None)
    return out


def param_specs(cfg: ModelConfig, mesh: Mesh, quant: bool) -> Any:
    model = build_model(cfg)
    p_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if quant:
        qcfg = AWQConfig(quant=QuantConfig(group_size=64))
        p_shapes = jax.eval_shape(
            lambda: quantize_params(
                jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype),
                             p_shapes), None, qcfg)[0])
    shardings = shd.make_sharding(p_shapes, mesh, shd.param_pspec, cfg)
    return _sds(p_shapes, shardings)


def train_state_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    model = build_model(cfg)
    st = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))
    p_sh = shd.pspec_tree(st["params"], mesh, shd.param_pspec, cfg)
    m_sh = jax.tree.map(
        lambda spec, leaf: shd.zero1_pspec(spec, leaf.shape, mesh),
        p_sh, st["params"], is_leaf=lambda x: isinstance(x, P))
    specs = {"params": p_sh, "opt": {"m": m_sh, "v": m_sh}, "step": P()}
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return _sds(st, shardings), shardings


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                max_seq: int) -> Any:
    model = build_model(cfg)
    c_shapes = jax.eval_shape(
        lambda: model.init_cache(batch, max_seq))
    shardings = shd.make_sharding(c_shapes, mesh, shd.cache_pspec, cfg)
    return _sds(c_shapes, shardings)


def decode_token_specs(mesh: Mesh, batch: int) -> tuple:
    sh = NamedSharding(mesh, shd._resolve(mesh, ("batch",), (batch,)))
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=sh)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=sh)
    return tok, pos


# ---------------------------------------------------------------------------
# Serving fleet specs (k8s-style declarative deployment description)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """One serving replica, declaratively.

    ``mesh_axis`` is the replica's tensor-parallel ``model``-axis width
    (1 = unsharded; the device pool must hold ``mesh_axis`` devices).
    ``disagg=True`` serves the replica as a `DisaggController`
    prefill/decode pair with per-side mesh widths instead of one
    `GenerationEngine`. ``engine_kwargs`` forward verbatim to the engine
    constructor(s) — shape, KV quant, speculation, preemption knobs.
    """
    mesh_axis: int = 1
    disagg: bool = False
    prefill_mesh_axis: int = 1
    decode_mesh_axis: int = 1
    engine_kwargs: dict = dataclasses.field(default_factory=dict)

    def build(self, model, params, **overrides):
        """Construct the replica this spec describes."""
        from repro.distributed import serving_mesh
        from repro.serving import DisaggController, GenerationEngine
        kw = {**self.engine_kwargs, **overrides}
        if self.disagg:
            return DisaggController(
                model, params,
                prefill_mesh=(serving_mesh(self.prefill_mesh_axis)
                              if self.prefill_mesh_axis > 1 else None),
                decode_mesh=(serving_mesh(self.decode_mesh_axis)
                             if self.decode_mesh_axis > 1 else None),
                **kw)
        mesh = serving_mesh(self.mesh_axis) if self.mesh_axis > 1 else None
        return GenerationEngine(model, params, mesh=mesh, **kw)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A whole serving fleet, declaratively: N replicas of a
    `ReplicaSpec` behind a `serving.Router`.

    The analog of a k8s Deployment + Service: ``replicas`` is the scale,
    ``replica`` the pod template, ``drain_timeout_s`` bounds how long
    `drain_replica` may step the fleet before giving up (elastic
    scale-down), and the placement knobs configure the router's scoring
    (see `serving.router.Router`). `build` materializes the fleet;
    `repro.launch.serve --replicas N` and `examples/serve_fleet.py`
    drive it.
    """
    replicas: int = 1
    replica: ReplicaSpec = dataclasses.field(default_factory=ReplicaSpec)
    drain_timeout_s: float = 30.0
    placement: str = "affinity"
    affinity_threshold: int = 1
    warmup: bool = False

    def build(self, model, params, **overrides):
        """Materialize the fleet: build every replica, wrap the router,
        optionally precompile each replica's dispatch family."""
        from repro.serving import Router
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        fleet = [self.replica.build(model, params, **overrides)
                 for _ in range(self.replicas)]
        router = Router(fleet, placement=self.placement,
                        affinity_threshold=self.affinity_threshold)
        if self.warmup:
            router.warmup()
        return router
