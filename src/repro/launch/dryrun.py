import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count on first init. 512 placeholder CPU devices back both production
meshes: single-pod (16 data × 16 model = 256 chips) and multi-pod
(2 pods × 16 × 16 = 512 chips).

Per cell this script:
  1. builds ShapeDtypeStruct inputs (launch/specs.py — nothing allocates),
  2. jit(step_fn, in_shardings=…).lower(...).compile(),
  3. prints memory_analysis (fits-per-chip proof) and cost_analysis,
  4. parses collective bytes from the compiled HLO,
  5. writes a JSON record consumed by EXPERIMENTS.md §Dry-run/§Roofline and
     `benchmarks/roofline.py`.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --cell train_4k \
      --mesh single --quant awq --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax

import repro.configs as configs
from repro.configs import SHAPES, cells_for
from repro.core.qlinear import set_execution_config
from repro.distributed import sharding as shd
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.roofline.analysis import RooflineTerms, hlo_costs
from repro.roofline.costmodel import analytic_terms
from repro.training import TrainConfig, make_train_step


def model_flops_estimate(cfg, cell) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D (train) or 2·N_active·D (decode/
    prefill forward-only), D = tokens processed this step."""
    n = cfg.n_active_params()
    if cell.step == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.step == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def lower_cell(arch: str, cell_name: str, mesh, quant: bool,
               variant: str = "baseline"):
    cfg = configs.get_config(arch)
    if "kvint8" in variant:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_quant="int8")  # §Perf A4
    cell = SHAPES[cell_name]
    model = build_model(cfg)
    set_execution_config(impl="ref")   # dry-run lowers the jnp dequant path

    with shd.use_mesh(mesh):
        if cell.step == "train":
            state_sds, state_shardings = S.train_state_specs(cfg, mesh)
            batch_sds = S.batch_specs(cfg, cell, mesh)
            step = make_train_step(model, TrainConfig())
            fn = jax.jit(step, out_shardings=(state_shardings, None))
            lowered = fn.lower(state_sds, batch_sds)
        elif cell.step == "prefill":
            params_sds = S.param_specs(cfg, mesh, quant)
            batch_sds = S.batch_specs(cfg, cell, mesh)
            cache_sds = S.cache_specs(cfg, mesh, cell.global_batch,
                                      cell.seq_len)
            fn = jax.jit(model.prefill)
            lowered = fn.lower(params_sds, batch_sds, cache_sds)
        else:  # decode
            params_sds = S.param_specs(cfg, mesh, quant)
            cache_sds = S.cache_specs(cfg, mesh, cell.global_batch,
                                      cell.seq_len)
            tok, pos = S.decode_token_specs(mesh, cell.global_batch)
            if variant == "fused-sample":
                # §Perf A2: greedy sampling fused into the step — logits
                # stay vocab-sharded; only the [B] token crosses the wire.
                import jax.numpy as jnp

                def serve_step(params, cache, token, pos):
                    logits, cache = model.decode_step(params, cache, token,
                                                      pos)
                    return jnp.argmax(logits, -1).astype(jnp.int32), cache
                fn = jax.jit(serve_step, donate_argnums=(1,))
            else:
                fn = jax.jit(model.decode_step)
            lowered = fn.lower(params_sds, cache_sds, tok, pos)
    return lowered, cfg, cell


def run_cell(arch: str, cell_name: str, mesh_kind: str, quant: bool,
             out_dir: str | None, variant: str = "baseline") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    lowered, cfg, cell = lower_cell(arch, cell_name, mesh, quant, variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    costs = hlo_costs(compiled.as_text())
    analytic = analytic_terms(cfg, cell_name, chips, quant)
    # compute/collective terms from the compiled artifact (dot flops and
    # collective operand bytes parse exactly); memory term from the analytic
    # model (XLA-CPU widens bf16 dots to f32 — its byte counts are recorded
    # as `hlo_bytes_upper_bound`, see roofline/costmodel.py docstring).
    terms = RooflineTerms(
        flops=max(costs["flops"], analytic["analytic_flops_global"] / chips),
        bytes_accessed=analytic["analytic_bytes_global"] / chips,
        collective_bytes=costs["total"], chips=chips,
        model_flops=model_flops_estimate(cfg, cell))

    rec = {
        "arch": arch, "cell": cell_name, "mesh": mesh_kind,
        "variant": variant,
        "chips": chips, "quant": "awq-int4" if quant else "none",
        "step": cell.step,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes",
                                      0)),
        },
        "collectives": {k: v for k, v in costs.items()
                        if k not in ("flops", "bytes")},
        "hlo_flops": costs["flops"],
        "hlo_bytes_upper_bound": costs["bytes"],
        "raw_cost_analysis": {"flops": float(ca.get("flops", 0.0)),
                              "bytes": float(ca.get("bytes accessed", 0.0))},
        **analytic,
        **terms.to_dict(),
    }
    print(f"[dryrun] {arch} {cell_name} mesh={mesh_kind} "
          f"quant={rec['quant']}")
    print(f"  memory_analysis: {rec['memory_analysis']}")
    print(f"  cost: flops/chip={terms.flops:.3e} bytes/chip="
          f"{terms.bytes_accessed:.3e} coll_bytes/chip="
          f"{terms.collective_bytes:.3e}")
    print(f"  terms: compute={terms.compute_s:.3e}s memory="
          f"{terms.memory_s:.3e}s collective={terms.collective_s:.3e}s "
          f"dominant={terms.dominant} roofline_frac="
          f"{terms.roofline_fraction:.3f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{cell_name}__{mesh_kind}__{rec['quant']}"
        if variant != "baseline":
            fn += f"__{variant}"
        fn += ".json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="awq", choices=["awq", "none"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    jobs = []
    if args.all:
        for arch in configs.list_archs():
            for cell in cells_for(arch):
                for mk in meshes:
                    jobs.append((arch, cell, mk))
    else:
        for mk in meshes:
            jobs.append((args.arch, args.cell, mk))

    failures = []
    for arch, cell, mk in jobs:
        quant = (args.quant == "awq") and SHAPES[cell].step != "train"
        try:
            run_cell(arch, cell, mk, quant, args.out, args.variant)
        except Exception as e:  # a failing cell is a bug in the system
            failures.append((arch, cell, mk, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print(f"dry-run OK: {len(jobs)} cells")


if __name__ == "__main__":
    main()
