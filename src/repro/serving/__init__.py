from repro.serving.engine import GenerationEngine, SamplerConfig  # noqa: F401
