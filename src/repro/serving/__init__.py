from repro.serving.engine import (  # noqa: F401
    GenerationEngine, SamplerConfig, sample, sample_batched)
from repro.serving.kv_pager import (  # noqa: F401
    KVPager, PageAllocationError, PagerConfig, commit_prefill)
from repro.serving.scheduler import (  # noqa: F401
    Request, Scheduler, ngram_propose, width_family)
