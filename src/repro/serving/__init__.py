from repro.serving.disagg import (  # noqa: F401
    DecodeEngine, DisaggController, DisaggStats, KVHandoff, PrefillEngine)
from repro.serving.engine import (  # noqa: F401
    EngineStats, GenerationEngine, SamplerConfig, sample, sample_batched)
from repro.serving.router import (  # noqa: F401
    Router, RouterStats)
from repro.serving.kv_pager import (  # noqa: F401
    HandoffRecord, KVPager, PageAllocationError, PagerConfig, PagerStats,
    SpillRecord, commit_prefill)
from repro.serving.scheduler import (  # noqa: F401
    Request, Scheduler, ngram_propose, spec_k_buckets, width_family)
