"""Continuous-batching request scheduler (AccLLM/EdgeLLM-style runtime).

The decode step is a fixed-shape jit'd function over ``num_slots`` rows;
the scheduler's job is to keep those rows saturated:

  * **admission** — FIFO queue; a request is admitted when a slot is free
    and the pager can cover its worst-case KV footprint. Admission runs a
    per-request prefill (jit per prompt length), samples the first token
    with the request's own sampling params, and commits the prefill KV
    into the paged cache.
  * **decode interleaving** — one `step()` decodes every active slot in a
    single fixed-shape dispatch; per-request positions, temperatures and
    top-k ride along as arrays, inactive rows decode into the pager's
    scratch page (masked out host-side).
  * **EOS eviction + backfill** — a row finishing (EOS or token budget)
    frees its pages and slot, and the queue is drained into freed slots
    in the same `step()` call, so the batch never idles a slot while work
    is queued.

The scheduler is deliberately device-agnostic: it talks to the engine
through two callables (`prefill_commit`, `decode`) and keeps only
host-side state, so it can be unit-tested with a fake executor.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import numpy as np

from repro.serving.kv_pager import KVPager


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray            # [S] int32 prompt
    max_new_tokens: int
    temperature: float = 0.0      # 0 ⇒ greedy
    top_k: int = 0                # 0 ⇒ full softmax
    eos_id: int = -1              # -1 ⇒ never stops early
    prefix_id: str | None = None  # opt into prefix sharing (namespace key)


@dataclasses.dataclass
class _SlotState:
    request: Request
    generated: list[int]          # sampled tokens, first comes from prefill

    @property
    def next_pos(self) -> int:
        """Cache position where the next decode input token is written."""
        return len(self.request.tokens) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        r = self.request
        return (len(self.generated) >= r.max_new_tokens
                or (r.eos_id >= 0 and self.generated
                    and self.generated[-1] == r.eos_id))


@dataclasses.dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    slot_tokens: int = 0          # useful tokens produced by decode rows
    slot_steps: int = 0           # total rows dispatched (incl. idle)
    prefix_shared_pages: int = 0  # pages aliased instead of allocated


class Scheduler:
    """Queue + slot bookkeeping over an executor's jit'd prefill/decode."""

    def __init__(self, pager: KVPager, *,
                 prefill_commit: Callable[[Request, int, list[int], int],
                                          int],
                 decode: Callable[[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray], np.ndarray]):
        self.pager = pager
        self.num_slots = pager.cfg.num_slots
        # prefill_commit(request, slot, pages, n_shared) → first sampled
        # token; the engine fuses prefill + page commit + sampling into one
        # dispatch, skipping the commit of the n_shared aliased prefix pages
        self._prefill_commit = prefill_commit
        self._decode = decode
        self.queue: deque[Request] = deque()
        self.slots: dict[int, _SlotState] = {}
        self.finished: dict[int, np.ndarray] = {}
        self.stats = SchedulerStats()

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        if len(request.tokens) < 1:
            raise ValueError("empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be ≥ 1")
        # reject requests that could never be placed even on an idle engine —
        # otherwise they sit at the queue head forever and stall everything
        if not self.pager.fits(len(request.tokens), request.max_new_tokens):
            pc = self.pager.cfg
            raise ValueError(
                f"request rid={request.rid} exceeds engine capacity: "
                f"{len(request.tokens) + request.max_new_tokens - 1} KV "
                f"tokens vs slot capacity "
                f"{pc.pages_per_slot * pc.page_size} "
                f"({pc.num_pages - 1} usable pages)")
        self.queue.append(request)

    @property
    def num_active(self) -> int:
        return len(self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.slots

    def step(self) -> list[tuple[int, int]]:
        """Admit → decode all slots once → evict + backfill.

        Returns ``(rid, token)`` stream events in emission order.
        """
        events: list[tuple[int, int]] = []
        self._admit(events)
        if self.slots:
            self._decode_once(events)
            self._admit(events)          # backfill slots freed by EOS now
        return events

    def run(self) -> dict[int, np.ndarray]:
        """Drain queue + slots to completion; returns {rid: tokens}."""
        while not self.idle:
            self.step()
        out, self.finished = self.finished, {}
        return out

    # ------------------------------------------------------------ internals
    def _admit(self, events: list[tuple[int, int]]) -> None:
        while self.queue:
            req = self.queue[0]
            # prefix detection at admission: requests that opted in
            # (prefix_id set) alias any already-resident full pages whose
            # content-hash chain matches their prompt — those pages don't
            # count against free capacity
            shared = (self.pager.match_prefix(req.tokens, req.prefix_id)
                      if req.prefix_id is not None else [])
            if not self.pager.can_admit(len(req.tokens), req.max_new_tokens,
                                        n_shared=len(shared)):
                break
            self.queue.popleft()
            slot, pages = self.pager.alloc_slot(len(req.tokens),
                                                req.max_new_tokens,
                                                shared_pages=shared)
            tok = int(self._prefill_commit(req, slot, pages, len(shared)))
            if req.prefix_id is not None:
                self.pager.register_prefix(slot, req.tokens, req.prefix_id)
            self.stats.prefix_shared_pages += len(shared)
            st = _SlotState(request=req, generated=[tok])
            self.slots[slot] = st
            self.stats.admitted += 1
            events.append((req.rid, tok))
            if st.done:
                self._finish(slot)

    def _decode_once(self, events: list[tuple[int, int]]) -> None:
        b = self.num_slots
        token = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        topks = np.zeros(b, np.int32)
        for slot, st in self.slots.items():
            token[slot] = st.generated[-1]
            pos[slot] = st.next_pos
            temps[slot] = st.request.temperature
            topks[slot] = st.request.top_k
            self.pager.extend(slot, st.next_pos + 1)
        next_tokens = self._decode(self.pager.page_tables, token, pos,
                                   temps, topks)
        self.stats.decode_steps += 1
        self.stats.slot_steps += b
        for slot in list(self.slots):
            st = self.slots[slot]
            tok = int(next_tokens[slot])
            st.generated.append(tok)
            self.stats.slot_tokens += 1
            events.append((st.request.rid, tok))
            if st.done:
                self._finish(slot)

    def _finish(self, slot: int) -> None:
        st = self.slots.pop(slot)
        self.pager.free_slot(slot)
        self.finished[st.request.rid] = np.asarray(st.generated, np.int32)
        self.stats.finished += 1
