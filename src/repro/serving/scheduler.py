"""Continuous-batching request scheduler (AccLLM/EdgeLLM-style runtime).

Two execution models over the same admission/eviction machinery:

  * **chunked (token-budget) scheduling** — the default serving path for
    pure paged-attention archs. Every `step()` issues ONE fixed-shape
    dispatch of ``num_slots × chunk_size`` token positions: each
    decoding slot contributes one row (its decode token), the remaining
    rows are packed with **prefill chunks** from prefilling slots in
    admission order (a lone long prompt drains the whole idle budget),
    and unused positions are padded (``pos = -1``). A long prompt no
    longer monopolizes the engine (the convoy effect): its chunks
    interleave with everyone else's decode tokens, and the first token
    is sampled in the same dispatch whose chunk commits the last prompt
    token. Aliased shared-prefix pages seed the commit watermark at
    admission, so their tokens are **never recomputed** — prefix sharing
    saves prefill FLOPs, not just memory. Steps with no prefilling slot
    narrow to ``c = 1``, so steady-state decode pays zero padding; the
    compiled family is {decode-only, hybrid} × O(log) context buckets,
    killing the jit-per-prompt-length family.
  * **one-shot scheduling** (legacy) — per-request prefill fused with
    page commit and first-token sampling at admission, single-token
    decode over all slots. Still required for archs with bounded
    sequential per-slot state (sliding-window rings, SSM, MLA).

Shared across both: FIFO admission when a slot is free and the pager can
cover the request's worst-case KV footprint; EOS/budget eviction with
immediate backfill from the queue in the same `step()`.

The scheduler is deliberately device-agnostic: it talks to the engine
through callables (`run_batch` for chunked mode, `prefill_commit` +
`decode` for one-shot) and keeps only host-side state, so it can be
unit-tested with a fake executor.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import numpy as np

from repro.serving.kv_pager import KVPager


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray            # [S] int32 prompt
    max_new_tokens: int
    temperature: float = 0.0      # 0 ⇒ greedy
    top_k: int = 0                # 0 ⇒ full softmax
    eos_id: int = -1              # -1 ⇒ never stops early
    prefix_id: str | None = None  # opt into prefix sharing (namespace key)


@dataclasses.dataclass
class _SlotState:
    request: Request
    generated: list[int]          # sampled tokens (empty while prefilling)
    # prompt tokens already scheduled through the model. Deliberately NOT
    # the pager's slot_committed (KV-resident tokens): for a fully aliased
    # page-aligned prompt the pager watermark covers the whole prompt, but
    # this counter is seeded one short so the final token still runs and
    # produces the first-token logits.
    committed: int = 0

    @property
    def prefilling(self) -> bool:
        return self.committed < len(self.request.tokens)

    @property
    def next_pos(self) -> int:
        """Cache position where the next decode input token is written."""
        return len(self.request.tokens) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        r = self.request
        return (len(self.generated) >= r.max_new_tokens
                or (r.eos_id >= 0 and bool(self.generated)
                    and self.generated[-1] == r.eos_id))


@dataclasses.dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0         # unified dispatches in chunked mode
    slot_tokens: int = 0          # useful tokens produced by decode rows
    slot_steps: int = 0           # total rows dispatched (incl. idle)
    prefix_shared_pages: int = 0  # pages aliased instead of allocated
    prefill_chunks: int = 0       # prompt chunks dispatched (chunked mode)
    prefill_tokens: int = 0       # prompt tokens run through the model
    prefill_tokens_skipped: int = 0   # aliased prompt tokens never re-run


class Scheduler:
    """Queue + slot bookkeeping over an executor's jit'd step functions.

    Pass ``run_batch`` for chunked (token-budget) scheduling, or both
    ``prefill_commit`` and ``decode`` for one-shot scheduling:

      * run_batch(tokens [B, C], pos [B, C], row_slots [B],
        sample_idx [B], temps [B], topks [B]) → sampled [B] — one
        fixed-shape dispatch that scatters every valid token's KV into
        the paged cache (row b reads/writes slot ``row_slots[b]``'s
        pages) and returns, per row, the token sampled at ``sample_idx``
        (consumed only for rows that finished their prompt or decoded).
      * prefill_commit(request, slot, pages, n_shared) → first token;
        decode(page_tables, token, pos, temps, topks) → next tokens.
    """

    def __init__(self, pager: KVPager, *,
                 prefill_commit: Callable | None = None,
                 decode: Callable | None = None,
                 run_batch: Callable | None = None,
                 chunk_size: int = 16):
        self.pager = pager
        self.num_slots = pager.cfg.num_slots
        self.chunked = run_batch is not None
        if self.chunked:
            if chunk_size < 1:
                raise ValueError("chunk_size must be ≥ 1")
        elif prefill_commit is None or decode is None:
            raise ValueError("need run_batch (chunked) or "
                             "prefill_commit + decode (one-shot)")
        self._run_batch = run_batch
        self._prefill_commit = prefill_commit
        self._decode = decode
        self.chunk_size = chunk_size
        self.queue: deque[Request] = deque()
        self.slots: dict[int, _SlotState] = {}
        self.finished: dict[int, np.ndarray] = {}
        self.stats = SchedulerStats()

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        if len(request.tokens) < 1:
            raise ValueError("empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be ≥ 1")
        # reject requests that could never be placed even on an idle engine —
        # otherwise they sit at the queue head forever and stall everything
        if not self.pager.fits(len(request.tokens), request.max_new_tokens):
            pc = self.pager.cfg
            raise ValueError(
                f"request rid={request.rid} exceeds engine capacity: "
                f"{len(request.tokens) + request.max_new_tokens - 1} KV "
                f"tokens vs slot capacity "
                f"{pc.pages_per_slot * pc.page_size} "
                f"({pc.num_pages - 1} usable pages)")
        self.queue.append(request)

    @property
    def num_active(self) -> int:
        return len(self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.slots

    def step(self) -> list[tuple[int, int]]:
        """Admit → one dispatch over all slots → evict + backfill.

        Returns ``(rid, token)`` stream events in emission order.
        """
        events: list[tuple[int, int]] = []
        self._admit(events)
        if self.slots:
            if self.chunked:
                self._step_chunked(events)
            else:
                self._decode_once(events)
            self._admit(events)          # backfill slots freed by EOS now
        return events

    def run(self) -> dict[int, np.ndarray]:
        """Drain queue + slots to completion; returns {rid: tokens}."""
        while not self.idle:
            self.step()
        out, self.finished = self.finished, {}
        return out

    # ------------------------------------------------------------ admission
    def _admit(self, events: list[tuple[int, int]]) -> None:
        while self.queue:
            req = self.queue[0]
            # chunked mode registers a prefix on its final chunk; while a
            # slot with the same namespace is still prefilling, hold the
            # queue head so the follower admits against the full
            # registered match instead of racing it to zero sharing
            if (self.chunked and req.prefix_id is not None
                    and any(st.prefilling
                            and st.request.prefix_id == req.prefix_id
                            for st in self.slots.values())):
                break
            # prefix detection at admission: requests that opted in
            # (prefix_id set) alias any already-resident full pages whose
            # content-hash chain matches their prompt — those pages don't
            # count against free capacity
            shared = (self.pager.match_prefix(req.tokens, req.prefix_id)
                      if req.prefix_id is not None else [])
            if not self.pager.can_admit(len(req.tokens), req.max_new_tokens,
                                        n_shared=len(shared)):
                break
            self.queue.popleft()
            slot, pages = self.pager.alloc_slot(len(req.tokens),
                                                req.max_new_tokens,
                                                shared_pages=shared)
            self.stats.prefix_shared_pages += len(shared)
            self.stats.admitted += 1
            if self.chunked:
                # aliased tokens are already resident: chunking starts past
                # them (at least the final prompt token always runs, so the
                # first-token logits exist even for a fully aliased prompt)
                skip = min(len(shared) * self.pager.cfg.page_size,
                           len(req.tokens) - 1)
                self.slots[slot] = _SlotState(request=req, generated=[],
                                              committed=skip)
                self.stats.prefill_tokens_skipped += skip
                continue
            # one-shot: fused prefill + commit + first-token sample now
            tok = int(self._prefill_commit(req, slot, pages, len(shared)))
            if req.prefix_id is not None:
                self.pager.register_prefix(slot, req.tokens, req.prefix_id)
            st = _SlotState(request=req, generated=[tok],
                            committed=len(req.tokens))
            self.slots[slot] = st
            events.append((req.rid, tok))
            if st.done:
                self._finish(slot)

    # ------------------------------------------- chunked (token-budget) step
    def _step_chunked(self, events: list[tuple[int, int]]) -> None:
        """One fixed-shape dispatch packing prefill chunks + decode tokens.

        The dispatch is a ``[num_slots, c]`` token block — the step's
        token budget. Each decoding slot takes one row (its single decode
        token); the remaining rows are handed to prefilling slots in
        admission order as consecutive fixed-size chunks, so a lone long
        prompt drains the whole idle budget instead of one chunk per
        step. Rows carry their slot in ``row_slots`` (the executor
        gathers that slot's page-table row per dispatch row). When no
        slot is prefilling the block narrows to ``c = 1`` — steady-state
        decode pays zero padding, and the compiled-variant family stays
        at {decode-only, hybrid} × context buckets.
        """
        b = self.num_slots
        prefilling = [s for s, st in self.slots.items() if st.prefilling]
        c = self.chunk_size if prefilling else 1
        tokens = np.zeros((b, c), np.int32)
        pos = np.full((b, c), -1, np.int32)
        row_slots = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        topks = np.zeros(b, np.int32)
        sample_idx = np.zeros(b, np.int32)
        sample_row: dict[int, int] = {}       # slot → row holding its sample
        chunk_tok: dict[int, int] = {}        # slot → prompt tokens this step
        row = 0
        for slot, st in self.slots.items():   # decode rows first
            if st.prefilling:
                continue
            r = st.request
            tokens[row, 0] = st.generated[-1]
            pos[row, 0] = st.next_pos
            row_slots[row] = slot
            self.pager.extend(slot, st.next_pos + 1)
            sample_row[slot] = row
            temps[row] = r.temperature
            topks[row] = r.top_k
            row += 1
        for slot in prefilling:               # pack chunks into free rows
            if row >= b:
                break
            st = self.slots[slot]
            r = st.request
            start = st.committed
            take = min(len(r.tokens) - start, (b - row) * c)
            done = 0
            while done < take:
                n = min(c, take - done)
                tokens[row, :n] = r.tokens[start + done:start + done + n]
                pos[row, :n] = np.arange(start + done, start + done + n)
                row_slots[row] = slot
                self.stats.prefill_chunks += 1
                done += n
                if start + done == len(r.tokens):
                    sample_row[slot] = row    # last chunk lands this step
                    sample_idx[row] = n - 1
                    temps[row] = r.temperature
                    topks[row] = r.top_k
                row += 1
            self.pager.commit_chunk(slot, start, start + take)
            chunk_tok[slot] = take
        sampled = self._run_batch(tokens, pos, row_slots, sample_idx,
                                  temps, topks)
        self.stats.decode_steps += 1
        self.stats.slot_steps += b
        for slot in list(self.slots):
            st = self.slots[slot]
            if slot in chunk_tok:
                st.committed += chunk_tok[slot]
                self.stats.prefill_tokens += chunk_tok[slot]
            row = sample_row.get(slot)
            if row is None or st.prefilling:
                continue                      # mid-prefill: nothing sampled
            first = slot in chunk_tok         # prompt completed this step
            if first and st.request.prefix_id is not None:
                # register on the final chunk: the whole prompt is resident
                self.pager.register_prefix(slot, st.request.tokens,
                                           st.request.prefix_id)
            tok = int(sampled[row])
            st.generated.append(tok)
            if not first:
                self.stats.slot_tokens += 1
            events.append((st.request.rid, tok))
            if st.done:
                self._finish(slot)

    # ------------------------------------------------- one-shot decode step
    def _decode_once(self, events: list[tuple[int, int]]) -> None:
        b = self.num_slots
        token = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        topks = np.zeros(b, np.int32)
        for slot, st in self.slots.items():
            token[slot] = st.generated[-1]
            pos[slot] = st.next_pos
            temps[slot] = st.request.temperature
            topks[slot] = st.request.top_k
            self.pager.extend(slot, st.next_pos + 1)
        next_tokens = self._decode(self.pager.page_tables, token, pos,
                                   temps, topks)
        self.stats.decode_steps += 1
        self.stats.slot_steps += b
        for slot in list(self.slots):
            st = self.slots[slot]
            tok = int(next_tokens[slot])
            st.generated.append(tok)
            self.stats.slot_tokens += 1
            events.append((st.request.rid, tok))
            if st.done:
                self._finish(slot)

    def _finish(self, slot: int) -> None:
        st = self.slots.pop(slot)
        self.pager.free_slot(slot)
        self.finished[st.request.rid] = np.asarray(st.generated, np.int32)
        self.stats.finished += 1
