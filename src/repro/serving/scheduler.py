"""Continuous-batching request scheduler (AccLLM/EdgeLLM-style runtime).

Two execution models over the same admission/eviction machinery:

  * **chunked (token-budget) scheduling** — the default serving path for
    pure paged-attention archs. Every `step()` issues ONE fixed-shape
    dispatch of ``num_slots × c`` token positions, where each row is one
    slot's **token run**: a single decode token, a speculative
    draft/verify run of up to ``spec_k + 1`` tokens, or a prefill chunk
    (a lone long prompt drains the whole idle budget across several
    rows). Rows declare their true run length and ``c`` is the smallest
    width bucket covering the longest run this step — a decode row is no
    longer padded to the prefill chunk width when only a short tail
    chunk (or nothing) is prefilling, and steps with only plain decode
    rows narrow to ``c = 1`` (zero padding in steady state). The first
    token is sampled in the same dispatch whose chunk commits the last
    prompt token; aliased shared-prefix pages seed the commit watermark
    at admission, so their tokens are **never recomputed**. The compiled
    family stays bounded: O(log chunk) width buckets × O(log) context
    buckets, killing the jit-per-prompt-length family.
  * **one-shot scheduling** (legacy) — per-request prefill fused with
    page commit and first-token sampling at admission, single-token
    decode over all slots. Still required for archs with bounded
    sequential per-slot state (sliding-window rings, SSM, MLA).

Speculative decoding (chunked mode only) rides the token-run
generalization: a drafter proposes up to ``spec_k`` tokens per decoding
slot — either the built-in **n-gram prompt-lookup self-drafter** (the
slot's own context predicts its continuation; no second model) or an
engine-supplied ``draft_fn`` (small draft model) — and the slot's row
becomes ``[last_token, d_1, …, d_k]`` at consecutive positions. The
same unified dispatch verifies all drafts in one weight pass (the
verify row is just a multi-token decode row), the executor returns how
many leading drafts the target distribution accepted plus one
corrected/bonus token (standard acceptance sampling — exactly
token-identical to sequential decode under greedy), and rejected
suffixes roll the KV watermark back via `KVPager.truncate`.

Shared across both: FIFO-within-priority admission when a slot is free
and the pager can cover the request's worst-case KV footprint; EOS/budget
eviction with immediate backfill from the queue in the same `step()`.

SLO-aware preemption (``preemption=True``, chunked mode only):

  * `Request.priority` classes order the queue (higher first, FIFO within
    a class). When admission of a higher class would otherwise stall, the
    scheduler picks a **victim** among strictly-lower-priority active
    slots — lowest priority, then most pages held, then least progress —
    and spills it through `KVPager.spill` to the host tier (the engine's
    ``spill_fn`` gathers the evicted pages' bytes off the device first).
  * Preempted requests park in ``self.preempted`` with their full slot
    state (generated tokens, prefill progress). Re-admission prefers
    parked requests over the queue at equal-or-higher priority — they
    hold committed KV — and `restore` re-enters the chunk dispatch at
    the pager's commit watermark with **zero recompute**: a decoding
    request resumes decoding, a mid-prefill request resumes at its next
    chunk.
  * Under ``PagerConfig.optimistic`` admission the scheduler also runs a
    pre-dispatch **pressure check**: if this step's decode/verify
    extends would drain the free pool, victims are spilled (same score)
    before packing, which keeps `extend` infallible at dispatch time.
    Progress is guaranteed: `fits` caps any single request at the pool
    size, so spilling down to one slot always relieves the pressure
    (absent pathological pinning, which raises a clear error).

The scheduler is deliberately device-agnostic: it talks to the engine
through callables (`run_batch` for chunked mode, `prefill_commit` +
`decode` for one-shot) and keeps only host-side state, so it can be
unit-tested with a fake executor.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.serving.kv_pager import KVPager, SpillRecord


def ngram_propose(ctx: np.ndarray, k: int, max_n: int = 3,
                  min_n: int = 1, window: int = 512) -> list[int]:
    """Prompt-lookup drafting: continue ``ctx`` by matching its suffix.

    Finds the longest suffix n-gram (``max_n`` down to ``min_n``) that
    occurred earlier in ``ctx`` and proposes up to ``k`` tokens that
    followed its most recent earlier occurrence. Returns ``[]`` when
    nothing matches — the slot falls back to plain single-token decode.
    This is the self-drafting mode: repetitive text (code, templated
    chat, lists) drafts itself with no second model.

    The match scans only the trailing ``window`` tokens, so per-step
    drafting cost is O(window), not O(context) — long streams don't turn
    the host-side drafter into a quadratic scan (recent context is also
    where the predictive repetition lives).
    """
    ctx = np.asarray(ctx)
    if window and len(ctx) > window:
        ctx = ctx[-window:]
    ln = len(ctx)
    for n in range(min(max_n, ln - 1), min_n - 1, -1):
        tail = ctx[ln - n:]
        # windows over ctx[:-1]: every match has at least one continuation
        # token, and the suffix itself (start ln - n) is never a candidate
        win = np.lib.stride_tricks.sliding_window_view(ctx[:-1], n)
        hits = np.nonzero((win == tail).all(axis=1))[0]
        if len(hits):
            start = int(hits[-1]) + n          # most recent occurrence
            cont = ctx[start:start + k]
            if cont.size:
                return [int(t) for t in cont]
    return []


def ngram_propose_tree(ctx: np.ndarray, budget: int, fanout: int,
                       max_n: int = 3, min_n: int = 1,
                       window: int = 512) -> list[tuple[int, int]]:
    """Prompt-lookup drafting, tree-shaped: ``[(token, parent), …]``.

    Like `ngram_propose`, but instead of a single chain the proposal is a
    token TREE of at most ``budget`` nodes: a primary chain continued
    from the suffix's most recent earlier occurrence, plus up to
    ``fanout - 1`` depth-1 **alternate** first tokens taken from older
    occurrence sites whose continuations start differently. Each node is
    ``(token, parent)`` with ``parent`` the node index of its parent
    (``-1`` = the root, i.e. the slot's last sampled token); parents
    always precede children (topological order), which the device-side
    acceptance walk and the KV-slot layout both rely on. Alternates hedge
    the chain: when the target rejects the primary first token, a
    matching alternate still salvages one accepted token from the same
    weight pass. Returns ``[]`` when nothing matches.
    """
    ctx = np.asarray(ctx)
    if window and len(ctx) > window:
        ctx = ctx[-window:]
    ln = len(ctx)
    for n in range(min(max_n, ln - 1), min_n - 1, -1):
        tail = ctx[ln - n:]
        win = np.lib.stride_tricks.sliding_window_view(ctx[:-1], n)
        hits = np.nonzero((win == tail).all(axis=1))[0]
        if not len(hits):
            continue
        start = int(hits[-1]) + n              # most recent occurrence
        first = int(ctx[start])
        # depth-1 alternates: older sites with DISTINCT first tokens
        alts: list[int] = []
        seen = {first}
        for h in hits[-2::-1]:
            if len(alts) >= fanout - 1:
                break
            t2 = int(ctx[int(h) + n])
            if t2 not in seen:
                seen.add(t2)
                alts.append(t2)
        chain_len = max(1, budget - len(alts))
        alts = alts[:budget - chain_len]
        chain = [int(t) for t in ctx[start:start + chain_len]]
        if not chain:
            continue
        nodes = [(chain[0], -1)]
        for i, t in enumerate(chain[1:]):
            nodes.append((t, i))               # chain: parent = predecessor
        nodes.extend((t, -1) for t in alts)    # alternates branch the root
        return nodes
    return []


def spec_k_buckets(spec_k_max: int) -> list[int]:
    """Draft-length buckets adaptive speculation moves through: powers of
    two up to ``spec_k_max``, plus ``spec_k_max`` itself. Bounded at
    O(log k), so the compiled verify-width family stays bounded too."""
    ks = {1, spec_k_max}
    k = 2
    while k < spec_k_max:
        ks.add(k)
        k *= 2
    return sorted(ks)


def width_family(chunk_size: int, spec_k: int = 0) -> list[int]:
    """Column-width buckets the token-budget packer may dispatch.

    Powers of two up to ``chunk_size`` (plus ``chunk_size`` itself and,
    under speculative decoding, the verify-run width ``kb + 1`` for every
    draft-length bucket adaptive ``spec_k`` may visit), so the
    compiled-step family stays O(log chunk + log k) wide while rows are
    padded only to the smallest bucket covering the step's longest
    declared run — not unconditionally to the prefill chunk width.
    """
    widths = {1, chunk_size}
    w = 2
    while w < chunk_size:
        widths.add(w)
        w *= 2
    if spec_k:
        widths.update(kb + 1 for kb in spec_k_buckets(spec_k))
    return sorted(widths)


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray            # [S] int32 prompt
    max_new_tokens: int
    temperature: float = 0.0      # 0 ⇒ greedy
    top_k: int = 0                # 0 ⇒ full softmax
    eos_id: int = -1              # -1 ⇒ never stops early
    prefix_id: str | None = None  # opt into prefix sharing (namespace key)
    priority: int = 0             # SLO class: higher admits/preempts lower


@dataclasses.dataclass
class _SlotState:
    request: Request
    generated: list[int]          # sampled tokens (empty while prefilling)
    # prompt tokens already scheduled through the model. Deliberately NOT
    # the pager's slot_committed (KV-resident tokens): for a fully aliased
    # page-aligned prompt the pager watermark covers the whole prompt, but
    # this counter is seeded one short so the final token still runs and
    # produces the first-token logits.
    committed: int = 0

    @property
    def prefilling(self) -> bool:
        return self.committed < len(self.request.tokens)

    @property
    def next_pos(self) -> int:
        """Cache position where the next decode input token is written."""
        return len(self.request.tokens) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        r = self.request
        return (len(self.generated) >= r.max_new_tokens
                or (r.eos_id >= 0 and bool(self.generated)
                    and self.generated[-1] == r.eos_id))


@dataclasses.dataclass
class _Preempted:
    """A spilled request parked off-device: scheduler state + the pager's
    spill record + the engine's opaque handle onto the host-tier bytes."""
    state: _SlotState
    record: SpillRecord
    handle: object
    seq: int                      # spill order (FIFO restore within class)


@dataclasses.dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0         # unified dispatches in chunked mode
    slot_tokens: int = 0          # useful tokens produced by decode rows
    slot_steps: int = 0           # total rows dispatched (incl. idle)
    prefix_shared_pages: int = 0  # pages aliased instead of allocated
    prefill_chunks: int = 0       # prompt chunks dispatched (chunked mode)
    prefill_tokens: int = 0       # prompt tokens run through the model
    prefill_tokens_skipped: int = 0   # aliased prompt tokens never re-run
    # --- speculative decoding -------------------------------------------
    spec_rows: int = 0            # draft/verify runs dispatched
    draft_tokens: int = 0         # draft tokens proposed and verified
    accepted_tokens: int = 0      # draft tokens the target accepted
    rollbacks: int = 0            # verify runs that truncated the KV
    rollback_pages: int = 0       # pages returned to the free list by them
    # --- token-budget packing accounting --------------------------------
    dispatched_positions: int = 0     # num_slots × c summed over steps
    padded_positions: int = 0         # dispatched positions holding padding
    padded_positions_fixed: int = 0   # what padding the pre-run-length
    #                                   policy (c = chunk_size whenever
    #                                   anything prefills) would have paid
    # --- preemption / spill ---------------------------------------------
    preemptions: int = 0          # slots spilled to the host tier
    pressure_spills: int = 0      # of those, spills by the page-pressure
    #                               check (optimistic admission), not SLO
    restores: int = 0             # parked requests re-admitted
    spilled_pages: int = 0        # page strips gathered to the host tier
    restored_pages: int = 0       # page strips scattered back
    restore_time_s: float = 0.0   # wall time inside restore (pager +
    #                               device scatter), for restore latency

    def zero(self) -> None:
        """Reset every declared counter to its default, **in place**.

        This is the reset `GenerationEngine.reset_stats()` uses. Resetting
        in place (rather than rebuilding via ``type(self)()``) keeps two
        guarantees the rebuild silently broke:

          * the object's identity survives — anything holding a reference
            to the stats snapshot keeps seeing the live counters;
          * fields without a default (e.g. added by a subclass that binds
            live state at construction) are left untouched instead of
            crashing the reset or being dropped to a stale default —
            only counters with a declared default/default_factory reset.
        """
        for f in dataclasses.fields(self):
            if f.default is not dataclasses.MISSING:
                setattr(self, f.name, f.default)
            elif f.default_factory is not dataclasses.MISSING:
                setattr(self, f.name, f.default_factory())

    @property
    def acceptance_rate(self) -> float:
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def spec_tokens_per_row(self) -> float:
        """Mean tokens emitted per draft/verify run (accepted + the
        corrected/bonus token); 1.0 means drafting never helped."""
        return (self.accepted_tokens + self.spec_rows) / max(self.spec_rows,
                                                             1)

    @property
    def padding_waste(self) -> float:
        return self.padded_positions / max(self.dispatched_positions, 1)


class Scheduler:
    """Queue + slot bookkeeping over an executor's jit'd step functions.

    Pass ``run_batch`` for chunked (token-budget) scheduling, or both
    ``prefill_commit`` and ``decode`` for one-shot scheduling:

      * run_batch(tokens [B, C], pos [B, C], row_slots [B],
        sample_idx [B], temps [B], topks [B]) → sampled [B] — one
        fixed-shape dispatch that scatters every valid token's KV into
        the paged cache (row b reads/writes slot ``row_slots[b]``'s
        pages) and returns, per row, the token sampled at ``sample_idx``
        (consumed only for rows that finished their prompt or decoded).
        Under speculative decoding the call carries an extra keyword
        ``n_draft [B]`` (draft tokens per row — the run is
        ``tokens[b, sample_idx[b] : sample_idx[b] + 1 + n_draft[b]]``)
        and must return ``(fix_tok [B], n_acc [B])``: the leading-accept
        count against the target distribution and the corrected (on
        rejection) or bonus (on full acceptance) token sampled at index
        ``n_acc``. Rows with ``n_draft == 0`` degenerate to the plain
        contract (``n_acc = 0``, ``fix_tok`` = the sampled token).
        Under ``spec_tree`` steps carrying at least one tree row add a
        keyword ``tree={"rpos", "amask", "parents"}`` (logical
        positions, per-row ancestor-closure visibility blocks, in-row
        parent indices) and must return ``(fix_tok, n_acc, path)`` with
        ``path [B, spec_k]`` the accepted branch's in-row node indices —
        the executor walks the tree ON DEVICE and compacts the winning
        branch's KV into contiguous slots before returning.
      * prefill_commit(request, slot, pages, n_shared) → first token;
        decode(page_tables, token, pos, temps, topks) → next tokens.

    ``spec_decode``: ``None`` (off), ``"ngram"`` (built-in prompt-lookup
    self-drafter), or ``"draft_fn"`` with a ``draft_fn`` callable
    ``[(slot, rid, ctx, next_pos, k_eff)] → {slot: [tokens]}`` (the
    engine's draft-model hook, or a custom drafter in tests). Draft
    length is capped per slot at ``min(spec_k, budget_left - 1)`` so a
    verify run can never write KV past the slot's admitted reservation.
    """

    def __init__(self, pager: KVPager, *,
                 prefill_commit: Callable | None = None,
                 decode: Callable | None = None,
                 run_batch: Callable | None = None,
                 chunk_size: int = 16,
                 spec_decode: str | None = None,
                 spec_k: int = 4,
                 adaptive_spec_k: bool = False,
                 spec_tree: bool = False,
                 spec_tree_fanout: int = 2,
                 draft_fn: Callable | None = None,
                 ngram_max: int = 3,
                 preemption: bool = False,
                 spill_fn: Callable | None = None,
                 restore_fn: Callable | None = None):
        self.pager = pager
        self.num_slots = pager.cfg.num_slots
        self.chunked = run_batch is not None
        if self.chunked:
            if chunk_size < 1:
                raise ValueError("chunk_size must be ≥ 1")
        elif prefill_commit is None or decode is None:
            raise ValueError("need run_batch (chunked) or "
                             "prefill_commit + decode (one-shot)")
        if spec_decode not in (None, "ngram", "draft_fn"):
            raise ValueError(f"unknown spec_decode {spec_decode!r}")
        if spec_decode is not None:
            if not self.chunked:
                raise ValueError("speculative decoding requires the "
                                 "chunked (token-budget) execution path")
            if spec_k < 1:
                raise ValueError("spec_k must be ≥ 1")
            if spec_decode == "draft_fn" and draft_fn is None:
                raise ValueError("spec_decode='draft_fn' needs a draft_fn")
        if spec_tree:
            if spec_decode is None:
                raise ValueError("spec_tree needs a drafter "
                                 "(spec_decode='ngram' or 'draft_fn')")
            if spec_tree_fanout < 1:
                raise ValueError("spec_tree_fanout must be ≥ 1")
        self._run_batch = run_batch
        self._prefill_commit = prefill_commit
        self._decode = decode
        self.chunk_size = chunk_size
        self.spec_decode = spec_decode
        self.spec_k = spec_k              # max draft length (static cap)
        self._draft_fn = draft_fn
        self.ngram_max = ngram_max
        # adaptive draft length: walk spec_k_cur through the bucket family
        # {1, 2, 4, …, spec_k} from an EMA of the measured per-step
        # acceptance fraction — a drafter that keeps missing stops paying
        # k wasted verify positions per row; one that keeps hitting earns
        # its full width back. The verify dispatch always materializes
        # spec_k + 1 logits (static shape), so adapting k only changes
        # the packed row widths, never the compiled family.
        self.adaptive_spec_k = adaptive_spec_k
        self.spec_k_cur = spec_k
        self._k_buckets = spec_k_buckets(spec_k)
        self._accept_ema: float | None = None
        # tree speculation: drafts become (token, parent) node lists, the
        # verify row carries the whole tree at contiguous KV slots, and
        # the executor's device-side walk returns the deepest accepted
        # path. Adaptive shape: ``fanout_cur`` GROWS when acceptance is
        # low (alternates hedge a missing primary chain) and shrinks back
        # toward 1 when the chain keeps hitting (depth then earns more of
        # the node budget via ``spec_k_cur``).
        self.spec_tree = spec_tree
        self.spec_tree_fanout = spec_tree_fanout
        self.fanout_cur = min(spec_tree_fanout, 2) if spec_tree else 1
        self.width_buckets = width_family(
            chunk_size, spec_k if spec_decode is not None else 0)
        if preemption and not self.chunked:
            raise ValueError("preemption requires the chunked "
                             "(token-budget) execution path")
        if pager.cfg.optimistic and not preemption:
            raise ValueError("optimistic admission needs preemption as "
                             "its safety valve (extend can fail)")
        self.preemption = preemption
        # engine hooks moving page bytes across the device↔host tier:
        # spill_fn(phys_ids) → opaque handle (gather BEFORE the pager
        # releases the pages); restore_fn(handle, fresh_ids) scatters the
        # bytes into the freshly drawn pages. None ⇒ host-accounting-only
        # (fake-executor tests).
        self._spill_fn = spill_fn
        self._restore_fn = restore_fn
        self.queue: deque[Request] = deque()
        self.slots: dict[int, _SlotState] = {}
        self.preempted: list[_Preempted] = []
        self._preempt_seq = 0
        self.finished: dict[int, np.ndarray] = {}
        self.stats = SchedulerStats()
        # disaggregated serving (see serving.disagg): rids whose first
        # sampled token PARKS the slot for a cross-engine KV handoff
        # instead of decoding here. Parked slots leave `self.slots` but
        # keep their pager pages until the controller exports + frees
        # them; they surface in `ready_handoffs` as (state, slot).
        self.handoff_rids: set[int] = set()
        self.ready_handoffs: list[tuple[_SlotState, int]] = []

    # ------------------------------------------------------------------ api
    def submit(self, request: Request) -> None:
        if len(request.tokens) < 1:
            raise ValueError("empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be ≥ 1")
        # reject requests that could never be placed even on an idle engine —
        # otherwise they sit at the queue head forever and stall everything
        if not self.pager.fits(len(request.tokens), request.max_new_tokens):
            pc = self.pager.cfg
            raise ValueError(
                f"request rid={request.rid} exceeds engine capacity: "
                f"{len(request.tokens) + request.max_new_tokens - 1} KV "
                f"tokens vs slot capacity "
                f"{pc.pages_per_slot * pc.page_size} "
                f"({pc.num_pages - 1} usable pages)")
        # priority-ordered queue: insert before the first strictly-lower
        # class; equal priorities keep FIFO order (plain append)
        i = len(self.queue)
        while i > 0 and self.queue[i - 1].priority < request.priority:
            i -= 1
        self.queue.insert(i, request)

    def admit_handoff(self, request: Request, generated: list[int],
                      record) -> tuple[int, list[int], list[int]]:
        """Adopt a cross-engine KV handoff as an already-decoding slot.

        The pager re-places the shipped pages in this pool (aliasing any
        the prefix index already holds — see `KVPager.adopt`) and the
        slot enters with the prompt fully committed and ``generated``
        already sampled by the prefill side, so **no prefill chunk is
        ever scheduled for it**: decode-side TTFT is pure transfer cost.
        Returns ``(slot, strip_indices, fresh_pages)``; the engine
        scatters wire strip ``strip_indices[j]`` into ``fresh_pages[j]``.
        Raises `PageAllocationError` (no mutation) when the pool is full
        — the caller retries on a later step.
        """
        if not self.chunked:
            raise ValueError("handoff adoption requires the chunked "
                             "(token-budget) execution path")
        generated = [int(t) for t in generated]
        if not generated:
            raise ValueError("a handoff must carry the first sampled token")
        slot, scatter = self.pager.adopt(
            record, max_new_tokens=request.max_new_tokens)
        st = _SlotState(request=request, generated=generated,
                        committed=len(request.tokens))
        if st.done:
            # nothing left to decode — the prefill side should have
            # finished it there; undo the placement and refuse
            self.pager.free_slot(slot)
            raise ValueError("handoff request is already complete — "
                             "collect it on the prefill side")
        self.slots[slot] = st
        self.stats.admitted += 1
        self.stats.prefill_tokens_skipped += len(request.tokens)
        return slot, [i for i, _ in scatter], [pg for _, pg in scatter]

    @property
    def num_active(self) -> int:
        return len(self.slots)

    @property
    def idle(self) -> bool:
        return (not self.queue and not self.slots and not self.preempted
                and not self.ready_handoffs)

    def step(self) -> list[tuple[int, int]]:
        """Admit → one dispatch over all slots → evict + backfill.

        Returns ``(rid, token)`` stream events in emission order.
        """
        events: list[tuple[int, int]] = []
        self._admit(events)
        if self.slots:
            if self.chunked:
                self._step_chunked(events)
            else:
                self._decode_once(events)
            self._admit(events)          # backfill slots freed by EOS now
        return events

    def run(self) -> dict[int, np.ndarray]:
        """Drain queue + slots + parked requests; returns {rid: tokens}."""
        while not self.idle:
            before = (len(self.slots), len(self.preempted), len(self.queue))
            events = self.step()
            if not self.slots and not events and before == (
                    len(self.slots), len(self.preempted), len(self.queue)):
                raise RuntimeError(
                    "scheduler wedged: parked/queued requests cannot be "
                    "placed (pool exhausted by pins or kept shared pages)")
        out, self.finished = self.finished, {}
        return out

    # ------------------------------------------------------------ admission
    def _admit(self, events: list[tuple[int, int]]) -> None:
        """Place work on free slots, strictly by priority.

        Parked (preempted) requests take precedence over the queue within
        a priority class — they hold committed KV, so restoring them
        first minimizes both host-tier residency and wasted pool work.
        When the next candidate cannot be placed and preemption is on, a
        strictly-lower-priority victim is spilled and placement retried;
        candidates of lower priority never leapfrog a stalled higher one.
        """
        while True:
            cand = min(self.preempted,
                       key=lambda p: (-p.state.request.priority, p.seq)) \
                if self.preempted else None
            head = self.queue[0] if self.queue else None
            if cand is not None and (
                    head is None
                    or cand.state.request.priority >= head.priority):
                if self._try_restore(cand):
                    continue
                if self.preemption and self._preempt_one(
                        below=cand.state.request.priority):
                    continue
                return
            if head is None:
                return
            req = head
            # chunked mode registers a prefix on its final chunk; while a
            # slot with the same namespace is still prefilling, hold the
            # queue head so the follower admits against the full
            # registered match instead of racing it to zero sharing
            if (self.chunked and req.prefix_id is not None
                    and any(st.prefilling
                            and st.request.prefix_id == req.prefix_id
                            for st in self.slots.values())):
                return
            # prefix detection at admission: requests that opted in
            # (prefix_id set) alias any already-resident full pages whose
            # content-hash chain matches their prompt — those pages don't
            # count against free capacity
            shared = (self.pager.match_prefix(req.tokens, req.prefix_id)
                      if req.prefix_id is not None else [])
            if not self.pager.can_admit(len(req.tokens), req.max_new_tokens,
                                        n_shared=len(shared)):
                if self.preemption and self._preempt_one(below=req.priority):
                    continue
                return
            self._admit_head(req, shared, events)

    def _admit_head(self, req: Request, shared: list[int],
                    events: list[tuple[int, int]]) -> None:
        assert self.queue.popleft() is req
        slot, pages = self.pager.alloc_slot(len(req.tokens),
                                            req.max_new_tokens,
                                            shared_pages=shared)
        self.stats.prefix_shared_pages += len(shared)
        self.stats.admitted += 1
        if self.chunked:
            # aliased tokens are already resident: chunking starts past
            # them (at least the final prompt token always runs, so the
            # first-token logits exist even for a fully aliased prompt)
            skip = min(len(shared) * self.pager.cfg.page_size,
                       len(req.tokens) - 1)
            self.slots[slot] = _SlotState(request=req, generated=[],
                                          committed=skip)
            self.stats.prefill_tokens_skipped += skip
            return
        # one-shot: fused prefill + commit + first-token sample now
        tok = int(self._prefill_commit(req, slot, pages, len(shared)))
        if req.prefix_id is not None:
            self.pager.register_prefix(slot, req.tokens, req.prefix_id)
        st = _SlotState(request=req, generated=[tok],
                        committed=len(req.tokens))
        self.slots[slot] = st
        events.append((req.rid, tok))
        if st.done:
            self._finish(slot)

    # ------------------------------------------------- preemption machinery
    def _spill_slot(self, slot: int, *, pressure: bool = False) -> None:
        """Evict an active slot to the host tier, parking its state.

        Order matters: the engine's ``spill_fn`` gathers the evicted
        pages' bytes off the device BEFORE `KVPager.spill` releases those
        pages for reuse — JAX's functional arrays make the gathered value
        immune to later cache updates, so the copy may complete
        asynchronously while decode keeps dispatching.
        """
        st = self.slots.pop(slot)
        ids = self.pager.peek_spill(slot)
        handle = self._spill_fn(ids) \
            if (self._spill_fn is not None and ids) else None
        rec = self.pager.spill(slot)
        assert len(rec.spilled_pages) == len(ids)
        self.preempted.append(_Preempted(state=st, record=rec,
                                         handle=handle,
                                         seq=self._preempt_seq))
        self._preempt_seq += 1
        self.stats.preemptions += 1
        self.stats.spilled_pages += len(ids)
        if pressure:
            self.stats.pressure_spills += 1

    def _pick_victim(self, *, below: int | None,
                     keep_one: bool = False) -> int | None:
        """Victim choice: lowest priority, then most pages held (frees the
        most pool), then least progress (closest-to-done slots finish and
        free everything anyway). ``below`` restricts to strictly lower
        classes; ``keep_one`` never empties the active set (pressure
        relief must leave a slot to make progress)."""
        cand = [
            (st.request.priority, -len(self.pager.slot_pages[slot]),
             len(st.generated) / st.request.max_new_tokens, slot)
            for slot, st in self.slots.items()
            if below is None or st.request.priority < below]
        if not cand or (keep_one and len(self.slots) <= 1):
            return None
        return min(cand)[-1]

    def _preempt_one(self, *, below: int) -> bool:
        victim = self._pick_victim(below=below)
        if victim is None:
            return False
        self._spill_slot(victim)
        return True

    def _try_restore(self, p: _Preempted) -> bool:
        """Re-admit a parked request if capacity allows: pager restore,
        then the engine scatters the host-tier bytes into the fresh
        pages. The slot resumes exactly where it was spilled — the commit
        watermark came back with the record, so nothing re-prefills."""
        if not self.pager.can_restore(p.record):
            return False
        t0 = time.perf_counter()
        slot, fresh = self.pager.restore(p.record)
        if self._restore_fn is not None and p.handle is not None:
            self._restore_fn(p.handle, fresh)
        self.stats.restore_time_s += time.perf_counter() - t0
        self.stats.restores += 1
        self.stats.restored_pages += len(fresh)
        self.slots[slot] = p.state
        self.preempted.remove(p)
        return True

    def _relieve_pressure(self, drafts: dict[int, list[int]]) -> None:
        """Optimistic admission's safety valve, run before packing a
        chunked step: if the decode/verify extends this step will draw
        more pages than the free pool holds, spill victims (any class —
        pool pressure outranks SLO) until the step fits. Victims lose
        their draft proposals along with their row."""
        if not self.pager.cfg.optimistic:
            return
        pager = self.pager
        while True:
            need = 0
            for slot, st in self.slots.items():
                if st.prefilling:
                    continue
                n = 1 + len(drafts.get(slot, ()))
                short = (pager.pages_for(st.next_pos + n)
                         - len(pager.slot_pages[slot]))
                if short > 0:
                    need += max(0, short - pager.slot_reserved.get(slot, 0))
            if need <= len(pager.free_pages) - pager._reserved:
                return
            victim = self._pick_victim(below=None, keep_one=True)
            if victim is None:
                return      # last slot: fits() guarantees the pool covers it
            drafts.pop(victim, None)
            self._spill_slot(victim, pressure=True)

    def preempt_request(self, rid: int) -> bool:
        """Spill the active slot serving ``rid`` (test/ops hook; organic
        preemption is priority-driven). Returns False when ``rid`` is not
        currently on a slot (queued, parked, finished, or unknown)."""
        if not self.preemption:
            raise ValueError("preemption is not enabled on this scheduler")
        for slot, st in self.slots.items():
            if st.request.rid == rid:
                self._spill_slot(slot)
                return True
        return False

    # ---------------------------------------------------- speculative drafts
    def _propose_drafts(self) -> dict:
        """Per decoding slot, up to ``spec_k`` draft tokens for this step.

        Draft length is capped at the slot's remaining budget minus one
        (the corrected/bonus token), so a verify run never writes KV past
        position ``prompt + max_new − 2`` — inside the reservation
        `alloc_slot` already holds, which is what keeps `extend` for
        verify runs infallible. Empty proposals fall back to plain
        decode rows.

        Under ``spec_tree`` proposals are ``[(token, parent), …]`` node
        lists (parent = node index, ``-1`` = root) with the same total
        node cap — a tree occupies one KV slot per node, so the budget
        argument is identical. A ``draft_fn`` drafter receives an extra
        trailing ``fanout`` element per request and must return node
        lists in topological order (parents before children).
        """
        tree = self.spec_tree
        out: dict = {}
        reqs: list[tuple] = []
        caps: dict[int, int] = {}
        for slot, st in self.slots.items():
            if st.prefilling:
                continue
            r = st.request
            k_eff = min(self.spec_k_cur,
                        r.max_new_tokens - len(st.generated) - 1)
            if k_eff <= 0:
                continue
            ctx = np.concatenate([r.tokens,
                                  np.asarray(st.generated, np.int32)])
            if self.spec_decode == "ngram":
                prop = (ngram_propose_tree(ctx, k_eff, self.fanout_cur,
                                           self.ngram_max) if tree
                        else ngram_propose(ctx, k_eff, self.ngram_max))
                if prop:
                    out[slot] = prop
            else:
                reqs.append((slot, r.rid, ctx, st.next_pos, k_eff,
                             self.fanout_cur) if tree
                            else (slot, r.rid, ctx, st.next_pos, k_eff))
                caps[slot] = k_eff
        if reqs:
            for slot, prop in (self._draft_fn(reqs) or {}).items():
                cap = caps.get(slot, 0)
                if tree:
                    prop = [(int(t), int(par)) for t, par in prop][:cap]
                    if any(par >= i for i, (_, par) in enumerate(prop)):
                        raise ValueError(
                            f"draft_fn returned a non-topological tree "
                            f"for slot {slot}: every parent index must "
                            f"precede its child")
                else:
                    prop = [int(t) for t in prop][:cap]
                if prop:
                    out[slot] = prop
        return out

    # ------------------------------------------- chunked (token-budget) step
    def _step_chunked(self, events: list[tuple[int, int]]) -> None:
        """One fixed-shape dispatch packing prefill chunks + token runs.

        The dispatch is a ``[num_slots, c]`` token block — the step's
        token budget. Each decoding slot takes one row holding its token
        run (the single decode token, or ``[last, d_1 … d_k]`` for a
        speculative verify run at consecutive positions); the remaining
        rows are handed to prefilling slots in admission order as
        consecutive chunks, so a lone long prompt drains the whole idle
        budget instead of one chunk per step. Rows carry their slot in
        ``row_slots`` (the executor gathers that slot's page-table row
        per dispatch row).

        Every row declares its true run length and ``c`` is the smallest
        width bucket covering the longest one (a prefilling slot wants
        ``min(chunk_size, remaining)``) — decode rows are no longer
        padded to the prefill chunk width when only a short tail chunk
        is in flight, and pure-decode steps narrow to ``c = 1`` (or the
        verify-run bucket). The compiled-variant family stays bounded at
        `width_family` × context buckets.
        """
        b = self.num_slots
        drafts = self._propose_drafts() if self.spec_decode is not None \
            else {}
        if self.preemption:
            # optimistic admission: make sure this step's extends fit the
            # free pool BEFORE packing rows (victims lose their row)
            self._relieve_pressure(drafts)
            if not self.slots:
                return
        prefilling = [s for s, st in self.slots.items() if st.prefilling]
        want = 1
        for slot, st in self.slots.items():
            if not st.prefilling:
                want = max(want, 1 + len(drafts.get(slot, ())))
        if prefilling:
            want = max(want, max(
                min(self.chunk_size,
                    len(self.slots[s].request.tokens)
                    - self.slots[s].committed) for s in prefilling))
        c = next(w for w in self.width_buckets if w >= want)
        tokens = np.zeros((b, c), np.int32)
        pos = np.full((b, c), -1, np.int32)
        row_slots = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        topks = np.zeros(b, np.int32)
        sample_idx = np.zeros(b, np.int32)
        n_draft = np.zeros(b, np.int32)
        sample_row: dict[int, int] = {}       # slot → row holding its sample
        chunk_tok: dict[int, int] = {}        # slot → prompt tokens this step
        run_q: dict[int, int] = {}            # slot → base pos of its run
        row_draft: dict[int, list] = {}       # slot → drafts in its run
        tree_rows: dict[int, tuple] = {}      # row → packed tree metadata
        row = 0
        for slot, st in self.slots.items():   # decode/verify rows first
            if st.prefilling:
                continue
            r = st.request
            d = drafts.get(slot, [])
            n = 1 + len(d)
            q = st.next_pos
            tokens[row, 0] = st.generated[-1]
            if d and self.spec_tree:
                # tree verify row: node i sits at KV slot q + 1 + i (the
                # pager's extend/truncate stay contiguous), its LOGICAL
                # position is q + depth(i) (siblings share a depth, not a
                # slot), and the ancestor closure becomes the row's
                # intra-chunk visibility block
                tokens[row, 1:n] = [t for t, _ in d]
                dep = np.zeros(n, np.int32)
                anc = np.zeros((n, n), bool)
                anc[0, 0] = True
                par_inrow = np.full(n, -1, np.int32)
                for i, (_t, par) in enumerate(d):
                    j = 1 + i
                    pj = 1 + par if par >= 0 else 0
                    par_inrow[j] = pj
                    dep[j] = dep[pj] + 1
                    anc[j] = anc[pj]
                    anc[j, j] = True
                tree_rows[row] = (n, q, dep, anc, par_inrow)
            elif d:
                tokens[row, 1:n] = d
            pos[row, :n] = np.arange(q, q + n)
            row_slots[row] = slot
            self.pager.extend(slot, q + n)
            sample_row[slot] = row
            run_q[slot] = q
            row_draft[slot] = d
            n_draft[row] = len(d)
            temps[row] = r.temperature
            topks[row] = r.top_k
            row += 1
        for slot in prefilling:               # pack chunks into free rows
            if row >= b:
                break
            st = self.slots[slot]
            r = st.request
            start = st.committed
            take = min(len(r.tokens) - start, (b - row) * c)
            done = 0
            while done < take:
                n = min(c, take - done)
                tokens[row, :n] = r.tokens[start + done:start + done + n]
                pos[row, :n] = np.arange(start + done, start + done + n)
                row_slots[row] = slot
                self.stats.prefill_chunks += 1
                done += n
                if start + done == len(r.tokens):
                    sample_row[slot] = row    # last chunk lands this step
                    sample_idx[row] = n - 1
                    temps[row] = r.temperature
                    topks[row] = r.top_k
                row += 1
            self.pager.commit_chunk(slot, start, start + take)
            chunk_tok[slot] = take
        valid = int((pos >= 0).sum())
        c_fixed = max(c, self.chunk_size) if prefilling else c
        self.stats.dispatched_positions += b * c
        self.stats.padded_positions += b * c - valid
        self.stats.padded_positions_fixed += b * c_fixed - valid
        path_arr = None
        if self.spec_decode is None:
            sampled = self._run_batch(tokens, pos, row_slots, sample_idx,
                                      temps, topks)
            fix_tok, n_acc = sampled, np.zeros(b, np.int32)
        elif tree_rows:
            # tree verify: rpos carries logical (depth) positions, amask
            # the per-row ancestor closure (plain causality elsewhere),
            # parents the in-row walk topology. The executor returns the
            # deepest accepted path as in-row node indices.
            rpos = pos.copy()
            amask = np.broadcast_to(np.tril(np.ones((c, c), bool)),
                                    (b, c, c)).copy()
            parents = np.full((b, c), -1, np.int32)
            for trow, (n, q, dep, anc, par_inrow) in tree_rows.items():
                rpos[trow, :n] = q + dep
                amask[trow] = False
                amask[trow, :n, :n] = anc
                parents[trow, :n] = par_inrow
            fix_tok, n_acc, path_arr = self._run_batch(
                tokens, pos, row_slots, sample_idx, temps, topks,
                n_draft=n_draft,
                tree={"rpos": rpos, "amask": amask, "parents": parents})
        else:
            fix_tok, n_acc = self._run_batch(tokens, pos, row_slots,
                                             sample_idx, temps, topks,
                                             n_draft=n_draft)
        self.stats.decode_steps += 1
        self.stats.slot_steps += b
        step_drafted = step_accepted = 0
        for slot in list(self.slots):
            st = self.slots[slot]
            if slot in chunk_tok:
                st.committed += chunk_tok[slot]
                self.stats.prefill_tokens += chunk_tok[slot]
            row = sample_row.get(slot)
            if row is None or st.prefilling:
                continue                      # mid-prefill: nothing sampled
            first = slot in chunk_tok         # prompt completed this step
            if first and st.request.prefix_id is not None:
                # register on the final chunk: the whole prompt is resident
                self.pager.register_prefix(slot, st.request.tokens,
                                           st.request.prefix_id)
            if first:
                tok = int(fix_tok[row])
                st.generated.append(tok)
                events.append((st.request.rid, tok))
                if st.done:
                    self._finish(slot)
                elif st.request.rid in self.handoff_rids:
                    # disagg handoff point: the prompt's KV is fully
                    # committed and the first token is sampled — park the
                    # slot for export instead of decoding here. The pager
                    # slot stays live (pages intact) until the controller
                    # gathers its bytes and frees it.
                    self.slots.pop(slot)
                    self.ready_handoffs.append((st, slot))
                continue
            # decode / verify row: emit the accepted draft prefix plus the
            # corrected (rejection) or bonus (full-acceptance) token,
            # stopping at EOS / budget mid-run. Tree rows read the
            # accepted tokens off the returned path (in-row node indices,
            # deepest accepted branch); linear rows off the draft prefix.
            d = row_draft.get(slot, [])
            na = min(int(n_acc[row]), len(d))
            if self.spec_tree and d:
                emit = [d[int(path_arr[row, t]) - 1][0] for t in range(na)]
            else:
                emit = d[:na]
            for tok in emit + [int(fix_tok[row])]:
                st.generated.append(tok)
                events.append((st.request.rid, tok))
                self.stats.slot_tokens += 1
                if st.done:
                    break
            if d:
                self.stats.spec_rows += 1
                self.stats.draft_tokens += len(d)
                self.stats.accepted_tokens += na
                step_drafted += len(d)
                step_accepted += na
            if st.done:
                self._finish(slot)
            elif na < len(d):
                # rejected suffix: roll the KV watermark (and any pages
                # drawn for it) back so the cache matches the stream
                self.stats.rollbacks += 1
                self.stats.rollback_pages += self.pager.truncate(
                    slot, run_q[slot] + na + 1)
        if self.adaptive_spec_k and step_drafted:
            self._adapt_spec_k(step_accepted / step_drafted)

    # EMA half-life of one drafting step; hysteresis band so k doesn't
    # flap on a borderline drafter (one bucket move per step, at most)
    _EMA_ALPHA = 0.5
    _SHRINK_BELOW = 0.35
    _GROW_ABOVE = 0.65

    def _adapt_spec_k(self, frac: float) -> None:
        """Fold one step's acceptance fraction into the EMA and move
        ``spec_k_cur`` one bucket within {1, 2, 4, …, spec_k}."""
        a = self._EMA_ALPHA
        self._accept_ema = frac if self._accept_ema is None \
            else (1 - a) * self._accept_ema + a * frac
        i = self._k_buckets.index(self.spec_k_cur)
        if self._accept_ema < self._SHRINK_BELOW and i > 0:
            self.spec_k_cur = self._k_buckets[i - 1]
        elif self._accept_ema > self._GROW_ABOVE \
                and i + 1 < len(self._k_buckets):
            self.spec_k_cur = self._k_buckets[i + 1]
        if self.spec_tree:
            # tree shape rides the same EMA in the opposite direction:
            # a missing drafter earns more hedging (wider root fanout), a
            # hitting one hands the node budget back to chain depth
            if self._accept_ema < self._SHRINK_BELOW:
                self.fanout_cur = min(self.fanout_cur + 1,
                                      self.spec_tree_fanout)
            elif self._accept_ema > self._GROW_ABOVE:
                self.fanout_cur = max(self.fanout_cur - 1, 1)

    # ------------------------------------------------- one-shot decode step
    def _decode_once(self, events: list[tuple[int, int]]) -> None:
        b = self.num_slots
        token = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        topks = np.zeros(b, np.int32)
        for slot, st in self.slots.items():
            token[slot] = st.generated[-1]
            pos[slot] = st.next_pos
            temps[slot] = st.request.temperature
            topks[slot] = st.request.top_k
            self.pager.extend(slot, st.next_pos + 1)
        next_tokens = self._decode(self.pager.page_tables, token, pos,
                                   temps, topks)
        self.stats.decode_steps += 1
        self.stats.slot_steps += b
        for slot in list(self.slots):
            st = self.slots[slot]
            tok = int(next_tokens[slot])
            st.generated.append(tok)
            self.stats.slot_tokens += 1
            events.append((st.request.rid, tok))
            if st.done:
                self._finish(slot)

    def _finish(self, slot: int) -> None:
        st = self.slots.pop(slot)
        self.pager.free_slot(slot)
        self.finished[st.request.rid] = np.asarray(st.generated, np.int32)
        self.stats.finished += 1
