"""Paged KV cache: fixed-size pages, per-request page tables, alloc/free.

The dense decode cache sizes every request at ``max_seq`` — a 16-slot
engine at 32k context holds 512k tokens of KV even when serving 16
eight-token chats. Paging (vLLM-style, adapted to jit-stable JAX shapes)
splits KV into fixed ``page_size``-token pages drawn from a shared pool:

  * device side — per-layer pools ``[num_pages, P, Hkv, hd]`` (see
    `models.attention.init_paged_kv_cache`); decode scatters the new
    token's K/V into ``pool[table[slot, pos // P], pos % P]`` and reads by
    gathering ``pool[table[slot]]`` back into logical order. All shapes are
    fixed, so the jit'd decode step never re-specializes as requests come
    and go.
  * host side — `KVPager` owns the free list and the ``[num_slots,
    pages_per_slot]`` page tables. Pages are exclusively owned by one slot;
    **page 0 is a reserved scratch page** that inactive slots keep writing
    into, which is what lets finished rows ride along in the fixed batch.

Admission control is conservative: a request is admitted only if its
worst-case footprint (prompt + max_new − 1 tokens) can be covered by free
plus already-reserved pages, so `extend` during decode can never fail.

`commit_prefill` is the device-side bridge from a per-request dense
prefill cache (``model.prefill`` output, batch 1, seq = prompt length) into
the paged/slot caches; it is shape-polymorphic and meant to be jit'd per
prompt length by the engine.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


class PageAllocationError(RuntimeError):
    """Request cannot be placed: not enough free pages or slot capacity."""


@dataclasses.dataclass
class PagerConfig:
    num_pages: int        # total physical pages incl. the scratch page 0
    page_size: int        # tokens per page
    num_slots: int        # concurrent requests (decode batch size)
    pages_per_slot: int   # logical blocks per slot (slot capacity / P)


class KVPager:
    """Host-side page-table + free-list accounting (no device arrays)."""

    def __init__(self, cfg: PagerConfig):
        if cfg.num_pages < 2:
            raise ValueError("need ≥2 pages (page 0 is scratch)")
        self.cfg = cfg
        # LIFO free list: newly freed pages are reused first (cache-warm).
        self.free_pages: list[int] = list(range(cfg.num_pages - 1, 0, -1))
        self.free_slots: list[int] = list(range(cfg.num_slots - 1, -1, -1))
        self.page_tables = np.zeros((cfg.num_slots, cfg.pages_per_slot),
                                    np.int32)
        self.slot_pages: dict[int, list[int]] = {}
        self.slot_reserved: dict[int, int] = {}
        self.slot_len = np.zeros(cfg.num_slots, np.int64)
        self._reserved = 0   # pages promised to active slots, not yet drawn
        # bumped on every page-table mutation; lets the engine cache the
        # device copy of the tables instead of re-uploading each step
        self.version = 0

    # ------------------------------------------------------------- metrics
    @property
    def num_free_pages(self) -> int:
        return len(self.free_pages)

    @property
    def pages_in_use(self) -> int:
        return self.cfg.num_pages - 1 - len(self.free_pages)

    @property
    def num_free_slots(self) -> int:
        return len(self.free_slots)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.page_size)

    # ----------------------------------------------------------- lifecycle
    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Static check: could this request EVER be placed on an idle engine?

        Shared by `can_admit` and the scheduler's submit-time rejection so
        the two capacity rules cannot drift apart.
        """
        total = prompt_len + max_new_tokens - 1   # last token is never cached
        need = self.pages_for(total)
        return (need <= self.cfg.pages_per_slot
                and need <= self.cfg.num_pages - 1)

    def can_admit(self, prompt_len: int, max_new_tokens: int) -> bool:
        total = prompt_len + max_new_tokens - 1
        return (bool(self.free_slots)
                and self.fits(prompt_len, max_new_tokens)
                and (len(self.free_pages) - self._reserved
                     >= self.pages_for(total)))

    def alloc_slot(self, prompt_len: int, max_new_tokens: int
                   ) -> tuple[int, list[int]]:
        """Place a request: returns (slot, physical pages for the prompt).

        Reserves (but does not draw) the pages decode will need, so later
        `extend` calls cannot fail.
        """
        if not self.can_admit(prompt_len, max_new_tokens):
            raise PageAllocationError(
                f"cannot admit prompt_len={prompt_len} "
                f"max_new={max_new_tokens}: free_slots={len(self.free_slots)}"
                f" free_pages={len(self.free_pages)} reserved={self._reserved}")
        slot = self.free_slots.pop()
        total = self.pages_for(prompt_len + max_new_tokens - 1)
        now = self.pages_for(prompt_len)
        pages = [self.free_pages.pop() for _ in range(now)]
        self.slot_pages[slot] = pages
        self.page_tables[slot, :now] = pages
        self.version += 1
        self.slot_reserved[slot] = total - now
        self._reserved += total - now
        self.slot_len[slot] = prompt_len
        return slot, pages

    def extend(self, slot: int, new_len: int) -> None:
        """Grow a slot's mapping to cover ``new_len`` tokens (from reserve)."""
        pages = self.slot_pages[slot]
        need = self.pages_for(new_len)
        if need > self.cfg.pages_per_slot:
            raise PageAllocationError(f"slot {slot} over capacity: {new_len}")
        while len(pages) < need:
            if self.slot_reserved[slot] <= 0:
                raise PageAllocationError(
                    f"slot {slot} grew past its reservation ({new_len})")
            page = self.free_pages.pop()
            self.page_tables[slot, len(pages)] = page
            pages.append(page)
            self.version += 1
            self.slot_reserved[slot] -= 1
            self._reserved -= 1
        self.slot_len[slot] = max(int(self.slot_len[slot]), new_len)

    def free_slot(self, slot: int) -> None:
        """Return a finished request's pages + slot; resets table to scratch."""
        self.free_pages.extend(self.slot_pages.pop(slot))
        self._reserved -= self.slot_reserved.pop(slot, 0)
        self.page_tables[slot, :] = 0
        self.slot_len[slot] = 0
        self.free_slots.append(slot)
        self.version += 1


# ---------------------------------------------------------------------------
# Device-side commit: dense per-request prefill cache → paged / slot caches
# ---------------------------------------------------------------------------

def _commit_paged_leaf(pool, pre, phys_pages, page_size: int):
    """pre [L, 1, S, ...] → scatter into pool [L, num_pages, P, ...]."""
    lead = pre.shape[0]
    s = pre.shape[2]
    rest = pre.shape[3:]
    pre = pre[:, 0].astype(pool.dtype)                    # [L, S, ...]
    full = s // page_size
    rem = s % page_size
    if full:
        body = pre[:, :full * page_size].reshape(
            (lead, full, page_size) + rest)
        pool = pool.at[:, phys_pages[:full]].set(body)
    if rem:
        pool = pool.at[:, phys_pages[full], :rem].set(pre[:, full * page_size:])
    return pool


def _commit_ring_leaf(slot_cache, pre, slot):
    """pre [L, 1, S≤W, ...] → write into ring slot row [L, num_slots, W, ...].

    For S < W the prefill ring is dense (position p at ring slot p); pad
    with zeros so the whole row is overwritten — stale state from the
    slot's previous occupant must never survive reuse.
    """
    lead, _, s = pre.shape[:3]
    w = slot_cache.shape[2]
    row = pre[:, 0].astype(slot_cache.dtype)
    if s < w:
        pad = jnp.zeros((lead, w - s) + row.shape[2:], slot_cache.dtype)
        row = jnp.concatenate([row, pad], axis=1)
    return slot_cache.at[:, slot].set(row)


def commit_prefill(cache, prefill_cache, slot, phys_pages, *,
                   page_size: int):
    """Merge one request's prefill cache into the shared paged cache.

    ``cache``: `Model.init_paged_cache` pytree; ``prefill_cache``: the
    populated `Model.init_cache(1, prompt_len)` pytree; ``slot`` int32
    scalar; ``phys_pages`` [pages_for(prompt_len)] int32. Pure function —
    jit per prompt length with cache donated.
    """
    out = {}
    for seg, entry in cache.items():
        pre_entry = prefill_cache[seg]
        new_entry = {}
        for kind_key, leaves in entry.items():
            if kind_key == "kv_pool":
                new_entry[kind_key] = {
                    k: _commit_paged_leaf(leaves[k], pre_entry["kv"][k],
                                          phys_pages, page_size)
                    for k in leaves}
            elif kind_key == "kv":         # sliding-window ring, per slot
                new_entry[kind_key] = {
                    k: _commit_ring_leaf(leaves[k], pre_entry["kv"][k], slot)
                    for k in leaves}
            elif kind_key == "mla":        # dense per-slot latent cache
                new_entry[kind_key] = {
                    k: _commit_dense_leaf(leaves[k], pre_entry["mla"][k], slot)
                    for k in leaves}
            elif kind_key == "ssm":        # per-slot recurrent state
                new_entry[kind_key] = {
                    k: leaves[k].at[:, slot].set(
                        pre_entry["ssm"][k][:, 0].astype(leaves[k].dtype))
                    for k in leaves}
            else:
                raise ValueError(f"unknown cache entry {kind_key!r}")
        out[seg] = new_entry
    return out


def _commit_dense_leaf(slot_cache, pre, slot):
    """pre [L, 1, S, ...] → slot row prefix [L, num_slots, S_max, ...]."""
    s = pre.shape[2]
    return slot_cache.at[:, slot, :s].set(pre[:, 0].astype(slot_cache.dtype))
