"""Paged KV cache: fixed-size pages, per-request page tables, alloc/free,
refcounted prefix sharing.

The dense decode cache sizes every request at ``max_seq`` — a 16-slot
engine at 32k context holds 512k tokens of KV even when serving 16
eight-token chats. Paging (vLLM-style, adapted to jit-stable JAX shapes)
splits KV into fixed ``page_size``-token pages drawn from a shared pool:

  * device side — per-layer pools ``[num_pages, P, Hkv, hd]`` (see
    `models.attention.init_paged_kv_cache`); decode scatters the new
    token's K/V into ``pool[table[slot, pos // P], pos % P]`` and reads by
    gathering ``pool[table[slot]]`` back into logical order. All shapes are
    fixed, so the jit'd decode step never re-specializes as requests come
    and go. Quantized pools (``kv_quant="int8"``) store int8 codes plus
    per-(position, head) float32 scale strips ``ks``/``vs``.
  * host side — `KVPager` owns the free list, the ``[num_slots,
    pages_per_slot]`` page tables, and a per-page **refcount**. Pages are
    normally owned by one slot; prefix sharing lets several slots alias
    the same read-only full pages (see below). **Page 0 is a reserved
    scratch page** that inactive slots keep writing into, which is what
    lets finished rows ride along in the fixed batch.

Prefix sharing (refcount + content-hash index):

  * requests submitted with a ``prefix_id`` participate in sharing. The
    pager keeps a chain-hash index: the key of logical page ``i`` is
    ``sha1(key(i-1) || tokens[i*P:(i+1)*P])``, seeded with the prefix_id —
    a hit means the exact same token prefix, so the page's committed KV is
    identical and can be aliased read-only (refcount += 1).
  * only **full** pages are ever shared. The partial tail page (prefix
    tokens + the request's own tokens) is always freshly allocated and
    privately rewritten by the aliasing request — copy-on-write resolved
    at admission time, since the token ranges that could ever be written
    later (decode positions ≥ prompt_len) never land in a shared page.
  * `free_slot` decrements refcounts and returns a page to the free list
    exactly once, when its last owner releases it; the index entry dies
    with the page.

Chunked prefill (incremental commit):

  * the chunked execution path writes a prompt's KV into the pool one
    fixed-size chunk at a time (quantize-on-commit per chunk inside the
    dispatch — same per-(position, head) codec as one-shot commit, so the
    pages are bit-identical). The pager tracks a per-slot **commit
    watermark** (`commit_chunk`): chunks must extend it contiguously,
    rewrites at or below it are allowed (the fully-aliased page-aligned
    prompt re-runs its final token through identical bytes), and aliased
    shared-prefix pages seed the watermark at admission — those tokens
    are **never recomputed**, which is what turns prefix sharing from a
    memory saving into a prefill-FLOPs saving.
  * reservation accounting is unchanged: `alloc_slot` still draws the
    prompt's pages up front and reserves the decode tail, so `extend`
    during decode cannot fail regardless of how the prompt is chunked.
  * `register_prefix` runs on the final chunk, once the whole prompt is
    resident.

Speculative-decode rollback (`truncate`):

  * a verify run writes k + 1 tokens of KV ahead of the sampled stream;
    when the target model rejects a draft suffix, `truncate(slot,
    new_len)` rewinds the slot's KV watermark, returns now-empty pages to
    the free list, and re-credits them to the slot's decode reservation
    (so a rolled-back slot can always re-extend to its admitted worst
    case). Aliased, pinned, or prefix-indexed pages are never rolled
    back — rollback targets sit at decode positions past the prompt, and
    the guards make that an invariant. Rejected-draft KV left between the
    new watermark and the old one is dead by construction: reads are
    causally masked to positions ≤ the query position, and the next
    accepted token rewrites its position before anything reads it.

Cross-engine page handoff (`export_slot` / `adopt` — disaggregated
prefill/decode, see `serving.disagg`):

  * `export_slot(slot)` is a **read-only** snapshot of an active slot for
    shipping to a *different* engine's pool: the physical page ids in
    logical order (every page ships — the target pool holds none of this
    pool's bytes) plus a `HandoffRecord` carrying the slot length, the
    commit watermark, and each page's prefix-index chain key (if any).
    The source engine gathers the ids' bytes (same jit'd gather as
    `peek_spill`), then frees the slot normally — functional arrays make
    the gathered strips immune to the release.
  * `adopt(record, max_new_tokens=...)` re-places the request in THIS
    pool: fresh physical pages are drawn for the shipped strips and the
    slot enters fully committed (decode resumes with **zero prefill
    recompute**). Pages whose chain key is already in this pool's prefix
    index are **aliased instead of transferred** (refcount += 1, zero
    wire bytes — the content hash guarantees identical bytes), and
    freshly transferred indexed pages re-register here exactly once, so
    a hot prefix is never duplicated no matter how many handoffs carry
    it; the sticky-pin semantics of `register_prefix` apply. Raises
    `PageAllocationError` without mutating anything when capacity is
    short — the caller retries later.

Cross-burst prefix pinning: `pin_prefix(prefix_id)` takes a refcount on
every page indexed under that namespace (and on pages registered under
it later), so a hot prefix survives its last owning request and the next
burst aliases it without recomputing — `unpin_prefix` releases the pin,
returning pages to the free list exactly once when no request holds them
either.

Admission control is conservative by default: a request is admitted only
if its worst-case footprint (prompt + max_new − 1 tokens, minus aliased
pages) can be covered by free plus already-reserved pages, so `extend`
during decode can never fail. With ``PagerConfig.optimistic`` the
reservation is dropped: admission only requires the prompt's pages (plus
one page of headroom) and `extend` draws straight from the free pool —
steady-state occupancy rises, and the scheduler's preemption + spill
machinery is the safety valve when the pool runs dry.

Preemption spill/restore (`spill` / `restore`):

  * `spill(slot)` evicts an active slot to a **host-memory tier**: pages
    the slot owns exclusively (refcount 1, not prefix-indexed) are
    released to the free list — the engine gathers their bytes to host
    first via `peek_spill` — while aliased/pinned/prefix-indexed pages
    are **never spilled**: they stay resident and shareable, with the
    returned `SpillRecord` holding the slot's refcount on them. The
    record also carries the slot's commit watermark, length and decode
    reservation, so a restore is a re-admission that skips prefill
    entirely.
  * `restore(record)` re-places the request in a (possibly different)
    free slot: fresh physical pages are drawn for the spilled logical
    pages (the engine scatters the host bytes back), kept pages reattach
    with their refcount transferred back, and the watermark/reservation
    come back exactly as spilled. Raises `PageAllocationError` without
    mutating anything when capacity is short — the caller retries later.
  * spill/truncate/free are mutually safe: a spilled slot is inactive,
    so `truncate`/`free_slot`/`commit_chunk`/`extend` on it raise before
    mutating (same hardening as the refcount-underflow guards), a
    double `spill` raises, and a `restore` of an already-restored or
    dropped record raises.

`commit_prefill` is the device-side bridge from a per-request dense
prefill cache (``model.prefill`` output, batch 1, seq = prompt length) into
the paged/slot caches; it is shape-polymorphic and meant to be jit'd per
(prompt length, shared-page count) by the engine. When the pool is int8
but the prefill cache is float, K/V are **quantized on commit**; aliased
prefix pages are skipped (``start_page``).
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax.numpy as jnp
import numpy as np


class PageAllocationError(RuntimeError):
    """Request cannot be placed: not enough free pages or slot capacity."""


@dataclasses.dataclass
class PagerConfig:
    num_pages: int        # total physical pages incl. the scratch page 0
    page_size: int        # tokens per page
    num_slots: int        # concurrent requests (decode batch size)
    pages_per_slot: int   # logical blocks per slot (slot capacity / P)
    # optimistic admission: admit on the prompt's pages alone (no decode
    # reservation); `extend` draws from the free pool and the scheduler's
    # preemption + spill machinery relieves pressure when it runs dry
    optimistic: bool = False


@dataclasses.dataclass(frozen=True)
class PagerStats:
    """Point-in-time occupancy snapshot of the page accounting.

    Page IDs are device-agnostic, so this is also the whole truth for a
    mesh-sharded engine — a physical page is striped across devices, but
    it is still ONE page here.
    """
    pages_total: int      # physical pages incl. the scratch page 0
    pages_free: int
    pages_used: int       # drawn from the pool (aliased pages count once)
    pages_aliased: int    # physical pages with more than one owner
    pages_pinned: int     # pages held resident by a pin_prefix namespace
    pages_reserved: int   # promised to active slots, not yet drawn
    logical_pages: int    # per-slot mappings (aliased count per owner)
    slots_active: int
    slots_free: int
    pages_spilled: int = 0   # logical pages parked in the host tier
    spill_records: int = 0   # preempted requests awaiting restore


@dataclasses.dataclass
class SpillRecord:
    """Host-tier image of one preempted slot's page accounting.

    ``layout`` preserves the slot's logical page order: ``("spilled", i)``
    entries point into the host-tier byte strips (``i`` is the gather
    order the engine used for `peek_spill`), ``("kept", pg)`` entries are
    aliased/pinned/prefix-indexed physical pages that never left the
    device — the record holds the slot's refcount on them, so they stay
    resident and shareable while the request is parked.
    """
    spill_id: int
    layout: list[tuple[str, int]]
    spilled_pages: list[int]   # original physical ids, gather order (dead
                               # after spill — bytes live in the host tier)
    slot_len: int              # tokens of valid KV at spill time
    committed: int             # chunked-prefill commit watermark
    reserved: int              # decode-tail reservation to re-take on restore
    restored: bool = False

    @property
    def n_spilled(self) -> int:
        return len(self.spilled_pages)


@dataclasses.dataclass
class HandoffRecord:
    """Pool-independent image of one slot for a cross-engine KV handoff
    (disaggregated prefill → decode, see `serving.disagg`).

    Unlike `SpillRecord` this carries no physical page ids — those are
    meaningless in the adopting pool. Per logical page it ships the
    prefix-index chain key + namespace (or None for unindexed pages) so
    the adopter can alias pages it already holds and re-register the
    rest, plus the slot length / commit watermark that make re-admission
    a pure decode resume (zero prefill recompute).
    """
    n_pages: int                                  # logical pages shipped
    page_meta: list[tuple[bytes, bytes] | None]   # (chain key, ns) per page
    slot_len: int                                 # tokens of valid KV
    committed: int                                # chunked-prefill watermark


def _chain_key(prev: bytes, chunk: np.ndarray) -> bytes:
    h = hashlib.sha1(prev)
    h.update(np.ascontiguousarray(chunk, np.int32).tobytes())
    return h.digest()


class KVPager:
    """Host-side page-table + free-list + refcount accounting (no device
    arrays)."""

    def __init__(self, cfg: PagerConfig):
        if cfg.num_pages < 2:
            raise ValueError("need ≥2 pages (page 0 is scratch)")
        self.cfg = cfg
        # LIFO free list: newly freed pages are reused first (cache-warm).
        self.free_pages: list[int] = list(range(cfg.num_pages - 1, 0, -1))
        self.free_slots: list[int] = list(range(cfg.num_slots - 1, -1, -1))
        self.page_tables = np.zeros((cfg.num_slots, cfg.pages_per_slot),
                                    np.int32)
        self.slot_pages: dict[int, list[int]] = {}
        self.slot_reserved: dict[int, int] = {}
        self.slot_len = np.zeros(cfg.num_slots, np.int64)
        self._reserved = 0   # pages promised to active slots, not yet drawn
        # per-page owner count: 0 = free, 1 = exclusive, >1 = prefix-shared
        self.page_ref = np.zeros(cfg.num_pages, np.int32)
        # chain-hash → physical page holding that exact token prefix chunk
        self.prefix_index: dict[bytes, int] = {}
        self._page_key: dict[int, bytes] = {}
        # chunked prefill: per-slot count of prompt tokens whose KV is
        # resident (aliased prefix tokens count — they were committed by
        # the request that registered them)
        self.slot_committed: dict[int, int] = {}
        # cross-burst pinning: namespace key → pages the pin refcounts
        self._page_ns: dict[int, bytes] = {}
        self._pinned_ns: set[bytes] = set()
        self._pin_pages: dict[bytes, set[int]] = {}
        # preemption: spill_id → SpillRecord for requests parked in the
        # host tier (spilled, not yet restored or dropped)
        self.spill_records: dict[int, SpillRecord] = {}
        self._next_spill_id = 0
        # bumped on every page-table mutation; lets the engine cache the
        # device copy of the tables instead of re-uploading each step
        self.version = 0

    # ------------------------------------------------------------- metrics
    @property
    def num_free_pages(self) -> int:
        return len(self.free_pages)

    @property
    def pages_in_use(self) -> int:
        """Physical pages drawn from the pool (aliased pages count once)."""
        return self.cfg.num_pages - 1 - len(self.free_pages)

    @property
    def logical_pages_in_use(self) -> int:
        """Sum of per-slot mapped pages (aliased pages count per owner)."""
        return sum(len(p) for p in self.slot_pages.values())

    @property
    def shared_pages(self) -> int:
        """Physical pages currently aliased by more than one slot."""
        return int((self.page_ref > 1).sum())

    @property
    def num_free_slots(self) -> int:
        return len(self.free_slots)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.page_size)

    def stats(self) -> PagerStats:
        """Structured occupancy snapshot (the engine folds this into its
        `GenerationEngine.stats()` surface — read that, not the raw
        counters)."""
        pinned: set[int] = set()
        for pages in self._pin_pages.values():
            pinned |= pages
        return PagerStats(
            pages_total=self.cfg.num_pages,
            pages_free=len(self.free_pages),
            pages_used=self.pages_in_use,
            pages_aliased=self.shared_pages,
            pages_pinned=len(pinned),
            pages_reserved=self._reserved,
            logical_pages=self.logical_pages_in_use,
            slots_active=len(self.slot_pages),
            slots_free=len(self.free_slots),
            pages_spilled=sum(r.n_spilled
                              for r in self.spill_records.values()),
            spill_records=len(self.spill_records))

    # ----------------------------------------------------------- lifecycle
    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Static check: could this request EVER be placed on an idle engine?

        Shared by `can_admit` and the scheduler's submit-time rejection so
        the two capacity rules cannot drift apart.
        """
        total = prompt_len + max_new_tokens - 1   # last token is never cached
        need = self.pages_for(total)
        return (need <= self.cfg.pages_per_slot
                and need <= self.cfg.num_pages - 1)

    def can_admit(self, prompt_len: int, max_new_tokens: int,
                  n_shared: int = 0) -> bool:
        if not (self.free_slots and self.fits(prompt_len, max_new_tokens)):
            return False
        if self.cfg.optimistic:
            # prompt pages now + one page of decode headroom; the decode
            # tail is NOT reserved — extend draws from the free pool and
            # preemption spills a victim when it runs dry
            need = self.pages_for(prompt_len) - n_shared
            if max_new_tokens > 1:
                need += 1
        else:
            total = prompt_len + max_new_tokens - 1
            need = self.pages_for(total) - n_shared
        return len(self.free_pages) - self._reserved >= need

    # ------------------------------------------------------- prefix sharing
    def match_prefix(self, tokens, prefix_id) -> list[int]:
        """Longest chain of already-committed full pages holding ``tokens``.

        Returns the physical pages (logical order) whose content-hash chain
        matches the prompt's full-page prefix under ``prefix_id``'s
        namespace. Only full pages match — the partial tail is never shared.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        p = self.cfg.page_size
        key = repr(prefix_id).encode()
        pages: list[int] = []
        for i in range(len(tokens) // p):
            key = _chain_key(key, tokens[i * p:(i + 1) * p])
            page = self.prefix_index.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def register_prefix(self, slot: int, tokens, prefix_id) -> int:
        """Index ``slot``'s committed full-prompt pages for future sharing.

        Idempotent per chunk: pages already indexed (including ones this
        slot aliased) are left alone. Returns the number of newly indexed
        pages.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        p = self.cfg.page_size
        ns = repr(prefix_id).encode()
        key = ns
        pages = self.slot_pages[slot]
        added = 0
        for i in range(len(tokens) // p):
            key = _chain_key(key, tokens[i * p:(i + 1) * p])
            if key not in self.prefix_index:
                self.prefix_index[key] = pages[i]
                self._page_key[pages[i]] = key
                self._page_ns[pages[i]] = ns
                added += 1
                if ns in self._pinned_ns:     # sticky pin: new pages join
                    self.page_ref[pages[i]] += 1
                    self._pin_pages[ns].add(pages[i])
        return added

    def pin_prefix(self, prefix_id) -> int:
        """Keep ``prefix_id``'s indexed pages resident across bursts.

        Takes one refcount on every page currently indexed under the
        namespace — and, stickily, on pages registered under it later —
        so the prefix-index entries survive their last owning request and
        the next burst aliases them without recomputing their KV.
        Returns the number of pages pinned now. Pinned pages count as in
        use: over-pinning shrinks the admission budget, so unpin cold
        prefixes.
        """
        ns = repr(prefix_id).encode()
        self._pinned_ns.add(ns)
        held = self._pin_pages.setdefault(ns, set())
        added = 0
        for pg, page_ns in self._page_ns.items():
            if page_ns == ns and pg not in held:
                self.page_ref[pg] += 1
                held.add(pg)
                added += 1
        return added

    def unpin_prefix(self, prefix_id) -> int:
        """Release a `pin_prefix` hold; pages with no owning request left
        return to the free list (exactly once — the pin was one owner).
        Returns the number of pages whose pin was released."""
        ns = repr(prefix_id).encode()
        self._pinned_ns.discard(ns)
        pages = self._pin_pages.pop(ns, set())
        for pg in pages:
            self._release_page(pg)
        if pages:
            self.version += 1
        return len(pages)

    def _release_page(self, pg: int) -> None:
        """Drop one refcount; free the page (and its index entry) at 0.

        The underflow check runs BEFORE any mutation: a double-free (or a
        release of a never-allocated page) raises without pushing the page
        onto the free list a second time, so the free list can never hold
        duplicates that would later alias two slots to one physical page.
        """
        if self.page_ref[pg] <= 0:
            raise RuntimeError(
                f"page {pg} refcount underflow (double free?): "
                f"ref={int(self.page_ref[pg])}")
        self.page_ref[pg] -= 1
        if self.page_ref[pg] == 0:
            self.free_pages.append(pg)
            key = self._page_key.pop(pg, None)
            if key is not None:
                self.prefix_index.pop(key, None)
            self._page_ns.pop(pg, None)

    def alloc_slot(self, prompt_len: int, max_new_tokens: int,
                   shared_pages: list[int] | None = None
                   ) -> tuple[int, list[int]]:
        """Place a request: returns (slot, physical pages for the prompt).

        ``shared_pages`` (from `match_prefix`) are aliased read-only
        (refcount += 1) instead of drawn from the free list; the remainder
        is freshly allocated. Reserves (but does not draw) the pages decode
        will need, so later `extend` calls cannot fail.
        """
        shared = list(shared_pages or [])
        if not self.can_admit(prompt_len, max_new_tokens,
                              n_shared=len(shared)):
            raise PageAllocationError(
                f"cannot admit prompt_len={prompt_len} "
                f"max_new={max_new_tokens}: free_slots={len(self.free_slots)}"
                f" free_pages={len(self.free_pages)} reserved={self._reserved}")
        total = self.pages_for(prompt_len + max_new_tokens - 1)
        now = self.pages_for(prompt_len)
        # validate the alias list BEFORE mutating any state: callers catch
        # PageAllocationError for capacity rejection, so an error path must
        # not leak the popped slot or partial refcount increments
        if len(shared) > now:
            raise PageAllocationError(
                f"{len(shared)} shared pages exceed the {now}-page prompt")
        for pg in shared:
            if self.page_ref[pg] < 1:
                raise PageAllocationError(f"aliasing unowned page {pg}")
        slot = self.free_slots.pop()
        for pg in shared:
            self.page_ref[pg] += 1
        fresh = [self.free_pages.pop() for _ in range(now - len(shared))]
        for pg in fresh:
            self.page_ref[pg] = 1
        pages = shared + fresh
        self.slot_pages[slot] = pages
        self.page_tables[slot, :now] = pages
        self.version += 1
        reserve = 0 if self.cfg.optimistic else total - now
        self.slot_reserved[slot] = reserve
        self._reserved += reserve
        self.slot_len[slot] = prompt_len
        # aliased prefix pages are already-committed content: chunked
        # prefill starts past them (their tokens are never recomputed)
        self.slot_committed[slot] = len(shared) * self.cfg.page_size
        return slot, pages

    def commit_chunk(self, slot: int, start: int, end: int) -> None:
        """Record that prompt tokens ``[start, end)`` of ``slot`` are now
        resident (the chunked dispatch scatters their K/V directly into
        the slot's pages).

        Chunks must extend the commit watermark contiguously; rewriting
        at or below it is allowed (a fully-aliased page-aligned prompt
        re-runs its final token, writing identical bytes). Pages were
        drawn at admission, so a chunk can never land on an unmapped
        page — reservation accounting is untouched.
        """
        if slot not in self.slot_pages:
            raise PageAllocationError(
                f"commit_chunk on inactive slot {slot} (spilled or freed?)")
        done = self.slot_committed[slot]
        if start > done:
            raise PageAllocationError(
                f"slot {slot}: chunk [{start}, {end}) leaves a gap past "
                f"the commit watermark {done}")
        if end > len(self.slot_pages[slot]) * self.cfg.page_size:
            raise PageAllocationError(
                f"slot {slot}: chunk end {end} beyond its mapped pages")
        self.slot_committed[slot] = max(done, end)

    def extend(self, slot: int, new_len: int) -> None:
        """Grow a slot's mapping to cover ``new_len`` tokens.

        Pages come from the slot's decode reservation (conservative
        admission — cannot fail) or, under ``optimistic`` admission,
        straight from the free pool — raising `PageAllocationError` on an
        empty pool, which the scheduler's pre-dispatch pressure relief is
        there to prevent.
        """
        if slot not in self.slot_pages:
            raise PageAllocationError(
                f"extend of inactive slot {slot} (spilled or freed?)")
        pages = self.slot_pages[slot]
        need = self.pages_for(new_len)
        if need > self.cfg.pages_per_slot:
            raise PageAllocationError(f"slot {slot} over capacity: {new_len}")
        while len(pages) < need:
            from_reserve = self.slot_reserved[slot] > 0
            if not from_reserve and not (self.cfg.optimistic
                                         and self.free_pages):
                raise PageAllocationError(
                    f"slot {slot} grew past its reservation ({new_len})"
                    if not self.cfg.optimistic else
                    f"slot {slot}: free pool exhausted at {new_len} tokens "
                    f"(optimistic admission needs preemption pressure relief)")
            page = self.free_pages.pop()
            self.page_ref[page] = 1
            self.page_tables[slot, len(pages)] = page
            pages.append(page)
            self.version += 1
            if from_reserve:
                self.slot_reserved[slot] -= 1
                self._reserved -= 1
        self.slot_len[slot] = max(int(self.slot_len[slot]), new_len)

    def truncate(self, slot: int, new_len: int) -> int:
        """Rewind ``slot``'s KV watermark to ``new_len`` tokens (KV
        rollback for rejected speculative drafts).

        Pages that become wholly empty return to the free list and rejoin
        the slot's decode reservation (the pages were drawn from it by
        `extend`, so admission accounting stays exact: a rolled-back slot
        can always re-extend to its admitted worst case). Returns the
        number of pages released.

        Guards — each raises `PageAllocationError` without mutating
        anything, because a partial rollback would corrupt the free list
        or shared state:

          * the slot must be active and ``new_len`` must not grow it;
          * rollback below the committed prompt is refused (speculative
            tokens only ever live at decode positions ≥ prompt length);
          * aliased/pinned shared-prefix pages are never rolled back: a
            page with other owners (refcount > 1) or a live prefix-index
            entry stays put (free-exactly-once is preserved — in practice
            such pages sit below the prompt watermark and are unreachable
            here; the guard makes that an invariant, not an accident).
        """
        if slot not in self.slot_pages:
            raise PageAllocationError(f"truncate of inactive slot {slot}")
        cur = int(self.slot_len[slot])
        if new_len > cur:
            raise PageAllocationError(
                f"slot {slot}: truncate to {new_len} > current {cur}")
        if new_len < max(self.slot_committed.get(slot, 0), 1):
            raise PageAllocationError(
                f"slot {slot}: truncate to {new_len} below the committed "
                f"prompt watermark {self.slot_committed.get(slot, 0)}")
        pages = self.slot_pages[slot]
        keep = self.pages_for(new_len)
        for pg in pages[keep:]:      # validate BEFORE mutating any state
            if self.page_ref[pg] != 1:
                raise PageAllocationError(
                    f"slot {slot}: page {pg} has {int(self.page_ref[pg])} "
                    f"owners — aliased/pinned pages are never rolled back")
            if pg in self._page_key:
                raise PageAllocationError(
                    f"slot {slot}: page {pg} is prefix-indexed — "
                    f"registered pages are never rolled back")
        released = 0
        while len(pages) > keep:
            pg = pages.pop()
            self._release_page(pg)
            self.page_tables[slot, len(pages)] = 0
            if not self.cfg.optimistic:   # optimistic extend drew from the
                self.slot_reserved[slot] += 1   # free pool, not a reserve
                self._reserved += 1
            released += 1
        if released:
            self.version += 1
        self.slot_len[slot] = new_len
        return released

    def free_slot(self, slot: int) -> None:
        """Release a finished request: refcount-- on every mapped page; a
        page returns to the free list exactly once, when its last owner
        (request or pin) lets go (its prefix-index entry dies with it).
        Freeing a slot that is not active (double free) raises."""
        if slot not in self.slot_pages:
            raise PageAllocationError(
                f"free of inactive slot {slot} (double free?)")
        for pg in self.slot_pages.pop(slot):
            self._release_page(pg)
        self._reserved -= self.slot_reserved.pop(slot, 0)
        self.slot_committed.pop(slot, None)
        self.page_tables[slot, :] = 0
        self.slot_len[slot] = 0
        self.free_slots.append(slot)
        self.version += 1

    # ------------------------------------------------- preemption spill tier
    def _spillable(self, pg: int) -> bool:
        """A page leaves the device only if this slot is its sole owner and
        no prefix-index entry could hand it to a future request."""
        return int(self.page_ref[pg]) == 1 and pg not in self._page_key

    def peek_spill(self, slot: int) -> list[int]:
        """Physical pages `spill(slot)` WOULD move to the host tier, in
        logical order — the engine gathers their bytes off the device
        before the accounting releases them for reuse."""
        if slot not in self.slot_pages:
            raise PageAllocationError(f"spill of inactive slot {slot}")
        return [pg for pg in self.slot_pages[slot] if self._spillable(pg)]

    def spill(self, slot: int) -> SpillRecord:
        """Evict an active slot to the host tier; the slot itself frees.

        Exclusive unindexed pages return to the free list (their bytes
        must already be gathered — see `peek_spill`); aliased, pinned and
        prefix-indexed pages stay resident, with the returned record
        inheriting the slot's refcount on them so sharing keeps working
        while the request is parked. The record snapshots slot length,
        commit watermark and decode reservation for an exact restore.
        Spilling an inactive (already spilled/freed) slot raises before
        mutating anything.
        """
        if slot not in self.slot_pages:
            raise PageAllocationError(f"spill of inactive slot {slot}")
        pages = self.slot_pages.pop(slot)
        layout: list[tuple[str, int]] = []
        spilled: list[int] = []
        for pg in pages:
            if self._spillable(pg):
                layout.append(("spilled", len(spilled)))
                spilled.append(pg)
                self._release_page(pg)
            else:                       # record inherits the slot's refcount
                layout.append(("kept", pg))
        rec = SpillRecord(
            spill_id=self._next_spill_id, layout=layout,
            spilled_pages=spilled, slot_len=int(self.slot_len[slot]),
            committed=self.slot_committed.pop(slot, 0),
            reserved=self.slot_reserved.pop(slot, 0))
        self._next_spill_id += 1
        self._reserved -= rec.reserved
        self.page_tables[slot, :] = 0
        self.slot_len[slot] = 0
        self.free_slots.append(slot)
        self.spill_records[rec.spill_id] = rec
        self.version += 1
        return rec

    def can_restore(self, rec: SpillRecord) -> bool:
        """Could `restore(rec)` succeed right now? Needs a free slot,
        fresh pages for every spilled strip, the record's reservation
        back, and (optimistic mode) one page of decode headroom."""
        if rec.restored or rec.spill_id not in self.spill_records:
            return False
        need = rec.n_spilled + rec.reserved
        if self.cfg.optimistic:
            need += 1
        return (bool(self.free_slots)
                and len(self.free_pages) - self._reserved >= need)

    def restore(self, rec: SpillRecord) -> tuple[int, list[int]]:
        """Re-admit a spilled request into a (possibly different) slot.

        Returns ``(slot, fresh_pages)`` where ``fresh_pages`` are the new
        physical pages for the spilled strips in gather order — the engine
        scatters the host-tier bytes into them. Kept pages reattach with
        the record's refcount transferred back to the slot. Raises
        `PageAllocationError` without mutating anything when capacity is
        short or the record was already restored/dropped.
        """
        if rec.restored or rec.spill_id not in self.spill_records:
            raise PageAllocationError(
                f"restore of dead spill record {rec.spill_id} "
                f"(already restored or dropped)")
        if not self.can_restore(rec):
            raise PageAllocationError(
                f"cannot restore spill {rec.spill_id}: needs "
                f"{rec.n_spilled}+{rec.reserved} pages, "
                f"free={len(self.free_pages)} reserved={self._reserved} "
                f"free_slots={len(self.free_slots)}")
        slot = self.free_slots.pop()
        fresh = [self.free_pages.pop() for _ in range(rec.n_spilled)]
        for pg in fresh:
            self.page_ref[pg] = 1
        pages = [fresh[ref] if tag == "spilled" else ref
                 for tag, ref in rec.layout]
        self.slot_pages[slot] = pages
        self.page_tables[slot, :len(pages)] = pages
        self.slot_len[slot] = rec.slot_len
        self.slot_committed[slot] = rec.committed
        self.slot_reserved[slot] = rec.reserved
        self._reserved += rec.reserved
        rec.restored = True
        del self.spill_records[rec.spill_id]
        self.version += 1
        return slot, fresh

    def drop_spill(self, rec: SpillRecord) -> None:
        """Abandon a parked request (cancelled while spilled): release the
        record's refcount on kept pages; host-tier bytes just die. Raises
        on a record already restored or dropped."""
        if rec.restored or rec.spill_id not in self.spill_records:
            raise PageAllocationError(
                f"drop of dead spill record {rec.spill_id}")
        for tag, ref in rec.layout:
            if tag == "kept":
                self._release_page(ref)
        rec.restored = True
        del self.spill_records[rec.spill_id]
        self.version += 1

    # -------------------------------------- cross-engine page handoff tier
    def export_slot(self, slot: int) -> tuple[HandoffRecord, list[int]]:
        """Read-only snapshot of an active slot for shipping to ANOTHER
        engine's pool (disaggregated prefill → decode handoff).

        Returns ``(record, phys_ids)`` with the physical pages in logical
        order. Every mapped page ships — unlike `peek_spill`, aliasing
        status in THIS pool is irrelevant because the target pool holds
        none of these bytes (the adopter dedups against its own prefix
        index instead, via the chain keys in the record). Nothing is
        mutated: the caller gathers the ids' bytes off the device and
        then releases the slot with the ordinary `free_slot` — the
        functional gathered arrays are immune to the release.
        """
        if slot not in self.slot_pages:
            raise PageAllocationError(f"export of inactive slot {slot}")
        pages = list(self.slot_pages[slot])
        meta: list[tuple[bytes, bytes] | None] = [
            (self._page_key[pg], self._page_ns[pg])
            if pg in self._page_key else None
            for pg in pages]
        return HandoffRecord(
            n_pages=len(pages), page_meta=meta,
            slot_len=int(self.slot_len[slot]),
            committed=self.slot_committed.get(slot, 0)), pages

    def _adopt_plan(self, rec: HandoffRecord
                    ) -> list[tuple[str, int]]:
        """Per logical page: ("alias", phys) when this pool's prefix index
        already holds the chain key, else ("fresh", strip_index)."""
        plan: list[tuple[str, int]] = []
        for i, m in enumerate(rec.page_meta):
            if m is not None and m[0] in self.prefix_index:
                plan.append(("alias", self.prefix_index[m[0]]))
            else:
                plan.append(("fresh", i))
        return plan

    def can_adopt(self, rec: HandoffRecord, max_new_tokens: int) -> bool:
        """Could `adopt(rec, ...)` succeed right now? Needs a free slot,
        fresh pages for every non-aliased strip, the decode-tail
        reservation (or optimistic headroom), and slot capacity."""
        total = max(rec.n_pages,
                    self.pages_for(rec.slot_len + max_new_tokens - 1))
        if not self.free_slots or total > self.cfg.pages_per_slot:
            return False
        n_fresh = sum(1 for tag, _ in self._adopt_plan(rec)
                      if tag == "fresh")
        if self.cfg.optimistic:
            need = n_fresh + (1 if max_new_tokens > 1 else 0)
        else:
            need = n_fresh + (total - rec.n_pages)
        return len(self.free_pages) - self._reserved >= need

    def adopt(self, rec: HandoffRecord, max_new_tokens: int
              ) -> tuple[int, list[tuple[int, int]]]:
        """Place an exported slot into THIS pool (the decode half of the
        disaggregated handoff).

        Returns ``(slot, scatter)`` where ``scatter`` is a list of
        ``(strip_index, fresh_page)`` pairs — the engine scatters those
        wire strips into the freshly drawn pages. Pages whose chain key
        is already in this pool's prefix index are **aliased** instead
        (refcount += 1, nothing scattered — the content hash guarantees
        identical bytes), and freshly scattered indexed pages re-register
        here with `register_prefix`'s sticky-pin semantics, so a hot
        prefix exists exactly once no matter how many handoffs carry it.
        The slot re-admits fully committed at the shipped watermark with
        the decode tail reserved as `alloc_slot` would — decode resumes
        with zero prefill recompute. Raises `PageAllocationError` without
        mutating anything when capacity is short (callers retry later).
        """
        if not self.can_adopt(rec, max_new_tokens):
            raise PageAllocationError(
                f"cannot adopt handoff ({rec.n_pages} pages, "
                f"slot_len={rec.slot_len}, max_new={max_new_tokens}): "
                f"free_slots={len(self.free_slots)} "
                f"free_pages={len(self.free_pages)} "
                f"reserved={self._reserved}")
        plan = self._adopt_plan(rec)
        total = max(rec.n_pages,
                    self.pages_for(rec.slot_len + max_new_tokens - 1))
        slot = self.free_slots.pop()
        pages: list[int] = []
        scatter: list[tuple[int, int]] = []
        for i, (tag, ref) in enumerate(plan):
            if tag == "alias":
                self.page_ref[ref] += 1
                pages.append(ref)
                continue
            pg = self.free_pages.pop()
            self.page_ref[pg] = 1
            pages.append(pg)
            scatter.append((i, pg))
            m = rec.page_meta[i]
            if m is not None:
                key, ns = m
                # first carrier of this prefix chunk registers it here;
                # later handoffs (and match_prefix admissions) alias it
                self.prefix_index[key] = pg
                self._page_key[pg] = key
                self._page_ns[pg] = ns
                if ns in self._pinned_ns:   # sticky pin: new pages join
                    self.page_ref[pg] += 1
                    self._pin_pages.setdefault(ns, set()).add(pg)
        self.slot_pages[slot] = pages
        self.page_tables[slot, :len(pages)] = pages
        self.slot_len[slot] = rec.slot_len
        self.slot_committed[slot] = rec.committed
        reserve = 0 if self.cfg.optimistic else total - rec.n_pages
        self.slot_reserved[slot] = reserve
        self._reserved += reserve
        self.version += 1
        return slot, scatter

    # ---------------------------------------------------------- invariants
    def verify_invariants(self) -> None:
        """Assert the global accounting invariants (test/debug hook; the
        property-based harness calls this after every rule).

        Checks: free-exactly-once (no duplicate free-list entries, free ⟺
        refcount 0), refcount conservation (every page's refcount equals
        its owner count across slots + pins + spill records' kept pages),
        reservation consistency, page-table mirrors, and watermark/length
        bounds per slot.
        """
        cfg = self.cfg
        free = set(self.free_pages)
        assert len(free) == len(self.free_pages), "free list holds duplicates"
        assert 0 not in free, "scratch page 0 on the free list"
        expected = np.zeros(cfg.num_pages, np.int64)
        for pages in self.slot_pages.values():
            for pg in pages:
                expected[pg] += 1
        for held in self._pin_pages.values():
            for pg in held:
                expected[pg] += 1
        for rec in self.spill_records.values():
            for tag, ref in rec.layout:
                if tag == "kept":
                    expected[ref] += 1
        for pg in range(1, cfg.num_pages):
            ref = int(self.page_ref[pg])
            assert ref == expected[pg], (
                f"page {pg}: refcount {ref} != owner count {expected[pg]}")
            assert (pg in free) == (ref == 0), (
                f"page {pg}: free-list membership disagrees with ref {ref}")
        assert self.pages_in_use == cfg.num_pages - 1 - len(free)
        assert self._reserved == sum(self.slot_reserved.values()) >= 0
        if not cfg.optimistic:
            assert len(free) >= self._reserved, "reservation not backed"
        active = set(self.slot_pages)
        assert active.isdisjoint(self.free_slots)
        assert len(self.free_slots) == len(set(self.free_slots))
        assert sorted(active | set(self.free_slots)) == \
            list(range(cfg.num_slots))
        for slot, pages in self.slot_pages.items():
            n = len(pages)
            assert n <= cfg.pages_per_slot
            cover = max(int(self.slot_len[slot]),
                        self.slot_committed.get(slot, 0))
            assert self.pages_for(cover) <= n, (
                f"slot {slot}: {cover} tokens not covered by {n} pages")
            assert list(self.page_tables[slot, :n]) == pages
            assert not self.page_tables[slot, n:].any()
        for slot in self.free_slots:
            assert not self.page_tables[slot].any()
            assert int(self.slot_len[slot]) == 0


# ---------------------------------------------------------------------------
# Device-side commit: dense per-request prefill cache → paged / slot caches
# ---------------------------------------------------------------------------

def _commit_paged_leaf(pool, pre, phys_pages, page_size: int,
                       start_page: int = 0):
    """pre [L, 1, S, ...] → scatter into pool [L, num_pages, P, ...].

    ``start_page`` skips the leading aliased prefix pages: their content is
    already in the pool (committed by the request that registered the
    prefix) and they may be shared read-only with other slots.
    """
    lead = pre.shape[0]
    s = pre.shape[2]
    rest = pre.shape[3:]
    skip = start_page * page_size
    if skip >= s:
        return pool
    pre = pre[:, 0, skip:].astype(pool.dtype)             # [L, S - skip, ...]
    n = s - skip
    pages = phys_pages[start_page:]
    full = n // page_size
    rem = n % page_size
    if full:
        body = pre[:, :full * page_size].reshape(
            (lead, full, page_size) + rest)
        pool = pool.at[:, pages[:full]].set(body)
    if rem:
        pool = pool.at[:, pages[full], :rem].set(pre[:, full * page_size:])
    return pool


def _commit_ring_leaf(slot_cache, pre, slot):
    """pre [L, 1, S≤W, ...] → write into ring slot row [L, num_slots, W, ...].

    For S < W the prefill ring is dense (position p at ring slot p); pad
    with zeros so the whole row is overwritten — stale state from the
    slot's previous occupant must never survive reuse.
    """
    lead, _, s = pre.shape[:3]
    w = slot_cache.shape[2]
    row = pre[:, 0].astype(slot_cache.dtype)
    if s < w:
        pad = jnp.zeros((lead, w - s) + row.shape[2:], slot_cache.dtype)
        row = jnp.concatenate([row, pad], axis=1)
    return slot_cache.at[:, slot].set(row)


def _adapt_kv_quant(pre_kv: dict, pool: dict) -> dict:
    """Bridge dtype regimes between the dense prefill cache and the pool.

    * pool int8, prefill float  → **quantize on commit** (per-(pos, head)
      absmax scales, same codec as the decode write path),
    * pool float, prefill int8  → dequantize on commit,
    * matching regimes          → pass through.
    """
    from repro.models.attention import _kv_dequant, _kv_quantize
    pool_q, pre_q = "ks" in pool, "ks" in pre_kv
    if pool_q and not pre_q:
        k, ks = _kv_quantize(pre_kv["k"])
        v, vs = _kv_quantize(pre_kv["v"])
        return {"k": k, "v": v, "ks": ks, "vs": vs}
    if pre_q and not pool_q:
        return {"k": _kv_dequant(pre_kv["k"], pre_kv["ks"], pool["k"].dtype),
                "v": _kv_dequant(pre_kv["v"], pre_kv["vs"], pool["v"].dtype)}
    return pre_kv


def commit_prefill(cache, prefill_cache, slot, phys_pages, *,
                   page_size: int, start_page: int = 0):
    """Merge one request's prefill cache into the shared paged cache.

    ``cache``: `Model.init_paged_cache` pytree; ``prefill_cache``: the
    populated `Model.init_cache(1, prompt_len)` pytree; ``slot`` int32
    scalar; ``phys_pages`` [pages_for(prompt_len)] int32; ``start_page``
    static int — the first ``start_page`` pages are prefix-shared aliases
    and are not rewritten (per-slot dense state is always written). Pure
    function — jit per (prompt length, start_page) with cache donated.
    """
    out = {}
    for seg, entry in cache.items():
        pre_entry = prefill_cache[seg]
        new_entry = {}
        for kind_key, leaves in entry.items():
            if kind_key == "kv_pool":
                pre_kv = _adapt_kv_quant(pre_entry["kv"], leaves)
                new_entry[kind_key] = {
                    k: _commit_paged_leaf(leaves[k], pre_kv[k],
                                          phys_pages, page_size,
                                          start_page=start_page)
                    for k in leaves}
            elif kind_key == "kv":         # sliding-window ring, per slot
                new_entry[kind_key] = {
                    k: _commit_ring_leaf(leaves[k], pre_entry["kv"][k], slot)
                    for k in leaves}
            elif kind_key == "mla":        # dense per-slot latent cache
                new_entry[kind_key] = {
                    k: _commit_dense_leaf(leaves[k], pre_entry["mla"][k], slot)
                    for k in leaves}
            elif kind_key == "ssm":        # per-slot recurrent state
                new_entry[kind_key] = {
                    k: leaves[k].at[:, slot].set(
                        pre_entry["ssm"][k][:, 0].astype(leaves[k].dtype))
                    for k in leaves}
            else:
                raise ValueError(f"unknown cache entry {kind_key!r}")
        out[seg] = new_entry
    return out


def _commit_dense_leaf(slot_cache, pre, slot):
    """pre [L, 1, S, ...] → slot row prefix [L, num_slots, S_max, ...]."""
    s = pre.shape[2]
    return slot_cache.at[:, slot, :s].set(pre[:, 0].astype(slot_cache.dtype))
