"""Disaggregated prefill/decode serving (ROADMAP #5).

The source paper's second idea (after AWQ) is hybrid execution: route
compute-bound work to the FPGA, keep light work on the CPU. The
serving-fleet analog splits the two phases of generation the same way —
prefill is compute-bound (S×ctx score work per admitted token), decode is
bandwidth-bound (full weight stream + whole cache line per emitted token)
— and runs them as SEPARATE engines with different batch shapes and,
optionally, different meshes:

  * `PrefillEngine` — a `GenerationEngine` configured for pure chunked
    prefill (prefix sharing and AWQ weights work; speculation is off —
    it never decodes). When a marked request samples its first token,
    the scheduler PARKS the slot instead of decoding, and the engine
    exports the slot's committed pages + watermark + first token as a
    `KVHandoff`: the pager snapshot (`KVPager.export_slot`) plus a jit'd
    page-strip gather (the `peek_spill` movers — int8 pools ship codes +
    scale strips, ~2× fewer wire bytes than bf16).
  * `DecodeEngine` — a full-featured `GenerationEngine` (int8 KV ×
    prefix pinning × linear/tree speculation × mesh sharding) that
    ADOPTS handoffs into its own pool: fresh physical pages, scatter
    restore, and a re-admission that skips prefill entirely — the
    decode-side TTFT is pure transfer cost. Pages whose content-hash
    chain key is already in its prefix index are aliased instead of
    transferred. Because gathered strips are replicated
    (`distributed.sharding.handoff_sharding`), the wire image is
    mesh-agnostic: each side may run a *different* mesh and the adopt is
    a reshard-on-the-way-in.
  * `DisaggController` — owns both engines behind the ordinary
    `submit()/step()/collect()/drain()` API. Placement follows the
    roofline split policy (`roofline.costmodel.disagg_report`): prompts
    past the predicted convoy crossover go through the prefill engine,
    short interactive traffic is served unified-style by the decode
    engine. Each `step()` overlaps the handoff's device→host DMA with
    the decode engine's dispatch.

The unified `GenerationEngine` stays the small-deployment default —
build a controller only when the roofline report (or your own traffic)
says one long prefill convoys the decode fleet. Greedy streams through
the controller are token-identical to the unified engine
(`tests/test_disagg.py`, bench section `disagg_vs_unified`).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serving.engine import GenerationEngine, SamplerConfig
from repro.serving.kv_pager import HandoffRecord, PageAllocationError
from repro.serving.scheduler import Request

# constructor kwargs stripped from the prefill side: it parks at the
# first sampled token, so drafting/verification machinery would only
# widen its dispatches for nothing
_SPEC_KWARGS = ("spec_decode", "spec_k", "spec_ngram_max", "spec_adaptive",
                "spec_tree", "spec_tree_fanout", "draft_model",
                "draft_params", "draft_fn")


@dataclasses.dataclass
class KVHandoff:
    """One request's KV image in flight between engines.

    ``handle`` is the async device-side gather on the source engine;
    `PrefillEngine.wire` (or the controller) materializes ``strips`` —
    host numpy, mesh-agnostic, trimmed to the real page count — and the
    decode side scatters the non-aliased subset into its own pool.
    """
    request: Request             # prefill-side request (rid = source rid)
    generated: list[int]         # tokens already sampled (the first token)
    record: HandoffRecord        # pager metadata: page keys + watermark
    handle: dict | None          # async device strips (source engine)
    strips: dict | None = None   # host wire image, set by wire()
    wire_bytes: int = 0
    exported_at: float = 0.0


@dataclasses.dataclass
class DisaggStats:
    handoffs: int = 0            # requests adopted by the decode engine
    handoff_pages: int = 0       # logical pages shipped
    aliased_pages: int = 0       # shipped pages the decode pool already
                                 # held (prefix index hit — zero wire cost)
    wire_bytes: int = 0          # host-side bytes actually transferred
    adopt_time_s: float = 0.0    # wire + scatter + re-admission wall time
                                 # (the decode-side TTFT-as-transfer cost)
    direct: int = 0              # requests served whole by the decode side
    prefill_step_time_s: float = 0.0   # wall inside prefill dispatches
    decode_step_time_s: float = 0.0    # wall inside decode dispatches


class PrefillEngine:
    """The prefill half of a disaggregated pair.

    Wraps a `GenerationEngine` forced onto the chunked path with
    speculation stripped. `submit` marks every request for handoff:
    the first sampled token parks the slot, and `collect_handoffs`
    exports parked slots as `KVHandoff`s (async gather — call `wire`
    to materialize, ideally after dispatching decode-side work).
    """

    def __init__(self, model, params, *, mesh=None, **kw):
        for k in _SPEC_KWARGS:
            kw.pop(k, None)
        kw.pop("chunked_prefill", None)
        self.engine = GenerationEngine(model, params, mesh=mesh,
                                       chunked_prefill=True, **kw)

    def submit(self, tokens, max_new_tokens: int,
               sampler: SamplerConfig | None = None,
               eos_id: int | None = None, prefix_id: str | None = None,
               priority: int = 0) -> int:
        """Queue one request for prefill-then-handoff; returns its rid.

        The request carries its TRUE ``max_new_tokens`` (the decode side
        needs it, and the prefill pager reserves against it so the
        handoff can never strand an unplaceable slot) — but at most one
        token is ever decoded here: EOS-on-first-token finishes locally
        (collect it from `collect`), everything else parks for export.
        """
        rid = self.engine.submit(tokens, max_new_tokens, sampler=sampler,
                                 eos_id=eos_id, prefix_id=prefix_id,
                                 priority=priority)
        self.engine._scheduler.handoff_rids.add(rid)
        return rid

    def step(self) -> list[tuple[int, int]]:
        return self.engine.step()

    def collect(self):
        """Requests that finished HERE (EOS or budget at first token)."""
        return self.engine.collect()

    def collect_handoffs(self) -> list[KVHandoff]:
        """Export every slot parked since the last call.

        Per slot: pager snapshot, async page-strip gather, then the slot
        frees — the gathered arrays are functional, so the release can't
        corrupt them. The returned handoffs are NOT yet wired; `wire`
        blocks on the DMA.
        """
        sched = self.engine._scheduler
        if sched is None or not sched.ready_handoffs:
            return []
        out = []
        while sched.ready_handoffs:
            st, slot = sched.ready_handoffs.pop(0)
            rec, phys = sched.pager.export_slot(slot)
            handle = self.engine.handoff_gather(phys)
            sched.pager.free_slot(slot)
            sched.handoff_rids.discard(st.request.rid)
            out.append(KVHandoff(request=st.request,
                                 generated=list(st.generated),
                                 record=rec, handle=handle,
                                 exported_at=time.perf_counter()))
        return out

    def wire(self, h: KVHandoff) -> KVHandoff:
        """Materialize the host wire image (blocks on the gather DMA)."""
        if h.strips is None:
            h.strips, h.wire_bytes = self.engine.handoff_wire(h.handle)
            h.handle = None
        return h

    @property
    def idle(self) -> bool:
        return self.engine.idle

    def stats(self):
        return self.engine.stats()


class DecodeEngine:
    """The decode half: a full-featured `GenerationEngine` that adopts
    wired handoffs into its own pool and also serves ordinary requests
    (the controller routes short prompts here whole)."""

    def __init__(self, model, params, *, mesh=None, **kw):
        self.engine = GenerationEngine(model, params, mesh=mesh, **kw)

    def adopt(self, h: KVHandoff) -> tuple[int, int]:
        """Re-admit a wired handoff; returns ``(decode rid, n_fresh)``
        where ``n_fresh`` counts freshly scattered pages (the rest were
        aliased against this pool's prefix index — zero wire cost).

        The pager places the shipped pages, the engine scatters the
        non-aliased strips, and the slot resumes decoding at the shipped
        watermark — no prefill chunk is ever scheduled. Raises
        `PageAllocationError` (nothing mutated) when the pool is full;
        retry on a later step.
        """
        if h.strips is None:
            raise ValueError("handoff not wired — call PrefillEngine.wire")
        eng = self.engine
        if eng._scheduler is None:
            eng._scheduler = eng._serving_init()
        rid = eng._next_rid
        req = dataclasses.replace(h.request, rid=rid)
        slot, strip_idx, fresh = eng._scheduler.admit_handoff(
            req, h.generated, h.record)
        eng._next_rid += 1
        eng.handoff_scatter(h.strips, strip_idx, fresh)
        return rid, len(fresh)

    def submit(self, *a, **kw):
        return self.engine.submit(*a, **kw)

    def step(self) -> list[tuple[int, int]]:
        return self.engine.step()

    def collect(self):
        return self.engine.collect()

    @property
    def idle(self) -> bool:
        return self.engine.idle

    def stats(self):
        return self.engine.stats()


class DisaggController:
    """Both engines behind the ordinary engine API.

    ``handoff_min_tokens`` routes: prompts at or past it flow prefill →
    handoff → decode; shorter ones are served whole by the decode engine
    (unified-style — a transfer would cost more than it saves). The
    default ``"auto"`` takes the roofline crossover
    (`roofline.costmodel.disagg_report` at this deployment's decode
    batch and context); pass an int to pin it, ``0`` to disaggregate
    everything (tests do), or a large value to disable handoffs.

    Per-engine shape/feature kwargs come from ``**engine_kwargs`` (both
    sides) with `_SPEC_KWARGS` stripped for the prefill side;
    ``prefill_mesh`` / ``decode_mesh`` may differ — see
    `distributed.sharding.handoff_sharding` for why that works.
    """

    def __init__(self, model, params, *, prefill_mesh=None, decode_mesh=None,
                 handoff_min_tokens: int | str = "auto", **engine_kwargs):
        self.prefill = PrefillEngine(model, params, mesh=prefill_mesh,
                                     **dict(engine_kwargs))
        self.decode = DecodeEngine(model, params, mesh=decode_mesh,
                                   **dict(engine_kwargs))
        max_seq = self.decode.engine.max_seq
        self.split_report = None
        if handoff_min_tokens == "auto":
            from repro.roofline.costmodel import disagg_report
            rep = disagg_report(
                model.cfg,
                decode_batch=self.decode.engine.num_slots,
                context=max_seq,
                quant=self.decode.engine.kv_quant == "int8")
            self.split_report = rep
            cross = rep["crossover_prompt_tokens"]
            if rep["disaggregate"] and cross is not None:
                handoff_min_tokens = cross
            else:       # unified-style: no prompt pays for the transfer
                handoff_min_tokens = max_seq + 1
        self.handoff_min_tokens = int(handoff_min_tokens)
        self.stats_ = DisaggStats()
        self._next_crid = 0
        self._of_prefill: dict[int, int] = {}   # prefill rid → controller rid
        self._of_decode: dict[int, int] = {}    # decode rid → controller rid
        self._pending: list[KVHandoff] = []     # exported, not yet adopted

    # ------------------------------------------------------------------ api
    def submit(self, tokens, max_new_tokens: int,
               sampler: SamplerConfig | None = None,
               eos_id: int | None = None, prefix_id: str | None = None,
               priority: int = 0, n: int = 1) -> int | list[int]:
        """Queue a request; same contract as `GenerationEngine.submit`.

        Routing: ``n > 1`` (parallel sampling shares prompt pages, which
        only exist within one pool) and ``max_new_tokens == 1`` always go
        to the decode engine whole; otherwise prompts of at least
        ``handoff_min_tokens`` tokens take the disaggregated path.
        """
        ntok = len(np.asarray(tokens).reshape(-1))
        disagg = (n == 1 and max_new_tokens > 1
                  and ntok >= self.handoff_min_tokens)
        if disagg:
            prid = self.prefill.submit(
                tokens, max_new_tokens, sampler=sampler, eos_id=eos_id,
                prefix_id=prefix_id, priority=priority)
            crid = self._next_crid
            self._next_crid += 1
            self._of_prefill[prid] = crid
            return crid
        rids = self.decode.submit(tokens, max_new_tokens, sampler=sampler,
                                  eos_id=eos_id, prefix_id=prefix_id,
                                  priority=priority, n=n)
        self.stats_.direct += n
        out = []
        for drid in rids if n > 1 else [rids]:
            crid = self._next_crid
            self._next_crid += 1
            self._of_decode[drid] = crid
            out.append(crid)
        return out if n > 1 else out[0]

    def step(self) -> list[tuple[int, int]]:
        """One controller step → (rid, token) events, controller rids.

        Order is the transfer/compute overlap: prefill dispatch → export
        parked slots (async gather starts the device→host DMA) → decode
        dispatch (runs WHILE the DMA drains) → wire + adopt (the only
        blocking touch of the strips).
        """
        events: list[tuple[int, int]] = []
        t0 = time.perf_counter()
        for prid, tok in self.prefill.step():
            crid = self._of_prefill.get(prid)
            if crid is not None:
                events.append((crid, tok))
        self.stats_.prefill_step_time_s += time.perf_counter() - t0
        self._pending.extend(self.prefill.collect_handoffs())
        t0 = time.perf_counter()
        for drid, tok in self.decode.step():
            crid = self._of_decode.get(drid)
            if crid is not None:
                events.append((crid, tok))
        self.stats_.decode_step_time_s += time.perf_counter() - t0
        self._adopt_pending()
        return events

    def _adopt_pending(self) -> None:
        still: list[KVHandoff] = []
        for h in self._pending:
            self.prefill.wire(h)
            t0 = time.perf_counter()
            try:
                drid, n_fresh = self.decode.adopt(h)
            except PageAllocationError:
                still.append(h)     # decode pool full — retry next step
                continue
            st = self.stats_
            st.handoffs += 1
            st.handoff_pages += h.record.n_pages
            st.aliased_pages += h.record.n_pages - n_fresh
            st.wire_bytes += h.wire_bytes
            st.adopt_time_s += time.perf_counter() - t0
            self._of_decode[drid] = self._of_prefill[h.request.rid]
        self._pending = still

    def collect(self) -> dict[int, np.ndarray]:
        """Finished streams, keyed by controller rid. Streams are complete
        regardless of where the request finished: adopted slots carry the
        prefill-side first token in their generated list."""
        out: dict[int, np.ndarray] = {}
        for prid, toks in self.prefill.collect().items():
            crid = self._of_prefill.pop(prid, None)
            if crid is not None:
                out[crid] = toks
        for drid, toks in self.decode.collect().items():
            crid = self._of_decode.pop(drid, None)
            if crid is not None:
                out[crid] = toks
        return out

    def drain(self) -> dict[int, np.ndarray]:
        """Step until both engines and the handoff queue are empty."""
        out = self.collect()
        wedged = 0
        while not self.idle:
            before = (len(self._pending), self.prefill.idle,
                      self.decode.idle)
            events = self.step()
            got = self.collect()
            out.update(got)
            after = (len(self._pending), self.prefill.idle,
                     self.decode.idle)
            wedged = 0 if (events or got or before != after) else wedged + 1
            if wedged > 1000:
                raise RuntimeError(
                    "disagg controller wedged: pending handoffs cannot "
                    "be adopted (decode pool exhausted by pins?)")
        out.update(self.collect())
        return out

    @property
    def idle(self) -> bool:
        return self.prefill.idle and self.decode.idle and not self._pending

    @property
    def num_active(self) -> int:
        return (self.prefill.engine.num_active
                + self.decode.engine.num_active + len(self._pending))

    def warmup(self, sampled: bool = False) -> int:
        """Precompile both engines' dispatch families."""
        return (self.prefill.engine.warmup(sampled=sampled)
                + self.decode.engine.warmup(sampled=sampled))

    def pin_prefix(self, prefix_id: str) -> int:
        """Pin on BOTH sides: the prefill pool skips recomputing the
        prefix, the decode pool keeps its adopted copy resident so later
        handoffs alias it instead of re-shipping the bytes."""
        return (self.prefill.engine.pin_prefix(prefix_id)
                + self.decode.engine.pin_prefix(prefix_id))

    def unpin_prefix(self, prefix_id: str) -> int:
        return (self.prefill.engine.unpin_prefix(prefix_id)
                + self.decode.engine.unpin_prefix(prefix_id))

    def prefix_reuse_pages(self, tokens, prefix_id) -> int:
        """Router affinity signal: the best prefix reuse either side
        offers (a handoff aliases decode-resident pages; a direct-routed
        request aliases whichever pool it lands in)."""
        return max(
            self.prefill.engine.prefix_reuse_pages(tokens, prefix_id),
            self.decode.engine.prefix_reuse_pages(tokens, prefix_id))

    def stats(self) -> DisaggStats:
        return self.stats_

    def reset_stats(self) -> None:
        self.stats_ = DisaggStats()
        for side in (self.prefill.engine, self.decode.engine):
            if side._scheduler is not None:
                side.reset_stats()
