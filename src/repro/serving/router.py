"""Fleet router: N engine replicas behind one engine-shaped API.

One `GenerationEngine` saturates one accelerator; the roadmap's traffic
target needs N of them. `Router` owns a list of **replicas** — each a
`GenerationEngine` (optionally TP-sharded via `serving_mesh`) or a
`DisaggController` pair — and exposes the exact
``submit() / step() / collect() / drain()`` surface, so callers scale
from one engine to a fleet without changing a line.

Placement is the perf lever. Within one engine, prefix sharing already
converts duplicate prompt prefixes into aliased pages and skipped
prefill FLOPs; across a fleet that only happens if requests with the
same prefix **land on the replica holding its pages**. The prefix index
is content-addressed, so the router's cache-hit estimate is *exact*:
`GenerationEngine.prefix_reuse_pages` returns precisely the pages a
request would alias. Each `submit` scores every live replica:

  * **prefix affinity** — ``affinity_weight`` per reusable page, counted
    only when the reuse reaches ``affinity_threshold`` pages (below it a
    page or two of reuse must not override load balance);
  * **load** — ``queue_weight`` per waiting/in-flight request
    (`stats().queue_depth` + `num_active`), plus a tiny
    ``headroom_weight`` per free page (`stats().admission_headroom`) as
    a deterministic tiebreaker toward the emptier pool;
  * **SLO class** — interactive traffic (``priority > 0``) additionally
    pays ``slo_weight`` per *strictly lower-class* request already
    routed to the replica, so it never lands behind a batch-heavy
    replica when a quieter one exists (the PR 7 priority classes,
    fleet-level).

Scoring is a pure function of the observable fleet state — same state,
same request, same replica (ties break toward the lowest index) — which
is what makes placement testable.

**Session stickiness**: ``submit(..., session_id=...)`` pins the session
to the replica that served its first turn — later turns return to the
replica holding their pinned/warm pages instead of being re-scored. A
drained replica stops receiving its sessions (they re-score and re-pin);
a replica that re-joins gets its surviving sessions back.

**Elastic drain/join**: `drain_replica(i)` removes a replica from
placement, re-routes its *queued* (not-yet-admitted — they hold no
pages and have emitted nothing) requests to the rest of the fleet under
their original global request ids, and optionally steps the fleet until
the replica's in-flight requests finish — zero tokens lost or
duplicated, streams identical to an undisturbed fleet (greedy streams
are a function of the prompt alone, so re-routing never changes them).
`add_replica(...)` warms a new replica and adds it to placement — or
re-joins a previously drained one. Admitted requests stay put and
finish where they run; their committed pages could ride the PR 9
`export_slot`/`adopt` wire format to migrate mid-decode, but finishing
in place is both simpler and token-identical, so that is what ships.

`launch.specs.FleetSpec` builds a router declaratively (replica count,
mesh axis per replica, drain timeout); `benchmarks/bench_serving.py`'s
multi-replica section measures affinity-vs-random placement and gates
`router_vs_single` token identity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.engine import SamplerConfig


@dataclasses.dataclass
class RouterStats:
    """The placement ledger (fleet-level; per-replica engine metrics come
    from `Router.stats()`)."""
    placements: int = 0           # submit() calls placed by scoring
    affinity_hits: int = 0        # placements where the affinity term fired
    session_hits: int = 0         # placements short-circuited by a session
    reroutes: int = 0             # queued requests moved off a draining replica
    drains: int = 0               # drain_replica() calls
    joins: int = 0                # add_replica() calls (incl. re-joins)


class Router:
    """N replicas behind the `GenerationEngine` streaming API.

    ``replicas`` is a non-empty list of engine-shaped objects
    (`GenerationEngine` or `DisaggController`). The router never builds
    engines itself — construction stays explicit (or declarative via
    `launch.specs.FleetSpec.build`).

    ``placement`` selects the policy: ``"affinity"`` (the scored default),
    ``"round_robin"``, or ``"random"`` (seeded — the benchmark's
    placement-blind baseline). Sessions stick under every policy except
    ``"random"``, which is deliberately memoryless.
    """

    def __init__(self, replicas, *, placement: str = "affinity",
                 affinity_threshold: int = 1, affinity_weight: float = 4.0,
                 queue_weight: float = 1.0, slo_weight: float = 8.0,
                 headroom_weight: float = 1.0 / 1024.0, seed: int = 0):
        replicas = list(replicas)
        if not replicas:
            raise ValueError("Router needs at least one replica")
        if placement not in ("affinity", "round_robin", "random"):
            raise ValueError(f"unknown placement policy {placement!r}")
        if affinity_threshold < 1:
            raise ValueError("affinity_threshold must be >= 1 page")
        self._replicas = replicas
        self.placement_policy = placement
        self.affinity_threshold = affinity_threshold
        self.affinity_weight = affinity_weight
        self.queue_weight = queue_weight
        self.slo_weight = slo_weight
        self.headroom_weight = headroom_weight
        self._rng = np.random.default_rng(seed)
        self._rr_next = 0
        self._next_rid = 0
        # global rid → (replica, local rid, priority); removed on collect
        self._rid_map: dict[int, tuple[object, int, int]] = {}
        # per-replica local rid → global rid (keyed by id(replica))
        self._to_global: dict[int, dict[int, int]] = {
            id(r): {} for r in replicas}
        self._draining: set[int] = set()          # id(replica)
        self._sessions: dict[str, object] = {}    # session_id → replica
        self._finished: dict[int, np.ndarray] = {}  # from removed replicas
        self.router_stats = RouterStats()

    # ------------------------------------------------------------ placement
    @property
    def replicas(self) -> list:
        """The live fleet (placement-eligible AND draining replicas)."""
        return list(self._replicas)

    def _live_indices(self) -> list[int]:
        out = [i for i, r in enumerate(self._replicas)
               if id(r) not in self._draining]
        if not out:
            raise RuntimeError("every replica is draining — nothing can "
                               "accept placements (add_replica or re-join)")
        return out

    def _lower_class_backlog(self, rep, priority: int) -> int:
        """Unfinished requests of a strictly lower SLO class this router
        has placed on ``rep`` (the fleet-level 'batch-heavy' signal)."""
        rid_of = id(rep)
        return sum(1 for (r, _lrid, p) in self._rid_map.values()
                   if id(r) == rid_of and p < priority)

    def placement_scores(self, tokens, *, prefix_id=None,
                         priority: int = 0) -> list[float]:
        """Score every replica for this request (``-inf`` = draining).

        Pure in the fleet state: no placement, no mutation — `submit`
        calls this and takes the argmax (ties → lowest index), so the
        scores ARE the routing decision and tests can assert on them.
        """
        scores = []
        for rep in self._replicas:
            if id(rep) in self._draining:
                scores.append(float("-inf"))
                continue
            reuse = rep.prefix_reuse_pages(tokens, prefix_id)
            st = rep.stats()
            if isinstance(st, list) or not hasattr(st, "queue_depth"):
                st = None
            if st is None:     # DisaggController: per-side engine stats
                sides = (rep.prefill.engine.stats(),
                         rep.decode.engine.stats())
                queue_depth = sum(s.queue_depth for s in sides)
                headroom = sides[1].admission_headroom
            else:
                queue_depth = st.queue_depth
                headroom = st.admission_headroom
            score = 0.0
            if reuse >= self.affinity_threshold:
                score += self.affinity_weight * reuse
            score -= self.queue_weight * (queue_depth + rep.num_active)
            score += self.headroom_weight * headroom
            if priority > 0:
                score -= self.slo_weight \
                    * self._lower_class_backlog(rep, priority)
            scores.append(score)
        return scores

    def place(self, tokens, *, prefix_id=None, priority: int = 0,
              session_id: str | None = None) -> int:
        """Replica index `submit` would choose, without submitting."""
        live = self._live_indices()
        if self.placement_policy == "random":
            return live[int(self._rng.integers(len(live)))]
        if session_id is not None:
            rep = self._sessions.get(session_id)
            if rep is not None and id(rep) not in self._draining:
                for i, r in enumerate(self._replicas):
                    if r is rep:
                        return i
        if self.placement_policy == "round_robin":
            idx = live[self._rr_next % len(live)]
            return idx
        scores = self.placement_scores(tokens, prefix_id=prefix_id,
                                       priority=priority)
        best = max(scores)
        return scores.index(best)      # ties break toward the lowest index

    # ------------------------------------------------------------ streaming
    def submit(self, tokens, max_new_tokens: int,
               sampler: SamplerConfig | None = None,
               eos_id: int | None = None, prefix_id: str | None = None,
               priority: int = 0, n: int = 1,
               session_id: str | None = None) -> int | list[int]:
        """Place and queue one request; returns fleet-global rid(s).

        Same contract as `GenerationEngine.submit`, plus ``session_id``:
        multi-turn callers pass a stable id and every later turn returns
        to the replica holding the session's warm pages. ``n > 1``
        parallel-sampling siblings always land together (aliased prompt
        pages exist only within one pool).
        """
        idx = self.place(tokens, prefix_id=prefix_id, priority=priority,
                         session_id=session_id)
        rep = self._replicas[idx]
        stt = self.router_stats
        if session_id is not None and self._sessions.get(session_id) is rep \
                and self.placement_policy != "random":
            stt.session_hits += 1
        elif self.placement_policy == "affinity":
            stt.placements += 1
            if rep.prefix_reuse_pages(tokens, prefix_id) \
                    >= self.affinity_threshold:
                stt.affinity_hits += 1
        else:
            stt.placements += 1
        if self.placement_policy == "round_robin":
            self._rr_next += 1
        if session_id is not None and self.placement_policy != "random":
            self._sessions[session_id] = rep
        lrids = rep.submit(tokens, max_new_tokens, sampler=sampler,
                           eos_id=eos_id, prefix_id=prefix_id,
                           priority=priority, n=n)
        out = []
        for lrid in lrids if n > 1 else [lrids]:
            grid = self._next_rid
            self._next_rid += 1
            self._rid_map[grid] = (rep, lrid, priority)
            self._to_global[id(rep)][lrid] = grid
            out.append(grid)
        return out if n > 1 else out[0]

    def step(self) -> list[tuple[int, int]]:
        """Step every non-idle replica once (draining ones included —
        their in-flight requests must finish); merged (global rid, token)
        events in replica order, then emission order."""
        events: list[tuple[int, int]] = []
        for rep in list(self._replicas):
            if rep.idle:
                continue
            fwd = self._to_global[id(rep)]
            for lrid, tok in rep.step():
                grid = fwd.get(lrid)
                if grid is not None:
                    events.append((grid, tok))
        return events

    def collect(self) -> dict[int, np.ndarray]:
        """Finished streams accumulated so far, keyed by global rid."""
        out = dict(self._finished)
        self._finished.clear()
        for rep in self._replicas:
            fwd = self._to_global[id(rep)]
            for lrid, toks in rep.collect().items():
                grid = fwd.pop(lrid, None)
                if grid is not None:
                    out[grid] = toks
                    self._rid_map.pop(grid, None)
        return out

    def drain(self) -> dict[int, np.ndarray]:
        """Step until every replica is idle; all finished streams."""
        out = self.collect()
        wedged = 0
        while not self.idle:
            events = self.step()
            got = self.collect()
            out.update(got)
            wedged = 0 if (events or got) else wedged + 1
            if wedged > 1000:
                raise RuntimeError("router wedged: no replica can progress")
        out.update(self.collect())
        return out

    @property
    def idle(self) -> bool:
        return all(r.idle for r in self._replicas)

    @property
    def num_active(self) -> int:
        return sum(r.num_active for r in self._replicas)

    @property
    def num_replicas(self) -> int:
        return len(self._replicas)

    def warmup(self, sampled: bool = False) -> int:
        """Precompile every replica's dispatch family."""
        return sum(r.warmup(sampled=sampled) for r in self._replicas)

    def pin_prefix(self, prefix_id: str) -> int:
        """Pin on EVERY replica (sticky): whichever replica first serves
        the prefix keeps it resident, and the pin is a no-op (0 pages)
        everywhere else until pages register there."""
        return sum(r.pin_prefix(prefix_id) for r in self._replicas)

    def unpin_prefix(self, prefix_id: str) -> int:
        return sum(r.unpin_prefix(prefix_id) for r in self._replicas)

    def stats(self) -> list:
        """Per-replica engine snapshots, fleet order (`EngineStats` /
        `DisaggStats`); the placement ledger is `router_stats`."""
        return [r.stats() for r in self._replicas]

    def reset_stats(self) -> None:
        for r in self._replicas:
            r.reset_stats()
        self.router_stats = RouterStats()

    # --------------------------------------------------------- drain / join
    def drain_replica(self, i: int, *, reroute: bool = True,
                      wait: bool = True,
                      max_steps: int = 100_000) -> list[tuple[int, int]]:
        """Take replica ``i`` out of placement, losing nothing.

        1. The replica stops receiving placements (scores ``-inf``);
           its sessions re-score on their next turn and re-pin wherever
           they land.
        2. With ``reroute=True`` its **queued** requests — submitted but
           not yet admitted, so they hold no slot, no pages, and have
           emitted nothing — are moved to the rest of the fleet under
           their original global rids (greedy streams depend only on the
           prompt, so the move is invisible in the output).
        3. With ``wait=True`` the whole fleet keeps stepping (service
           continues) until the replica's in-flight requests finish;
           the (global rid, token) events produced meanwhile are
           returned so callers keep streaming. ``wait=False`` returns
           immediately — later `step()`/`drain()` calls finish the job.

        The drained replica stays in the fleet (idle, unplaceable) so
        `add_replica` can re-join it with its pages still warm; use
        `remove_replica` to drop it entirely.
        """
        rep = self._replicas[i]
        self._draining.add(id(rep))
        self.router_stats.drains += 1
        anyone_live = any(id(r) not in self._draining
                          for r in self._replicas)
        if reroute and anyone_live:
            self._reroute_queued(rep)   # no live target ⇒ serve in place
        events: list[tuple[int, int]] = []
        if wait:
            steps = 0
            while not rep.idle:
                events.extend(self.step())
                steps += 1
                if steps > max_steps:
                    raise RuntimeError(
                        f"drain_replica({i}) did not converge in "
                        f"{max_steps} steps")
        return events

    def _reroute_queued(self, rep) -> None:
        """Move ``rep``'s not-yet-admitted requests to live replicas."""
        sched = getattr(rep, "_scheduler", None)
        if sched is None or not sched.queue:
            return                      # disagg/fresh replica: nothing queued
        queued = list(sched.queue)
        sched.queue.clear()
        fwd = self._to_global[id(rep)]
        for req in queued:
            grid = fwd.pop(req.rid, None)
            if grid is None:
                continue                # not ours (defensive)
            self._rid_map.pop(grid, None)
            idx = self.place(req.tokens, prefix_id=req.prefix_id,
                             priority=req.priority)
            target = self._replicas[idx]
            lrid = target.submit(
                req.tokens, req.max_new_tokens,
                sampler=SamplerConfig(temperature=req.temperature,
                                      top_k=req.top_k),
                eos_id=req.eos_id, prefix_id=req.prefix_id,
                priority=req.priority)
            self._rid_map[grid] = (target, lrid, req.priority)
            self._to_global[id(target)][lrid] = grid
            self.router_stats.reroutes += 1

    def add_replica(self, replica, *, warmup: bool = False) -> int:
        """Join ``replica`` to the fleet (or re-join a drained one).

        A drained replica passed back in simply becomes placeable again —
        pages, pins, and sessions it still holds are warm immediately.
        A new replica is appended (and optionally warmed up so its first
        placement pays no compile). Returns its fleet index.
        """
        self.router_stats.joins += 1
        for i, r in enumerate(self._replicas):
            if r is replica:
                self._draining.discard(id(r))
                return i
        self._replicas.append(replica)
        self._to_global.setdefault(id(replica), {})
        if warmup:
            replica.warmup()
        return len(self._replicas) - 1

    def remove_replica(self, i: int):
        """Drop an **idle** replica from the fleet and return it.

        Raises if it still has queued or in-flight work — drain it first
        (`drain_replica`). Its already-finished streams are buffered and
        still come out of the next `collect()`.
        """
        rep = self._replicas[i]
        if not rep.idle:
            raise RuntimeError(
                f"replica {i} is not idle ({rep.num_active} active) — "
                "drain_replica() it first")
        if len(self._replicas) == 1:
            raise RuntimeError("cannot remove the last replica — the "
                               "router could no longer place anything")
        fwd = self._to_global.pop(id(rep), {})
        for lrid, toks in rep.collect().items():
            grid = fwd.pop(lrid, None)
            if grid is not None:
                self._finished[grid] = toks
                self._rid_map.pop(grid, None)
        self._draining.discard(id(rep))
        self._sessions = {s: r for s, r in self._sessions.items()
                          if r is not rep}
        del self._replicas[i]
        return rep
