"""Batched generation engine: prefill → decode loop, sampling, quantized path.

This is the paper's end-to-end inference flow (§III: model packed offline,
streamed to the accelerator, decoded token-by-token) as a framework feature:

  * `GenerationEngine(model, params)` — params may be float or AWQ-packed
    (`core.pipeline.quantize_params` output); every linear dispatches
    through `qlinear_apply`, so switching to the quantized model is a
    params swap, no engine change.
  * continuous-batching-lite: per-request positions and EOS tracking; a
    finished row keeps decoding into a scratch slot (masked out) so the
    jit'd step never re-specializes on batch composition.
  * `generate_scan` — the fixed-length `lax.scan` variant used by the
    throughput benchmarks (no per-token host round-trip).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0    # 0 ⇒ greedy
    top_k: int = 0              # 0 ⇒ full softmax


def sample(logits: jax.Array, cfg: SamplerConfig, key) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class GenerationEngine:
    def __init__(self, model, params, *, max_seq: int | None = None,
                 sampler: SamplerConfig = SamplerConfig(),
                 eos_id: int = -1, donate_cache: bool = True):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_seq = max_seq or model.cfg.max_seq_len
        self.sampler = sampler
        self.eos_id = eos_id
        self._prefill = jax.jit(model.prefill)
        donate = (1,) if donate_cache else ()
        self._step = jax.jit(self._decode_one, donate_argnums=donate)

    def _decode_one(self, params, cache, token, pos, key):
        logits, cache = self.model.decode_step(params, cache, token, pos)
        nxt = sample(logits, self.sampler, key)
        return nxt, cache, logits

    def generate(self, batch: dict, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """Host-loop generation with EOS early-exit. Returns [B, max_new]."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b = next(iter(batch.values())).shape[0]
        cache = self.model.init_cache(b, self.max_seq)
        cache, logits, pos = self._prefill(self.params, batch, cache)
        token = sample(logits, self.sampler, key)
        out = [np.asarray(token)]
        finished = np.zeros(b, bool)
        for t in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            token, cache, logits = self._step(self.params, cache, token,
                                              pos, sub)
            pos = pos + 1
            tok_np = np.asarray(token)
            tok_np = np.where(finished, self.eos_id, tok_np)
            finished |= tok_np == self.eos_id
            out.append(tok_np)
            if self.eos_id >= 0 and finished.all():
                break
        return np.stack(out, axis=1)

    def generate_scan(self, batch: dict, max_new_tokens: int, key=None):
        """Fixed-length scan generation (benchmark path, single dispatch)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b = next(iter(batch.values())).shape[0]
        cache = self.model.init_cache(b, self.max_seq)

        @jax.jit
        def run(params, batch, cache, key):
            cache, logits, pos = self.model.prefill(params, batch, cache)
            tok0 = sample(logits, self.sampler, key)

            def body(carry, _):
                tok, cache, pos, key = carry
                key, sub = jax.random.split(key)
                logits, cache = self.model.decode_step(params, cache, tok,
                                                       pos)
                nxt = sample(logits, self.sampler, sub)
                return (nxt, cache, pos + 1, key), tok

            (_, _, _, _), toks = jax.lax.scan(
                body, (tok0, cache, pos, key), None,
                length=max_new_tokens)
            return jnp.moveaxis(toks, 0, 1)

        return np.asarray(run(self.params, batch, cache, key))
