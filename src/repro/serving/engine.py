"""Serving facade: static-batch generation + continuous-batching streaming.

This is the paper's end-to-end inference flow (§III: model packed offline,
streamed to the accelerator, decoded token-by-token) grown into a serving
subsystem:

  * `GenerationEngine(model, params)` — params may be float or AWQ-packed
    (`core.pipeline.quantize_params` output). Every linear dispatches
    through `qlinear_apply`, but a quantized swap is NOT engine-invisible;
    the engine does two things to make it work: (a) every compiled
    dispatch is keyed on the active `ExecutionConfig` (`qlinear_apply`
    reads it at trace time, so without the keying a
    `set_execution_config(...)` after the first step would be silently
    ignored — flipping impl now retraces on the next step), and (b) under
    a mesh the `PackedLinear` leaves (qweight/scales/zeros/input_scale)
    shard through the same `param_pspec` rules as the float weight they
    replace, keeping whole quant groups per device.
  * static batch — `generate` (host loop, EOS early-exit) and
    `generate_scan` (fixed-length `lax.scan`, the throughput-benchmark
    path). These are the baselines the serving benchmarks compare against.
  * streaming — `submit()` / `step()` / `collect()` on top of
    `serving.scheduler` (continuous batching) and `serving.kv_pager`
    (paged KV cache): per-request sampling params, EOS eviction with
    immediate slot backfill, one fixed-shape jit'd dispatch per step
    regardless of batch composition.
  * chunked prefill — on pure paged-attention archs every step is ONE
    token-budget dispatch of ``num_slots × prefill_chunk`` positions
    that packs prefill chunks and decode tokens from mixed requests
    (`Model.chunk_step`); prompts are fed in fixed-size chunks whose KV
    scatters straight into the page pools, the first token is sampled
    when the last chunk lands, and the compiled family is bounded at
    O(log) context buckets × two block widths (no jit-per-prompt-length
    family). Archs with bounded
    sequential per-slot state (rings / SSM / MLA) keep the one-shot
    prefill path (``chunked_prefill=False`` forces it everywhere — the
    identity baseline).
  * memory levers — ``kv_quant="int8"`` stores the page pools as int8
    codes + per-(position, head) scale strips (quantize-on-commit,
    dequant fused into the paged attention read; ~1.9× more resident
    tokens per byte), and ``submit(..., prefix_id=...)`` aliases a shared
    system prompt's full pages across requests (refcounted, COW tail).
    Under chunked prefill the aliased tokens are also **never
    recomputed** (the chunk attends over the already-committed pages), so
    sharing saves prefill FLOPs too; `pin_prefix()` keeps a hot prefix
    resident across bursts.
  * speculative decoding — ``spec_decode="ngram"`` (prompt-lookup
    self-drafting, no second model) or ``"draft_model"`` (a small greedy
    drafter with its own dense cache) proposes up to ``spec_k`` tokens
    per decoding slot; the unified chunk dispatch verifies them in ONE
    weight pass (a verify run is just a multi-token decode row), a
    device-side acceptance sampler keeps outputs distribution-faithful
    (token-identical to sequential decode under greedy), and rejected
    suffixes roll the paged KV back via `KVPager.truncate`. One weight
    stream now amortizes over up to ``spec_k + 1`` emitted tokens — the
    lever the paper's 5.1 tok/s memory-bandwidth ceiling asks for.
    ``spec_adaptive=True`` lets the scheduler walk ``spec_k`` through
    ``{1, 2, 4, …, spec_k}`` from an EMA of the measured acceptance.
  * tensor parallelism — ``GenerationEngine(mesh=...)`` serves a
    TP-sharded model with TP-sharded paged KV over the mesh's ``model``
    axis: weights shard by the `distributed.sharding.param_pspec` rules,
    page pools stripe over KV heads (`paged_cache_pspec`), and every
    chunk/decode/verify dispatch is jit'd with explicit in/out shardings
    (page tables, token blocks and sampled tokens replicated). Page IDs
    are device-agnostic, so the host-side pager and scheduler are
    untouched by construction — admission, eviction, prefix sharing and
    rollback run identically, and greedy sharded streams are
    token-identical to the single-device engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_pager import (KVPager, PagerConfig, PagerStats,
                                    commit_prefill)
from repro.serving.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0    # 0 ⇒ greedy
    top_k: int = 0              # 0 ⇒ full softmax


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One structured serving snapshot — the public metrics surface.

    Everything the benchmarks (and an operator dashboard) need in one
    read: pager occupancy, dispatch/packing accounting, speculative
    acceptance, and the memory footprint of the page pools — global and
    **per device** (under a ``model`` mesh the pools stripe over KV
    heads, so the per-device number shrinks ~linearly with the axis).
    """
    pager: PagerStats
    # dispatch / packing
    dispatches: int               # unified steps issued
    prefill_tokens: int           # prompt tokens run through the model
    prefill_tokens_skipped: int   # aliased prompt tokens never re-run
    prefix_shared_pages: int      # pages aliased instead of allocated
    padding_waste: float          # padding / dispatched positions
    padding_waste_fixed: float    # same steps under pad-to-chunk-width
    # speculative decoding
    acceptance_rate: float
    spec_tokens_per_row: float
    draft_tokens: int
    accepted_tokens: int
    rollbacks: int
    spec_k_now: int               # current draft length (adaptive)
    spec_fanout_now: int          # current tree root fanout (1 = linear)
    # SLO preemption / host KV tier
    preemptions: int              # slots spilled to the host tier
    pressure_spills: int          # spills by optimistic-admission pressure
    restores: int                 # parked requests re-admitted
    spilled_pages: int            # cumulative page strips gathered to host
    restored_pages: int           # cumulative page strips scattered back
    pages_spilled_now: int        # live host-tier pages right now
    restore_ms_mean: float        # mean wall latency of one restore
    # sharding + memory
    model_axis: int               # |model| mesh axis (1 = unsharded)
    kv_pool_bytes: int            # global page-pool footprint, all layers
    kv_pool_bytes_per_device: int
    kv_bytes_per_token: float
    # weight stream (the AWQ lever): resident bytes of the served params
    # (PackedLinear leaves count int4 packing + metadata) and the bytes
    # streamed per EMITTED token — one full weight pass per decode step,
    # amortized over spec-accepted tokens per row when speculating.
    weight_bytes: int
    weight_bytes_per_token: float
    # load snapshot (cheap, host-only): what a fleet router needs to score
    # this engine as a placement target without touching scheduler/pager
    # internals. `queue_depth` counts requests waiting for a slot (queued
    # + preempted/parked); `admission_headroom` is the free pages an
    # admission can still draw (free minus standing reservations).
    queue_depth: int = 0
    admission_headroom: int = 0


def _tree_walk_greedy(g, tokens, parents, n_draft, depth):
    """Device-side greedy tree acceptance: from the root (in-row index 0),
    follow the child whose token equals the target argmax at the current
    node, as deep as the matches go.

    g ``[B, R]`` — the target argmax after each in-row position; tokens /
    parents ``[B, C]`` (parent = in-row index, ``-1`` = none); n_draft
    ``[B]`` node counts (nodes sit at in-row indices ``1 … n_draft``).
    Returns ``(fix [B], n_acc [B], path [B, depth])`` — the corrected /
    bonus token (argmax at the deepest accepted node), the accepted
    depth, and the accepted branch's in-row indices (0-padded). Emitting
    ``path`` tokens then ``fix`` reproduces sequential greedy decode
    token-for-token — the tree-speculation identity guarantee.
    """
    b, c = tokens.shape
    idx = jnp.arange(c, dtype=jnp.int32)[None, :]
    rmax = g.shape[1] - 1

    def body(t, carry):
        cur, n_acc, path, alive = carry
        g_cur = jnp.take_along_axis(g, jnp.clip(cur, 0, rmax)[:, None],
                                    axis=1)[:, 0]
        cand = ((parents == cur[:, None]) & (tokens == g_cur[:, None])
                & (idx >= 1) & (idx <= n_draft[:, None]) & alive[:, None])
        has = cand.any(axis=1)
        child = jnp.argmax(cand, axis=1).astype(jnp.int32)
        cur = jnp.where(has, child, cur)
        n_acc = n_acc + has.astype(jnp.int32)
        path = path.at[:, t].set(jnp.where(has, child, 0))
        return cur, n_acc, path, alive & has

    carry = (jnp.zeros(b, jnp.int32), jnp.zeros(b, jnp.int32),
             jnp.zeros((b, depth), jnp.int32), jnp.ones(b, bool))
    cur, n_acc, path, _ = jax.lax.fori_loop(0, depth, body, carry)
    fix = jnp.take_along_axis(g, jnp.clip(cur, 0, rmax)[:, None],
                              axis=1)[:, 0]
    return fix, n_acc, path


def _tree_walk_sampled(probs, tokens, parents, n_draft, depth, key):
    """Multi-branch acceptance sampling over a token tree (SpecInfer-style
    point-mass residuals), distribution-faithful per row.

    At each accepted node the children are tried in in-row order: child
    token x is accepted with probability ``p(x) / mass`` where ``p`` is
    the target distribution at the node and ``mass`` the residual left by
    previously rejected siblings (whose point mass is zeroed — standard
    residual acceptance, so the emitted marginal equals sequential
    sampling). When every child is rejected the fix token is drawn from
    the residual; at a leaf (or full depth) from the plain target — the
    bonus draw. ``probs [B, R, V]`` must already be temperature / top-k
    filtered; one-hot rows reduce exactly to `_tree_walk_greedy`.
    """
    b, c = tokens.shape
    v = probs.shape[-1]
    rmax = probs.shape[1] - 1
    ku, kf = jax.random.split(key)
    us = jax.random.uniform(ku, (depth, c, b))
    bidx = jnp.arange(b)

    def take_p(cur):
        return jnp.take_along_axis(
            probs, jnp.clip(cur, 0, rmax)[:, None, None], axis=1)[:, 0]

    def outer(t, carry):
        cur, n_acc, path, alive, p_bonus = carry
        u_t = jax.lax.dynamic_index_in_dim(us, t, 0, keepdims=False)

        def inner(j, ic):
            accepted, child, p_res = ic
            par_j = jax.lax.dynamic_index_in_dim(parents, j, 1,
                                                 keepdims=False)
            tok_j = jax.lax.dynamic_index_in_dim(tokens, j, 1,
                                                 keepdims=False)
            u_j = jax.lax.dynamic_index_in_dim(u_t, j, 0, keepdims=False)
            is_cand = (alive & ~accepted & (par_j == cur)
                       & (j <= n_draft))
            p_tok = p_res[bidx, tok_j]
            mass = p_res.sum(axis=1)
            acc = is_cand & (u_j * mass < p_tok)       # P = p_tok / mass
            rej = is_cand & ~acc
            p_res = p_res.at[bidx, tok_j].set(
                jnp.where(rej, 0.0, p_tok))
            return accepted | acc, jnp.where(acc, j, child), p_res

        accepted, child, p_res = jax.lax.fori_loop(
            1, c, inner,
            (jnp.zeros(b, bool), jnp.zeros(b, jnp.int32), take_p(cur)))
        stepped = alive & accepted
        p_bonus = jnp.where((alive & ~accepted)[:, None], p_res, p_bonus)
        cur = jnp.where(stepped, child, cur)
        n_acc = n_acc + stepped.astype(jnp.int32)
        path = path.at[:, t].set(jnp.where(stepped, child, 0))
        return cur, n_acc, path, stepped, p_bonus

    carry = (jnp.zeros(b, jnp.int32), jnp.zeros(b, jnp.int32),
             jnp.zeros((b, depth), jnp.int32), jnp.ones(b, bool),
             jnp.zeros((b, v), probs.dtype))
    cur, n_acc, path, alive, p_bonus = jax.lax.fori_loop(0, depth, outer,
                                                         carry)
    p_bonus = jnp.where(alive[:, None], take_p(cur), p_bonus)
    safe = jnp.where(p_bonus.sum(axis=1, keepdims=True) > 0, p_bonus, 1.0)
    fix = jax.random.categorical(kf, jnp.log(safe)).astype(jnp.int32)
    return fix, n_acc, path


def sample(logits: jax.Array, cfg: SamplerConfig, key) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def sample_batched(logits: jax.Array, temps: jax.Array, topks: jax.Array,
                   key) -> jax.Array:
    """Per-row sampling params: logits [B, V], temps [B], topks [B] → [B].

    Rows with ``temps == 0`` are greedy (bitwise-identical to `sample` with
    temperature 0, which the continuous-vs-static identity tests rely on);
    ``topks == 0`` disables the top-k filter for that row.
    """
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None]
    desc = -jnp.sort(-scaled, axis=-1)
    kth = jnp.take_along_axis(desc, jnp.clip(topks - 1, 0, v - 1)[:, None],
                              axis=1)
    filtered = jnp.where(scaled < kth, -1e30, scaled)
    scaled = jnp.where((topks > 0)[:, None], filtered, scaled)
    sampled = jax.random.categorical(key, scaled).astype(jnp.int32)
    return jnp.where(temps == 0.0, greedy, sampled)


class GenerationEngine:
    def __init__(self, model, params, *, max_seq: int | None = None,
                 sampler: SamplerConfig = SamplerConfig(),
                 eos_id: int = -1, donate_cache: bool = True,
                 num_slots: int = 4, page_size: int = 16,
                 num_pages: int | None = None, seed: int = 0,
                 kv_quant: str | None = None,
                 prefill_chunk: int = 16,
                 chunked_prefill: bool | None = None,
                 spec_decode: str | None = None,
                 spec_k: int = 4,
                 spec_ngram_max: int = 3,
                 spec_adaptive: bool = False,
                 spec_tree: bool = False,
                 spec_tree_fanout: int = 2,
                 draft_model=None, draft_params=None,
                 draft_fn=None,
                 mesh=None,
                 preemption: bool = False,
                 admission: str = "reserved"):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        # tensor-parallel serving: a jax Mesh with a `model` axis. Weights
        # shard per param_pspec, page pools stripe over KV heads per
        # paged_cache_pspec, host-side pager/scheduler stay replicated
        # single-authority. Indivisible head counts fail HERE, not inside
        # a kernel three layers down.
        self._mesh = mesh
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"mesh axes {mesh.axis_names} carry no 'model' axis — "
                    f"serving tensor parallelism shards over 'model' "
                    f"(see distributed.serving_mesh)")
            msize = mesh.shape["model"]
            has_attn = any(kind.mixer in ("attn", "hymba")
                           for kind, _ in model.cfg.segments())
            if msize > 1 and has_attn \
                    and model.cfg.num_kv_heads % msize != 0:
                raise ValueError(
                    f"num_kv_heads={model.cfg.num_kv_heads} is not "
                    f"divisible by the {msize}-way 'model' mesh axis — "
                    f"page pools shard over KV heads; choose a mesh size "
                    f"that divides Hkv (or mesh=None)")
        self.max_seq = max_seq or model.cfg.max_seq_len
        self.sampler = sampler
        self.eos_id = eos_id
        self._prefill = self._exec_jit(model.prefill)
        donate = (1,) if donate_cache else ()
        self._step = self._exec_jit(self._decode_one, donate_argnums=donate)
        # streaming/continuous-batching state (built lazily on first submit)
        self.num_slots = num_slots
        self.page_size = page_size
        self._num_pages = num_pages
        self._seed = seed
        # page-pool storage regime: None follows cfg.kv_quant; "int8"
        # serves int8 pages under a float model (quantize-on-commit,
        # dequant fused into the paged decode read)
        if kv_quant not in (None, "none", "int8"):
            raise ValueError(f"unknown kv_quant {kv_quant!r}")
        self.kv_quant = model.cfg.kv_quant if kv_quant is None else kv_quant
        # chunked prefill: None = auto (chunked whenever the arch's paged
        # cache is pure kv_pool), True = require it, False = one-shot
        # per-request prefill (the PR-2 baseline path)
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be ≥ 1")
        self.prefill_chunk = prefill_chunk
        self.chunked_prefill = chunked_prefill
        # speculative decoding: "ngram" (prompt-lookup self-drafter, no
        # second model) or "draft_model" (greedy small-model drafter — pass
        # draft_model + draft_params, or a custom draft_fn for testing)
        if spec_decode not in (None, "ngram", "draft_model"):
            raise ValueError(f"unknown spec_decode {spec_decode!r}")
        if spec_decode is not None and spec_k < 1:
            raise ValueError("spec_k must be ≥ 1")
        if spec_decode == "draft_model" and draft_model is None \
                and draft_fn is None:
            raise ValueError("spec_decode='draft_model' needs draft_model "
                             "(+ draft_params) or a draft_fn")
        if draft_model is not None:
            chunkable = self._cache_chunkable(jax.eval_shape(
                lambda: draft_model.init_paged_cache(1, 2, page_size,
                                                     page_size)))
            if not chunkable:
                raise ValueError(
                    "draft_model keeps bounded per-slot sequential state "
                    "(ring/SSM/MLA) — the draft cache must be pure dense "
                    "full attention")
        # tree speculation: drafts branch (a primary chain + alternate
        # first tokens), one chunk dispatch verifies every branch under
        # the kernel's ancestor mask, and the device-side walk + KV
        # compaction keep greedy streams token-identical to sequential
        # decode (see scheduler / _tree_greedy_fn)
        if spec_tree and spec_decode is None:
            raise ValueError("spec_tree needs a drafter — set "
                             "spec_decode='ngram' or 'draft_model'")
        if spec_tree and spec_tree_fanout < 1:
            raise ValueError("spec_tree_fanout must be ≥ 1")
        self.spec_decode = spec_decode
        self.spec_k = spec_k
        self.spec_adaptive = spec_adaptive
        self.spec_tree = spec_tree
        self.spec_tree_fanout = spec_tree_fanout
        self.spec_ngram_max = spec_ngram_max
        self.draft_model = draft_model
        self.draft_params = draft_params
        self._custom_draft_fn = draft_fn
        # SLO-aware preemption: priority classes on submit(), victim
        # spill to a host-memory page tier, zero-recompute restore.
        # admission="optimistic" drops the worst-case decode reservation
        # (preemption becomes the safety valve when the pool runs dry).
        if admission not in ("reserved", "optimistic"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if admission == "optimistic" and not preemption:
            raise ValueError("admission='optimistic' requires "
                             "preemption=True — without spill as a safety "
                             "valve a drained pool would fail extend()")
        self.preemption = preemption
        self.admission = admission
        self._next_rid = 0
        self._scheduler: Scheduler | None = None
        self._paged_cache = None

    def _decode_one(self, params, cache, token, pos, key):
        logits, cache = self.model.decode_step(params, cache, token, pos)
        nxt = sample(logits, self.sampler, key)
        return nxt, cache, logits

    def generate(self, batch: dict, max_new_tokens: int,
                 key=None) -> np.ndarray:
        """Host-loop generation with EOS early-exit. Returns [B, max_new]."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b = next(iter(batch.values())).shape[0]
        cache = self.model.init_cache(b, self.max_seq)
        cache, logits, pos = self._prefill(self.params, batch, cache)
        token = sample(logits, self.sampler, key)
        out = [np.asarray(token)]
        finished = np.zeros(b, bool)
        for t in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            token, cache, logits = self._step(self.params, cache, token,
                                              pos, sub)
            pos = pos + 1
            tok_np = np.asarray(token)
            tok_np = np.where(finished, self.eos_id, tok_np)
            finished |= tok_np == self.eos_id
            out.append(tok_np)
            if self.eos_id >= 0 and finished.all():
                break
        return np.stack(out, axis=1)

    # ------------------------------------------------------------ streaming
    # submit()/step()/collect() — continuous batching over the paged cache.

    def _serving_init(self) -> Scheduler:
        if self.max_seq % self.page_size:
            raise ValueError("max_seq must be a multiple of page_size")
        pages_per_slot = self.max_seq // self.page_size
        num_pages = self._num_pages
        if num_pages is None:   # full capacity: every slot can hit max_seq
            num_pages = self.num_slots * pages_per_slot + 1
        pager = KVPager(PagerConfig(num_pages=num_pages,
                                    page_size=self.page_size,
                                    num_slots=self.num_slots,
                                    pages_per_slot=pages_per_slot,
                                    optimistic=(self.admission
                                                == "optimistic")))
        self._paged_cache = self.model.init_paged_cache(
            self.num_slots, num_pages, self.page_size, self.max_seq,
            kv_quant=self.kv_quant)
        chunkable = self._cache_chunkable(self._paged_cache)
        chunked = chunkable if self.chunked_prefill is None \
            else self.chunked_prefill
        if chunked and not chunkable:
            raise ValueError(
                "chunked_prefill=True but the arch keeps bounded per-slot "
                "sequential state (ring/SSM/MLA) — only pure "
                "paged-attention caches support the chunked path")
        if self.spec_decode is not None and not chunked:
            raise ValueError(
                "spec_decode requires the chunked serving path (verify "
                "runs are multi-token rows of the unified chunk dispatch)")
        if self._mesh is not None and not chunked:
            raise ValueError(
                "mesh-sharded serving requires the chunked (token-budget) "
                "path: archs with bounded per-slot sequential state "
                "(ring/SSM/MLA) and the one-shot baseline stay "
                "single-device — pass mesh=None")
        if self.preemption and not chunked:
            raise ValueError(
                "preemption requires the chunked serving path: restore "
                "re-enters the unified chunk dispatch at the commit "
                "watermark, which one-shot prefill does not track")
        self._key = jax.random.PRNGKey(self._seed)
        self._tables_version = -1
        self._tables_dev = None
        self._tables_sliced = {}
        self._init_mesh_placement()
        if self.preemption:
            self._init_spill_tier()
        if chunked:
            # ONE compiled step for everything: prefill chunks + decode
            # token runs packed into a fixed [num_slots, c] block
            self._chunk_sampled = self._jit_dispatch(self._chunk_step_fn,
                                                     n_host=8, n_out=2)
            self._chunk_greedy = self._jit_dispatch(self._chunk_greedy_fn,
                                                    n_host=5, n_out=2)
            draft_fn = None
            sched_spec = None
            if self.spec_decode is not None:
                self._spec_greedy = self._jit_dispatch(self._spec_greedy_fn,
                                                       n_host=6, n_out=3)
                self._spec_sampled = self._jit_dispatch(
                    self._spec_sampled_fn, n_host=9, n_out=3)
                if self.spec_tree:
                    self._tree_greedy = self._jit_dispatch(
                        self._tree_greedy_fn, n_host=9, n_out=4)
                    self._tree_sampled = self._jit_dispatch(
                        self._tree_sampled_fn, n_host=12, n_out=4)
                sched_spec = "ngram" if self.spec_decode == "ngram" \
                    else "draft_fn"
                if self.spec_decode == "draft_model":
                    draft_fn = self._custom_draft_fn
                    if draft_fn is None:
                        self._draft_init()
                        draft_fn = self._draft_tree_fn if self.spec_tree \
                            else self._draft_fn
            return Scheduler(pager, run_batch=self._exec_run_batch,
                             chunk_size=self.prefill_chunk,
                             spec_decode=sched_spec, spec_k=self.spec_k,
                             adaptive_spec_k=self.spec_adaptive,
                             spec_tree=self.spec_tree,
                             spec_tree_fanout=self.spec_tree_fanout,
                             draft_fn=draft_fn,
                             ngram_max=self.spec_ngram_max,
                             preemption=self.preemption,
                             spill_fn=(self._exec_spill
                                       if self.preemption else None),
                             restore_fn=(self._exec_restore
                                         if self.preemption else None))
        # one-shot path: one dispatch per admission fusing prefill + page
        # commit + first sample (start_page static: commit skips the
        # aliased shared-prefix pages), jit per prompt length
        self._prefill_fused = self._exec_jit(self._prefill_commit_fn,
                                             donate_argnums=(1,),
                                             static_argnums=(8,))
        self._decode_paged = self._exec_jit(self._decode_paged_fn,
                                            donate_argnums=(1,))
        self._decode_greedy = self._exec_jit(self._decode_greedy_fn,
                                             donate_argnums=(1,))
        return Scheduler(pager, prefill_commit=self._exec_prefill_commit,
                         decode=self._exec_decode)

    @staticmethod
    def _cache_chunkable(cache) -> bool:
        """True when every cache entry is a page pool (no per-slot
        sequential state), i.e. the arch can run the chunked path."""
        return all(set(entry) == {"kv_pool"} for entry in cache.values())

    # --- tensor-parallel placement ----------------------------------------
    def _init_mesh_placement(self):
        """Shard params + page pools over the serving mesh (no-op without
        one). Weights follow `param_pspec` (column/row-parallel linears,
        vocab-parallel head), pools follow `paged_cache_pspec` (KV heads
        over ``model``); `self.params` itself stays untouched so the
        static-batch `generate` baselines keep their single-device path.
        """
        if self._mesh is None:
            self._params_run = self.params
            return
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed import sharding as shd
        mesh = self._mesh
        self._repl_sh = NamedSharding(mesh, P())
        self._param_sh = shd.make_sharding(self.params, mesh,
                                           shd.param_pspec, self.cfg)
        self._cache_sh = shd.make_sharding(self._paged_cache, mesh,
                                           shd.paged_cache_pspec)
        self._params_run = jax.device_put(self.params, self._param_sh)
        self._paged_cache = jax.device_put(self._paged_cache, self._cache_sh)

    # --- host-memory page tier (preemption spill/restore) -----------------
    def _init_spill_tier(self):
        """Compile the page-strip movers behind `KVPager.spill`/`restore`.

        Gather reads ``pool[:, ids]`` strips out of every kv_pool leaf —
        int8 codes + scale strips when the pool is quantized, so the host
        tier holds the pages **int8-recompressed**, never re-inflated.
        Scatter writes them into freshly drawn pages with the cache
        donated (the pool buffers mutate in place like every other
        dispatch). Under a mesh the strips cross the tier replicated
        (`distributed.sharding.spill_sharding`): the gather all-gathers
        each device's head shard in-dispatch, the scatter re-stripes on
        the way back in, and the host-side page ids stay device-agnostic.
        """
        if self._mesh is None:
            self._spill_sh = None
            self._spill_gather = self._exec_jit(self._spill_gather_fn)
            self._spill_scatter = self._exec_jit(self._spill_scatter_fn,
                                                 donate_argnums=(0,))
            return
        from repro.distributed import sharding as shd
        self._spill_sh = shd.spill_sharding(self._mesh)
        self._spill_gather = self._exec_jit(
            self._spill_gather_fn,
            in_shardings=(self._cache_sh, self._spill_sh),
            out_shardings=self._spill_sh)
        self._spill_scatter = self._exec_jit(
            self._spill_scatter_fn, donate_argnums=(0,),
            in_shardings=(self._cache_sh, self._spill_sh, self._spill_sh),
            out_shardings=self._cache_sh)

    def _spill_gather_fn(self, cache, ids):
        """cache, page ids [n] → {seg: {leaf: [L, n, P, ...] strips}}."""
        return {seg: {k: leaf[:, ids]
                      for k, leaf in entry["kv_pool"].items()}
                for seg, entry in cache.items()}

    def _spill_scatter_fn(self, cache, ids, strips):
        """Write gathered strips into pages ``ids`` of every pool leaf."""
        return {seg: {"kv_pool": {
                    k: leaf.at[:, ids].set(
                        strips[seg][k].astype(leaf.dtype))
                    for k, leaf in entry["kv_pool"].items()}}
                for seg, entry in cache.items()}

    @staticmethod
    def _spill_bucket(n: int) -> int:
        """Geometric page-count bucket for spill strips, so the compiled
        gather/scatter family stays O(log pages_per_slot); the pad ids
        point at the scratch page 0, whose content is never read."""
        b = 1
        while b < n:
            b *= 2
        return b

    def _spill_ids_dev(self, ids: list[int], n: int):
        padded = np.zeros(self._spill_bucket(n), np.int32)
        padded[:n] = ids
        if self._mesh is not None:
            return jax.device_put(padded, self._spill_sh)
        return jnp.asarray(padded)

    def _exec_spill(self, phys_ids: list[int]) -> dict:
        """Scheduler spill hook: gather ``phys_ids``'s pool bytes BEFORE
        the pager releases those pages. The gather is dispatched async —
        the strips snapshot the pre-release cache value (functional
        arrays), and the device→host DMA overlaps the decode dispatches
        that follow; nothing blocks until the strips are needed again."""
        n = len(phys_ids)
        strips = self._spill_gather(self._paged_cache,
                                    self._spill_ids_dev(phys_ids, n))
        for leaf in jax.tree.leaves(strips):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        return {"n": n, "strips": strips}

    def _exec_restore(self, handle: dict, fresh_ids: list[int]) -> None:
        """Scheduler restore hook: scatter the parked strips into the
        freshly drawn pages (the pager already rebuilt the page table)."""
        assert len(fresh_ids) == handle["n"]
        self._paged_cache = self._spill_scatter(
            self._paged_cache, self._spill_ids_dev(fresh_ids, handle["n"]),
            handle["strips"])

    # --- cross-engine KV page handoff (disaggregated prefill/decode) ------
    def _ensure_spill_movers(self) -> None:
        """The disagg handoff path reuses the preemption spill movers
        (gather/scatter over **replicated** strips — which is exactly why
        a decode engine on a different mesh can adopt them); build them
        lazily for engines that never enabled preemption. The wrappers
        are `_exec_jit` caches: nothing compiles until first use."""
        if self._scheduler is None:
            self._scheduler = self._serving_init()
        if not hasattr(self, "_spill_gather"):
            self._init_spill_tier()

    def handoff_gather(self, phys_ids: list[int]) -> dict:
        """Gather ``phys_ids``'s pool bytes for a cross-engine handoff.

        Dispatched async like `_exec_spill` — the strips snapshot the
        current cache value (functional arrays: the pager may free the
        slot immediately after), and the device→host DMA overlaps
        whatever the caller dispatches next (the decode engine's step, in
        `DisaggController`). `handoff_wire` materializes the wire image.
        """
        self._ensure_spill_movers()
        return self._exec_spill(phys_ids)

    def handoff_wire(self, handle: dict) -> tuple[dict, int]:
        """Block on a `handoff_gather` and return ``(strips, wire_bytes)``.

        Strips come back as host numpy trimmed to the real page count —
        the honest wire image: int8 pools ship codes + per-position scale
        strips (~2× fewer bytes than bf16), and because the gather leaves
        the mesh replicated (`distributed.sharding.spill_sharding`) the
        image is mesh-agnostic — a decode engine on a *different* mesh
        adopts it unchanged.
        """
        n = handle["n"]
        strips = jax.tree.map(lambda a: np.asarray(a)[:, :n],
                              handle["strips"])
        wire = sum(leaf.nbytes for leaf in jax.tree.leaves(strips))
        return strips, wire

    def handoff_scatter(self, strips: dict, strip_idx: list[int],
                        fresh_ids: list[int]) -> None:
        """Scatter wire strips ``strip_idx`` into this engine's freshly
        drawn pages (the pager's `adopt` already rebuilt the page table;
        pages it aliased against the local prefix index ship nothing and
        are absent here)."""
        self._ensure_spill_movers()
        if not fresh_ids:
            return
        assert len(strip_idx) == len(fresh_ids)
        n = len(fresh_ids)
        idx = np.zeros(self._spill_bucket(n), np.int64)
        idx[:n] = strip_idx                 # pad cols land on scratch page 0
        sub = jax.tree.map(lambda a: a[:, idx], strips)
        self._paged_cache = self._spill_scatter(
            self._paged_cache, self._spill_ids_dev(fresh_ids, n), sub)

    @staticmethod
    def _exec_jit(fn, **jit_kw):
        """jit ``fn`` keyed on the ACTIVE `core.qlinear.ExecutionConfig`.

        `qlinear_apply` reads the execution config at trace time, so a
        plain ``jax.jit`` would bake in whatever was set at the first call
        and silently ignore every later `set_execution_config(...)`.
        Every call instead looks up (or traces) a compiled instance for
        the config active *now* — flipping impl/compute_dtype retraces on
        the very next step, with the config pinned for the whole trace via
        the `execution_config` context manager.
        """
        from repro.core.qlinear import execution_config, get_execution_config
        cache: dict = {}

        def call(*args):
            cfg = get_execution_config()
            jitted = cache.get(cfg)
            if jitted is None:
                def traced(*a, _cfg=cfg):
                    with execution_config(_cfg):
                        return fn(*a)

                jitted = jax.jit(traced, **jit_kw)
                cache[cfg] = jitted
            return jitted(*args)

        # jax.jit's compiled-trace introspection, summed over the config
        # instances (tests bound the compile family through this)
        call._cache_size = lambda: sum(j._cache_size()
                                       for j in cache.values())
        return call

    def _jit_dispatch(self, fn, *, n_host: int, n_out: int):
        """jit one serving dispatch (cache donated), keyed on the active
        execution config (`_exec_jit`).

        Under a mesh the function is traced with the mesh active (so the
        model's `constrain` calls resolve) and pinned with EXPLICIT in/out
        shardings: params and cache as sharded, the ``n_host`` trailing
        operands (page tables, token blocks, per-row metadata, PRNG keys)
        and every output but the cache replicated, and the cache's out
        sharding equal to its in sharding — the donated pool buffers
        round-trip without resharding, step after step. The param
        shardings cover `PackedLinear` leaves too (`param_pspec` addresses
        them by leaf name), so the quantized model serves sharded through
        the exact same dispatches.
        """
        if self._mesh is None:
            return self._exec_jit(fn, donate_argnums=(1,))
        from repro.distributed.sharding import use_mesh

        def traced(*args):
            with use_mesh(self._mesh):
                return fn(*args)

        in_sh = (self._param_sh, self._cache_sh) + (self._repl_sh,) * n_host
        out_sh = (self._repl_sh,) * (n_out - 1) + (self._cache_sh,)
        return self._exec_jit(traced, donate_argnums=(1,),
                              in_shardings=in_sh, out_shardings=out_sh)

    def _prefill_commit_fn(self, params, cache, tokens, slot, pages,
                           temp, topk, key, start_page=0):
        """tokens [1, S] → (first sampled token, updated paged cache).

        ``start_page`` (static) skips committing the leading shared-prefix
        pages — their content is already resident and aliased read-only.
        """
        pre = self.model.init_cache(1, tokens.shape[1])
        pre, logits, _ = self.model.prefill(params, {"tokens": tokens}, pre)
        cache = commit_prefill(cache, pre, slot, pages,
                               page_size=self.page_size,
                               start_page=start_page)
        tok = sample_batched(logits, temp[None], topk[None], key)
        return tok[0], cache

    def _chunk_step_fn(self, params, cache, page_tables, tokens, pos,
                       row_slots, sample_idx, temps, topks, key):
        """Unified token-budget step: tokens/pos [B, C] → sampled [B].

        ``page_tables`` is the (bucketed) [num_slots, n_blocks] table;
        row b of the dispatch reads/writes slot ``row_slots[b]``'s row.
        """
        logits, cache = self.model.chunk_step(params, cache, tokens, pos,
                                              sample_idx,
                                              page_table=page_tables[
                                                  row_slots])
        return sample_batched(logits, temps, topks, key), cache

    def _chunk_greedy_fn(self, params, cache, page_tables, tokens, pos,
                         row_slots, sample_idx):
        """Greedy fast path: no PRNG, no sort/top-k machinery."""
        logits, cache = self.model.chunk_step(params, cache, tokens, pos,
                                              sample_idx,
                                              page_table=page_tables[
                                                  row_slots])
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    # --- speculative verify steps -----------------------------------------
    # A verify row is just a multi-token decode row of the unified chunk
    # dispatch: tokens[b, sample_idx[b] : sample_idx[b] + 1 + n_draft[b]]
    # is the run [last_sampled, d_1 … d_k] at consecutive positions, and
    # `chunk_step(num_logits = spec_k + 1)` returns the target
    # distribution after each of them. Acceptance runs on device, so the
    # vocab-sized distributions never leave it: each row returns its
    # leading-accept count and ONE corrected/bonus token.

    def _spec_gather_drafts(self, tokens, sample_idx, r):
        """draft_next [B, R]: the input token each gathered logit must
        predict — tokens at in-row index sample_idx + j + 1 (clipped;
        indices past a row's run are masked by n_draft downstream)."""
        c = tokens.shape[1]
        j = jnp.arange(r, dtype=jnp.int32)[None, :]
        nxt = jnp.clip(sample_idx[:, None].astype(jnp.int32) + j + 1,
                       0, c - 1)
        return jnp.take_along_axis(tokens, nxt, axis=1), j

    def _spec_greedy_fn(self, params, cache, page_tables, tokens, pos,
                        row_slots, sample_idx, n_draft):
        """Greedy verify: accept the longest draft prefix that matches the
        argmax chain; the fix token is the argmax after it (the corrected
        token on rejection, the bonus token on full acceptance) — exactly
        the tokens sequential greedy decode would emit."""
        r = self.spec_k + 1
        logits, cache = self.model.chunk_step(
            params, cache, tokens, pos, sample_idx,
            page_table=page_tables[row_slots], num_logits=r)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [B, R]
        draft_next, j = self._spec_gather_drafts(tokens, sample_idx, r)
        ok = (draft_next == g) & (j < n_draft[:, None])
        n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
        fix = jnp.take_along_axis(g, n_acc[:, None], axis=1)[:, 0]
        return fix, n_acc, cache

    def _spec_sampled_fn(self, params, cache, page_tables, tokens, pos,
                         row_slots, sample_idx, n_draft, temps, topks, key):
        """Acceptance sampling for point-mass drafts, distribution-faithful
        per row: draft d_j is accepted with probability p(d_j) under the
        row's (temperature / top-k filtered) target distribution; on the
        first rejection the fix token is drawn from the residual — the
        target with d_j removed, renormalized — and on full acceptance
        from the plain target at the bonus position. Marginally the
        emitted stream is distributed exactly as sequential sampling
        (greedy rows reduce to the argmax chain of `_spec_greedy_fn`).
        Rows with ``n_draft == 0`` degenerate to one plain sample at
        ``sample_idx`` — the pre-speculation contract.
        """
        r = self.spec_k + 1
        logits, cache = self.model.chunk_step(
            params, cache, tokens, pos, sample_idx,
            page_table=page_tables[row_slots], num_logits=r)   # [B, R, V]
        v = logits.shape[-1]
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None, None]
        kidx = jnp.broadcast_to(
            jnp.clip(topks - 1, 0, v - 1)[:, None, None],
            (logits.shape[0], r, 1))
        desc = -jnp.sort(-scaled, axis=-1)
        kth = jnp.take_along_axis(desc, kidx, axis=-1)
        filtered = jnp.where(scaled < kth, -1e30, scaled)
        scaled = jnp.where((topks > 0)[:, None, None], filtered, scaled)
        probs = jax.nn.softmax(scaled, axis=-1)
        draft_next, j = self._spec_gather_drafts(tokens, sample_idx, r)
        p_draft = jnp.take_along_axis(probs, draft_next[..., None],
                                      axis=-1)[..., 0]
        ku, kr, kb = jax.random.split(key, 3)
        u = jax.random.uniform(ku, p_draft.shape)
        greedy = (temps == 0.0)[:, None]
        ok = jnp.where(greedy, draft_next == g, u < p_draft)
        ok &= j < n_draft[:, None]
        n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
        dmask = jax.nn.one_hot(draft_next, v, dtype=bool)
        rej = jax.random.categorical(kr, jnp.where(dmask, -1e30, scaled))
        bon = jax.random.categorical(kb, scaled)
        # greedy rows: the residual argmax IS the global argmax (a greedy
        # rejection means draft ≠ argmax), and the bonus is the argmax too
        rej = jnp.where(greedy, g, rej.astype(jnp.int32))
        bon = jnp.where(greedy, g, bon.astype(jnp.int32))
        fix_rej = jnp.take_along_axis(rej, n_acc[:, None], axis=1)[:, 0]
        fix_bon = jnp.take_along_axis(bon, n_acc[:, None], axis=1)[:, 0]
        fix = jnp.where(n_acc == n_draft, fix_bon, fix_rej)
        return fix, n_acc, cache

    # --- tree-speculative verify steps ------------------------------------
    # A tree verify row carries a whole token TREE at contiguous KV slots
    # (node i at slot q + 1 + i, in node-index order): `chunk_step` runs
    # ONE weight pass with the per-row ancestor mask routing each node's
    # attention to exactly its own root-path, and with ``rpos`` giving
    # nodes their LOGICAL position q + depth(i) (siblings share a depth,
    # so their RoPE angles match what sequential decode would use). The
    # device-side walk picks the deepest accepted branch, and the KV of
    # that branch is compacted into the contiguous slots sequential
    # decode would have written — after the host truncates the losing
    # branches, the paged cache is bit-identical to a sequential run,
    # which is what makes greedy tree speculation token-identical
    # end-to-end (across int8 pools, prefix sharing, and the mesh).

    def _tree_compact(self, cache, pt, q, path, n_acc):
        """Gather-then-scatter the accepted branch's strips into place.

        For accepted depth ``t`` (1-based), the node at in-row index
        ``path[:, t-1]`` moves from KV slot ``q + path[:, t-1]`` to slot
        ``q + t`` in every pool leaf (int8 codes and scale strips
        included). All gathers complete before any scatter (functional
        updates), so chained moves within a row cannot clobber each
        other; no-op moves (node already in place), depths beyond
        ``n_acc`` and padding rows (``q < 0``) are redirected to the
        scratch page 0, whose content is never read.
        """
        ps = self.page_size
        dmax = path.shape[1]
        t = jnp.arange(1, dmax + 1, dtype=jnp.int32)[None, :]
        src = q[:, None] + path
        dst = q[:, None] + t
        live = (t <= n_acc[:, None]) & (path != t) & (q[:, None] >= 0)
        src_i = jnp.where(live, src, 0)
        dst_i = jnp.where(live, dst, 0)
        src_pg = jnp.take_along_axis(pt, src_i // ps, axis=1)
        dst_pg = jnp.take_along_axis(pt, dst_i // ps, axis=1)
        sp = jnp.where(live, src_pg, 0).reshape(-1)
        so = jnp.where(live, src_i % ps, 0).reshape(-1)
        dp = jnp.where(live, dst_pg, 0).reshape(-1)
        do = jnp.where(live, dst_i % ps, 0).reshape(-1)
        return {seg: {"kv_pool": {
                    k: leaf.at[:, dp, do].set(leaf[:, sp, so])
                    for k, leaf in entry["kv_pool"].items()}}
                for seg, entry in cache.items()}

    def _tree_greedy_fn(self, params, cache, page_tables, tokens, pos,
                        row_slots, sample_idx, n_draft, rpos, amask,
                        parents):
        """Greedy tree verify: one weight pass over every branch, then the
        argmax walk — emits exactly the tokens sequential greedy decode
        would (rows with ``n_draft == 0`` degenerate to plain decode)."""
        r = self.spec_k + 1
        pt = page_tables[row_slots]
        logits, cache = self.model.chunk_step(
            params, cache, tokens, pos, sample_idx, page_table=pt,
            num_logits=r, rpos=rpos, amask=amask)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        fix, n_acc, path = _tree_walk_greedy(g, tokens, parents, n_draft,
                                             self.spec_k)
        cache = self._tree_compact(cache, pt, pos[:, 0], path, n_acc)
        return fix, n_acc, path, cache

    def _tree_sampled_fn(self, params, cache, page_tables, tokens, pos,
                         row_slots, sample_idx, n_draft, rpos, amask,
                         parents, temps, topks, key):
        """Sampled tree verify: residual acceptance over sibling branches
        (see `_tree_walk_sampled`); greedy rows ride a one-hot target, so
        mixed-sampler steps keep their greedy rows argmax-exact."""
        r = self.spec_k + 1
        pt = page_tables[row_slots]
        logits, cache = self.model.chunk_step(
            params, cache, tokens, pos, sample_idx, page_table=pt,
            num_logits=r, rpos=rpos, amask=amask)
        v = logits.shape[-1]
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits / jnp.where(temps > 0, temps, 1.0)[:, None, None]
        kidx = jnp.broadcast_to(
            jnp.clip(topks - 1, 0, v - 1)[:, None, None],
            (logits.shape[0], r, 1))
        desc = -jnp.sort(-scaled, axis=-1)
        kth = jnp.take_along_axis(desc, kidx, axis=-1)
        filtered = jnp.where(scaled < kth, -1e30, scaled)
        scaled = jnp.where((topks > 0)[:, None, None], filtered, scaled)
        probs = jax.nn.softmax(scaled, axis=-1)
        probs = jnp.where((temps == 0.0)[:, None, None],
                          jax.nn.one_hot(g, v, dtype=probs.dtype), probs)
        fix, n_acc, path = _tree_walk_sampled(probs, tokens, parents,
                                              n_draft, self.spec_k, key)
        cache = self._tree_compact(cache, pt, pos[:, 0], path, n_acc)
        return fix, n_acc, path, cache

    # --- draft-model drafting (spec_decode="draft_model") -----------------
    # The draft model keeps a DENSE per-slot cache [num_slots, max_seq]
    # (it is small by construction — paging it would buy nothing): lazy
    # per-slot prefill when a request starts decoding, then k + 1 greedy
    # decode steps per scheduler step (the extra step writes the last
    # draft's KV, so after full acceptance the draft cache is already
    # caught up to the bonus token's position). Rejected-draft KV is
    # simply overwritten — positions are absolute, and the next step's
    # inputs rewrite every position past the accepted stream before any
    # causal read can see it.

    def _draft_init(self):
        self._draft_cache = self.draft_model.init_cache(self.num_slots,
                                                        self.max_seq)
        self._draft_rid: dict[int, int] = {}
        self._draft_prefill = self._exec_jit(self._draft_prefill_fn,
                                             donate_argnums=(1,))
        self._draft_step = self._exec_jit(self._draft_step_fn,
                                          donate_argnums=(1,))
        self._draft_top = self._exec_jit(self._draft_top_fn,
                                         donate_argnums=(1,),
                                         static_argnums=(4,))

    def _draft_prefill_fn(self, params, dcache, tokens, slot):
        """tokens [1, S] → draft cache with slot's rows 0..S-1 rewritten.

        ``tokens`` is the context zero-padded up to a geometric length
        bucket (`_draft_bucket`), so this compiles O(log max_seq) times
        instead of once per context length. The pad tail's KV (a zero
        continuation of the real prefix) lands at positions ≥ the real
        context length — exactly the positions drafting rewrites before
        any causal read can see them, the same dead-KV argument that
        covers rejected drafts.
        """
        from repro.serving.kv_pager import _commit_dense_leaf
        pre = self.draft_model.init_cache(1, tokens.shape[1])
        pre, _, _ = self.draft_model.prefill(params, {"tokens": tokens}, pre)
        return {seg: {"kv": {k: _commit_dense_leaf(entry["kv"][k],
                                                   pre[seg]["kv"][k], slot)
                             for k in entry["kv"]}}
                for seg, entry in dcache.items()}

    def _draft_bucket(self, n: int) -> int:
        """Geometric draft-prefill length bucket covering ``n`` tokens."""
        b = 8
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _draft_step_fn(self, params, dcache, token, pos):
        logits, dcache = self.draft_model.decode_step(params, dcache,
                                                      token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), dcache

    def _draft_top_fn(self, params, dcache, token, pos, f):
        """Top-``f`` next tokens per row (column 0 = the argmax) — the
        branching first step of tree drafting."""
        logits, dcache = self.draft_model.decode_step(params, dcache,
                                                      token, pos)
        return jax.lax.top_k(logits, f)[1].astype(jnp.int32), dcache

    def _draft_fn(self, reqs):
        """Scheduler drafting hook: [(slot, rid, ctx, next_pos, k_eff)] →
        {slot: draft tokens} via greedy draft-model decode."""
        b = self.num_slots
        for slot, rid, ctx, q, _k in reqs:
            if self._draft_rid.get(slot) != rid:   # slot reused: re-prefill
                padded = np.zeros(self._draft_bucket(q), np.int32)
                padded[:q] = ctx[:q]
                self._draft_cache = self._draft_prefill(
                    self.draft_params, self._draft_cache,
                    jnp.asarray(padded)[None, :], jnp.int32(slot))
                self._draft_rid[slot] = rid
        tok = np.zeros(b, np.int32)
        posv = np.zeros(b, np.int32)
        active: dict[int, int] = {}
        for slot, _rid, ctx, q, k in reqs:
            tok[slot] = int(ctx[-1])
            posv[slot] = q
            active[slot] = k
        props: dict[int, list[int]] = {slot: [] for slot in active}
        k_max = max(active.values())
        for i in range(k_max + 1):
            nxt, self._draft_cache = self._draft_step(
                self.draft_params, self._draft_cache,
                jnp.asarray(tok), jnp.asarray(posv))
            nxt = np.asarray(nxt)
            for slot, k in active.items():
                if i < k:
                    props[slot].append(int(nxt[slot]))
                    tok[slot] = int(nxt[slot])
                    posv[slot] += 1
                # i ≥ k: frozen — the row idempotently rewrites its last
                # draft's KV (rows of inactive slots idle at position 0,
                # which the next per-slot prefill rewrites)
        return props

    def _draft_tree_fn(self, reqs):
        """Tree drafting hook (``spec_tree``): the draft model's top-
        ``fanout`` first-step tokens branch the root — the top-1 opens
        the primary chain (continued greedily), the rest become depth-1
        alternates hedging a chain miss. Alternates consume node budget:
        the chain keeps ``k_eff − #alternates`` nodes, so the row width
        never exceeds the linear verify bucket. Same lazy per-slot
        dense-cache prefill and idempotent-rewrite argument as
        `_draft_fn`; requests carry a trailing ``fanout`` element."""
        b = self.num_slots
        for slot, rid, ctx, q, _k, _f in reqs:
            if self._draft_rid.get(slot) != rid:   # slot reused: re-prefill
                padded = np.zeros(self._draft_bucket(q), np.int32)
                padded[:q] = ctx[:q]
                self._draft_cache = self._draft_prefill(
                    self.draft_params, self._draft_cache,
                    jnp.asarray(padded)[None, :], jnp.int32(slot))
                self._draft_rid[slot] = rid
        tok = np.zeros(b, np.int32)
        posv = np.zeros(b, np.int32)
        chain: dict[int, int] = {}        # slot → chain length left
        fans: dict[int, int] = {}
        for slot, _rid, ctx, q, k, f in reqs:
            tok[slot] = int(ctx[-1])
            posv[slot] = q
            chain[slot] = k
            fans[slot] = f
        fmax = max(max(fans.values()), 1)
        nodes: dict[int, list[tuple[int, int]]] = {s: [] for s in chain}
        last: dict[int, int] = {}         # slot → chain tip node index
        alts: dict[int, list[int]] = {}
        for i in range(max(chain.values()) + 1):
            if i == 0:
                top, self._draft_cache = self._draft_top(
                    self.draft_params, self._draft_cache,
                    jnp.asarray(tok), jnp.asarray(posv), fmax)
                top = np.asarray(top)
                nxt = top[:, 0]
            else:
                nxt, self._draft_cache = self._draft_step(
                    self.draft_params, self._draft_cache,
                    jnp.asarray(tok), jnp.asarray(posv))
                nxt = np.asarray(nxt)
            for slot, k in chain.items():
                if i == 0:
                    a = [int(t) for t in top[slot, 1:fans[slot]]][:k - 1]
                    alts[slot] = a
                    chain[slot] = k - len(a)   # chain keeps the rest
                    nodes[slot].append((int(nxt[slot]), -1))
                    last[slot] = 0
                    tok[slot] = int(nxt[slot])
                    posv[slot] += 1
                elif i < chain[slot]:
                    nodes[slot].append((int(nxt[slot]), last[slot]))
                    last[slot] = len(nodes[slot]) - 1
                    tok[slot] = int(nxt[slot])
                    posv[slot] += 1
                # i ≥ chain length: frozen, same dead-KV argument as above
        for slot, a in alts.items():
            nodes[slot].extend((t, -1) for t in a)
        return nodes

    def _decode_paged_fn(self, params, cache, page_tables, token, pos,
                         temps, topks, key):
        logits, cache = self.model.decode_step(params, cache, token, pos,
                                               page_table=page_tables)
        return sample_batched(logits, temps, topks, key), cache

    def _decode_greedy_fn(self, params, cache, page_tables, token, pos):
        """Greedy fast path: no PRNG, no sort/top-k machinery."""
        logits, cache = self.model.decode_step(params, cache, token, pos,
                                               page_table=page_tables)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    # --- executor callables handed to the Scheduler (host-side glue) ------
    def _device_tables(self, n_blocks: int | None = None, host_tables=None):
        """Version-cached device copy of the pager's page tables, optionally
        sliced to the first ``n_blocks`` columns (the context bucket).
        ``host_tables`` lets an executor supply the host array it was
        handed (the Scheduler contract) instead of reading the pager."""
        pager = self._scheduler.pager
        if self._tables_version != pager.version:   # upload only on mutation
            src = pager.page_tables if host_tables is None else host_tables
            if self._mesh is not None:   # page IDs are device-agnostic:
                self._tables_dev = jax.device_put(src, self._repl_sh)
            else:                        # tables replicate across the mesh
                self._tables_dev = jnp.asarray(src)
            self._tables_version = pager.version
            self._tables_sliced = {}
        if n_blocks is None or n_blocks == self._tables_dev.shape[1]:
            return self._tables_dev
        if n_blocks not in self._tables_sliced:
            self._tables_sliced[n_blocks] = self._tables_dev[:, :n_blocks]
        return self._tables_sliced[n_blocks]

    def _context_bucket(self, max_pos: int) -> int:
        """Pages the unified step must read to cover ``max_pos``, rounded
        up to a geometric bucket (8, 16, 32, … pages, capped at slot
        capacity).

        The chunk dispatch's attention cost scales with the page-table
        width it reads, so reading the full slot capacity every step
        would make a long-context engine pay max_seq work from the first
        chunk. Bucketing keeps the compiled-variant family at
        O(log pages_per_slot) — independent of the prompt-length mix —
        while step cost tracks the actual committed context.
        """
        pps = self.max_seq // self.page_size
        need = max_pos // self.page_size + 1
        b = 8
        while b < need:
            b *= 2
        return min(b, pps)

    def _exec_run_batch(self, tokens, pos, row_slots, sample_idx, temps,
                        topks, n_draft=None, tree=None):
        tables = self._device_tables(self._context_bucket(int(pos.max())))
        if tree is not None:
            # tree verify: per-row ancestor masks + logical positions in,
            # (corrected token, accepted depth, accepted branch) out —
            # the accepted KV is already compacted on device
            targs = (jnp.asarray(tokens), jnp.asarray(pos),
                     jnp.asarray(row_slots), jnp.asarray(sample_idx),
                     jnp.asarray(n_draft), jnp.asarray(tree["rpos"]),
                     jnp.asarray(tree["amask"]),
                     jnp.asarray(tree["parents"]))
            if not temps.any() and not topks.any():
                fix, n_acc, path, self._paged_cache = self._tree_greedy(
                    self._params_run, self._paged_cache, tables, *targs)
            else:
                self._key, sub = jax.random.split(self._key)
                fix, n_acc, path, self._paged_cache = self._tree_sampled(
                    self._params_run, self._paged_cache, tables, *targs,
                    jnp.asarray(temps), jnp.asarray(topks), sub)
            return np.asarray(fix), np.asarray(n_acc), np.asarray(path)
        if n_draft is not None and n_draft.any():
            # at least one verify run: the speculative step returns, per
            # row, the leading-accept count + corrected/bonus token
            if not temps.any() and not topks.any():
                fix, n_acc, self._paged_cache = self._spec_greedy(
                    self._params_run, self._paged_cache, tables,
                    jnp.asarray(tokens), jnp.asarray(pos),
                    jnp.asarray(row_slots), jnp.asarray(sample_idx),
                    jnp.asarray(n_draft))
            else:
                self._key, sub = jax.random.split(self._key)
                fix, n_acc, self._paged_cache = self._spec_sampled(
                    self._params_run, self._paged_cache, tables,
                    jnp.asarray(tokens), jnp.asarray(pos),
                    jnp.asarray(row_slots), jnp.asarray(sample_idx),
                    jnp.asarray(n_draft), jnp.asarray(temps),
                    jnp.asarray(topks), sub)
            return np.asarray(fix), np.asarray(n_acc)
        if not temps.any() and not topks.any():
            out, self._paged_cache = self._chunk_greedy(
                self._params_run, self._paged_cache, tables,
                jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(row_slots), jnp.asarray(sample_idx))
        else:
            self._key, sub = jax.random.split(self._key)
            out, self._paged_cache = self._chunk_sampled(
                self._params_run, self._paged_cache, tables,
                jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(row_slots), jnp.asarray(sample_idx),
                jnp.asarray(temps), jnp.asarray(topks), sub)
        out = np.asarray(out)
        if n_draft is None:
            return out
        return out, np.zeros(out.shape[0], np.int32)

    def warmup(self, sampled: bool = False) -> int:
        """Precompile the chunked step family: every geometric context
        bucket × every width bucket the run-length packer may pick
        (× the speculative verify variants when spec_decode is on, × the
        sampled variants on request). All-padding dispatches only touch
        the scratch page, so serving state is unaffected. Returns the
        number of variants compiled; no-op on the one-shot path (its
        prefill compiles per prompt length at admission)."""
        if self._scheduler is None:
            self._scheduler = self._serving_init()
        if not self._scheduler.chunked:
            return 0
        # enumerate the bucket families through _context_bucket and the
        # scheduler's width_family itself, so warmup can never drift from
        # the schedule the serving loop uses
        buckets = {self._context_bucket(p)
                   for p in range(0, self.max_seq, self.page_size)}
        b = self.num_slots
        n = 0
        for nb in sorted(buckets):
            tables = self._device_tables(nb)
            for c in self._scheduler.width_buckets:
                args = (jnp.zeros((b, c), jnp.int32),
                        jnp.full((b, c), -1, jnp.int32),
                        jnp.zeros(b, jnp.int32), jnp.zeros(b, jnp.int32))
                _, self._paged_cache = self._chunk_greedy(
                    self._params_run, self._paged_cache, tables, *args)
                n += 1
                if sampled:
                    self._key, sub = jax.random.split(self._key)
                    _, self._paged_cache = self._chunk_sampled(
                        self._params_run, self._paged_cache, tables, *args,
                        jnp.zeros(b, jnp.float32), jnp.zeros(b, jnp.int32),
                        sub)
                    n += 1
                if self.spec_decode is None or c < 2:
                    continue        # a width-1 row can never carry a draft
                nd = jnp.zeros(b, jnp.int32)
                _, _, self._paged_cache = self._spec_greedy(
                    self._params_run, self._paged_cache, tables, *args, nd)
                n += 1
                if sampled:
                    self._key, sub = jax.random.split(self._key)
                    _, _, self._paged_cache = self._spec_sampled(
                        self._params_run, self._paged_cache, tables, *args, nd,
                        jnp.zeros(b, jnp.float32), jnp.zeros(b, jnp.int32),
                        sub)
                    n += 1
                if not self.spec_tree:
                    continue
                # tree-verify variants: all-padding rows with an all-false
                # ancestor mask (nothing visible in-span → exact-zero rows)
                targs = args + (nd, jnp.full((b, c), -1, jnp.int32),
                                jnp.zeros((b, c, c), jnp.bool_),
                                jnp.full((b, c), -1, jnp.int32))
                _, _, _, self._paged_cache = self._tree_greedy(
                    self._params_run, self._paged_cache, tables, *targs)
                n += 1
                if sampled:
                    self._key, sub = jax.random.split(self._key)
                    _, _, _, self._paged_cache = self._tree_sampled(
                        self._params_run, self._paged_cache, tables, *targs,
                        jnp.zeros(b, jnp.float32), jnp.zeros(b, jnp.int32),
                        sub)
                    n += 1
        return n

    def _exec_prefill_commit(self, req: Request, slot: int,
                             pages: list[int], n_shared: int = 0) -> int:
        self._key, sub = jax.random.split(self._key)
        toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
        tok, self._paged_cache = self._prefill_fused(
            self.params, self._paged_cache, toks, jnp.int32(slot),
            jnp.asarray(pages, jnp.int32),
            jnp.asarray(req.temperature, jnp.float32),
            jnp.asarray(req.top_k, jnp.int32), sub, n_shared)
        return int(tok)

    def _exec_decode(self, page_tables, token, pos, temps, topks
                     ) -> np.ndarray:
        tables = self._device_tables(host_tables=page_tables)
        if not temps.any() and not topks.any():
            next_tok, self._paged_cache = self._decode_greedy(
                self.params, self._paged_cache, tables,
                jnp.asarray(token), jnp.asarray(pos))
        else:
            self._key, sub = jax.random.split(self._key)
            next_tok, self._paged_cache = self._decode_paged(
                self.params, self._paged_cache, tables,
                jnp.asarray(token), jnp.asarray(pos), jnp.asarray(temps),
                jnp.asarray(topks), sub)
        return np.asarray(next_tok)

    def submit(self, tokens, max_new_tokens: int,
               sampler: SamplerConfig | None = None,
               eos_id: int | None = None,
               prefix_id: str | None = None,
               priority: int = 0, n: int = 1) -> int | list[int]:
        """Queue one request; returns its request id (or ``n`` ids).

        ``prefix_id`` opts the request into prefix sharing: requests
        carrying the same id alias any already-resident full KV pages
        whose token content matches their prompt's page-aligned prefix
        (typically a common system prompt), copy-on-write on the partial
        tail page. Greedy streams are token-identical with or without it.

        ``priority`` is the request's SLO class (higher = more urgent):
        admission strictly prefers higher classes, and with
        ``preemption=True`` a stalled higher class spills a lower-class
        victim's KV pages to the host tier and takes its slot; the victim
        restores later with zero recompute. Priorities reorder
        **scheduling**, never tokens — every stream stays identical to
        its uninterrupted run.

        ``n > 1`` requests parallel sampling: ``n`` continuations of the
        same prompt, returned as a list of request ids. The siblings
        share one prefix namespace (an auto-generated one when
        ``prefix_id`` is None), so the prompt's full KV pages are
        physically written once and aliased read-only by the other
        ``n - 1`` slots via the pager's refcounts; each slot
        copy-on-writes only its partial tail page when its own decode
        diverges. Greedy siblings emit identical streams; sampled
        siblings draw independently (one fresh key per dispatch).
        """
        if self._scheduler is None:
            self._scheduler = self._serving_init()
        if n < 1:
            raise ValueError(f"n must be >= 1, got {n}")
        s = sampler or self.sampler
        pid = prefix_id
        if n > 1 and pid is None:
            pid = f"__par{self._next_rid}"
        rids = []
        for _ in range(n):
            rid = self._next_rid
            self._next_rid += 1
            self._scheduler.submit(Request(
                rid=rid, tokens=np.asarray(tokens, np.int32).reshape(-1),
                max_new_tokens=max_new_tokens, temperature=s.temperature,
                top_k=s.top_k,
                eos_id=self.eos_id if eos_id is None else eos_id,
                prefix_id=pid, priority=priority))
            rids.append(rid)
        return rids if n > 1 else rids[0]

    def preempt(self, rid: int) -> bool:
        """Spill ``rid``'s slot to the host tier now (ops/test hook —
        organic preemption is priority-driven). False when ``rid`` holds
        no slot. Requires ``preemption=True``."""
        if self._scheduler is None:
            return False
        return self._scheduler.preempt_request(rid)

    def pin_prefix(self, prefix_id: str) -> int:
        """Keep ``prefix_id``'s indexed KV pages resident across bursts.

        Call while (or after) a request carrying the prefix is being
        served — the pin refcounts every page currently indexed under the
        namespace, plus any registered under it later, so the next burst
        aliases the prefix without recomputing its KV (under chunked
        prefill that skips the prefill FLOPs too). Returns the number of
        pages pinned now. Pinned pages count against the admission
        budget until `unpin_prefix` releases them.
        """
        if self._scheduler is None:
            self._scheduler = self._serving_init()
        return self._scheduler.pager.pin_prefix(prefix_id)

    def unpin_prefix(self, prefix_id: str) -> int:
        """Release a `pin_prefix` hold; unowned pages free exactly once."""
        if self._scheduler is None:
            return 0
        return self._scheduler.pager.unpin_prefix(prefix_id)

    def step(self) -> list[tuple[int, int]]:
        """One scheduler step → list of (rid, token) stream events."""
        if self._scheduler is None:
            return []
        return self._scheduler.step()

    def collect(self) -> dict[int, np.ndarray]:
        """Drain finished requests accumulated so far: {rid: tokens}."""
        if self._scheduler is None:
            return {}
        out = dict(self._scheduler.finished)
        self._scheduler.finished.clear()
        return out

    def drain(self) -> dict[int, np.ndarray]:
        """Step until queue + slots are empty; returns all finished."""
        if self._scheduler is None:
            return {}
        out = self.collect()
        out.update(self._scheduler.run())
        return out

    @property
    def idle(self) -> bool:
        """True when no requests are queued or in flight."""
        return self._scheduler is None or self._scheduler.idle

    @property
    def num_active(self) -> int:
        """Requests currently holding a decode slot."""
        return 0 if self._scheduler is None else self._scheduler.num_active

    @property
    def scheduler_stats(self):
        return self._scheduler.stats if self._scheduler else None

    # ------------------------------------------------------------- snapshot
    def stats(self) -> EngineStats:
        """One structured serving snapshot (see `EngineStats`).

        The public metrics surface: benchmarks and dashboards read THIS,
        not the scheduler's or pager's internal counters. Initializes the
        serving state lazily (like `submit`) so a fresh engine can be
        inspected before its first request.
        """
        if self._scheduler is None:
            self._scheduler = self._serving_init()
        st = self._scheduler.stats
        pool_total = pool_per_dev = 0
        for seg in self._paged_cache.values():
            pool = seg.get("kv_pool")
            if not pool:
                continue
            for a in pool.values():
                pool_total += int(np.prod(a.shape)) * a.dtype.itemsize
                shard = a.sharding.shard_shape(a.shape) \
                    if hasattr(a, "sharding") else a.shape
                pool_per_dev += int(np.prod(shard)) * a.dtype.itemsize
        valid = st.dispatched_positions - st.padded_positions
        fixed_total = valid + st.padded_positions_fixed
        model_axis = 1 if self._mesh is None \
            else int(self._mesh.shape.get("model", 1))
        pager_stats = self._scheduler.pager.stats()
        return EngineStats(
            pager=pager_stats,
            dispatches=st.decode_steps,
            prefill_tokens=st.prefill_tokens,
            prefill_tokens_skipped=st.prefill_tokens_skipped,
            prefix_shared_pages=st.prefix_shared_pages,
            padding_waste=st.padding_waste,
            padding_waste_fixed=(st.padded_positions_fixed
                                 / max(fixed_total, 1)),
            acceptance_rate=st.acceptance_rate,
            spec_tokens_per_row=st.spec_tokens_per_row,
            draft_tokens=st.draft_tokens,
            accepted_tokens=st.accepted_tokens,
            rollbacks=st.rollbacks,
            spec_k_now=self._scheduler.spec_k_cur,
            spec_fanout_now=getattr(self._scheduler, "fanout_cur", 1),
            preemptions=st.preemptions,
            pressure_spills=st.pressure_spills,
            restores=st.restores,
            spilled_pages=st.spilled_pages,
            restored_pages=st.restored_pages,
            pages_spilled_now=pager_stats.pages_spilled,
            restore_ms_mean=(st.restore_time_s * 1e3
                             / max(st.restores, 1)),
            model_axis=model_axis,
            kv_pool_bytes=pool_total,
            kv_pool_bytes_per_device=pool_per_dev,
            kv_bytes_per_token=self.paged_kv_bytes_per_token(),
            weight_bytes=self.weight_stream_bytes(),
            weight_bytes_per_token=self.weight_bytes_per_token(
                st.spec_tokens_per_row),
            queue_depth=(len(self._scheduler.queue)
                         + len(self._scheduler.preempted)),
            admission_headroom=max(
                0, pager_stats.pages_free - pager_stats.pages_reserved))

    def reset_stats(self) -> None:
        """Zero the cumulative counters behind `stats()` (occupancy and
        the adaptive ``spec_k`` state are live state, not counters, and
        are untouched) — benchmarks call this between warmup and the
        timed run.

        Resets **in place** via `SchedulerStats.zero()`: the stats object
        keeps its identity (held references stay live) and any field
        without a declared default — e.g. one a subclass binds at
        construction — survives, where rebuilding via ``type(stats)()``
        would raise or silently drop it.
        """
        if self._scheduler is not None:
            self._scheduler.stats.zero()

    def prefix_reuse_pages(self, tokens, prefix_id) -> int:
        """Exact count of already-resident KV pages a request with this
        prompt + ``prefix_id`` would alias instead of recomputing.

        This is the fleet router's affinity signal: the prefix index is
        content-addressed, so the count is exact — not an estimate. A
        fresh engine (serving never initialized) holds no pages and
        reports 0 without allocating anything.
        """
        if prefix_id is None or self._scheduler is None:
            return 0
        return len(self._scheduler.pager.match_prefix(tokens, prefix_id))

    # --------------------------------------------------- capacity accounting
    def paged_kv_page_bytes(self) -> int:
        """Bytes one physical page costs across all layers (codes + scale
        strips for int8 pools) — the unit of the serving memory budget.

        Pure shape accounting: when serving is not yet initialized the
        cache layout is traced with `jax.eval_shape`, so nothing is
        allocated on device.
        """
        if self._scheduler is not None:
            cache = self._paged_cache
            num_pages = self._scheduler.pager.cfg.num_pages
        else:
            pages_per_slot = self.max_seq // self.page_size
            num_pages = self._num_pages
            if num_pages is None:
                num_pages = self.num_slots * pages_per_slot + 1
            cache = jax.eval_shape(
                lambda: self.model.init_paged_cache(
                    self.num_slots, num_pages, self.page_size, self.max_seq,
                    kv_quant=self.kv_quant))
        total = 0
        for seg in cache.values():
            pool = seg.get("kv_pool")
            if pool:
                total += sum(int(np.prod(a.shape)) * a.dtype.itemsize
                             for a in pool.values())
        return total // num_pages

    def paged_kv_bytes_per_token(self) -> float:
        """KV bytes per cached token in the page pools (all layers)."""
        return self.paged_kv_page_bytes() / self.page_size

    def weight_stream_bytes(self) -> int:
        """Resident bytes of the served params — what ONE decode step
        streams through the matmul units. `PackedLinear` leaves count
        their int4 packing plus scales/zeros/input_scale metadata, so for
        the quantized model this is the paper's ~3.6× compression lever
        on the decode roofline."""
        from repro.utils.tree import leaf_bytes
        return leaf_bytes(self.params)

    def weight_bytes_per_token(self, spec_tokens_per_row: float = 0.0
                               ) -> float:
        """Weight bytes streamed per EMITTED token: the full weight pass,
        amortized over the tokens each decode row emits per dispatch
        (> 1 only under speculative decoding)."""
        return self.weight_stream_bytes() / max(spec_tokens_per_row, 1.0)

    def generate_scan(self, batch: dict, max_new_tokens: int, key=None):
        """Fixed-length scan generation (benchmark path, single dispatch)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b = next(iter(batch.values())).shape[0]
        cache = self.model.init_cache(b, self.max_seq)

        @self._exec_jit
        def run(params, batch, cache, key):
            cache, logits, pos = self.model.prefill(params, batch, cache)
            tok0 = sample(logits, self.sampler, key)

            def body(carry, _):
                tok, cache, pos, key = carry
                key, sub = jax.random.split(key)
                logits, cache = self.model.decode_step(params, cache, tok,
                                                       pos)
                nxt = sample(logits, self.sampler, sub)
                return (nxt, cache, pos + 1, key), tok

            (_, _, _, _), toks = jax.lax.scan(
                body, (tok0, cache, pos, key), None,
                length=max_new_tokens)
            return jnp.moveaxis(toks, 0, 1)

        return np.asarray(run(self.params, batch, cache, key))
