from repro.kernels import ops, paged_attention, ref  # noqa: F401
