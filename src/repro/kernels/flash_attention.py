"""Pallas TPU flash attention (§Perf C3): tiled online-softmax attention.

Why it exists here: the roofline of long-context prefill (§Roofline,
smollm/hubert/glm4 prefill_32k) is dominated by f32 score tensors hitting
HBM — ~2 TB/layer at S=32k. Flash tiling (Dao et al.; TPU adaptation per
the splash-kernel lineage) keeps each [block_q × block_k] score tile in
VMEM and carries the online-softmax state (running max m, normalizer l,
accumulator) across the K grid axis, so score traffic never leaves VMEM.

Supports: causal masking, sliding windows (gemma3/hymba local layers), GQA
(q-head → kv-head mapping in the BlockSpec index maps). Validated in
interpret mode against `ref.flash_attention_ref` over
shape/window/GQA sweeps (tests/test_flash_attention.py).

Layout: q [B, H, S, hd], k/v [B, Hkv, S, hd] — grid (B·H, S/bq, S/bk),
K innermost (accumulation), online state in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.utils.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, n_k: int, scale: float,
                  causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # [bq, 128] replicated
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]          # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)              # [bq, 128]
    p = jnp.exp(s - m_new[:, :1])                # [bq, bk]
    l_new = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=1)[:, None], l_prev.shape)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows → 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "window", "block_q",
                              "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float | None = None, causal: bool = True,
                    window: int = 0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q [B, H, S, hd], k/v [B, Hkv, S, hd] → [B, H, S, hd].

    GQA: H % Hkv == 0; q head h reads kv head h // (H // Hkv).
    S must divide by block_q/block_k (the wrapper in ops pads).
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    n_k = s // block_k
    grid = (b * h, s // block_q, n_k)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
        scale=scale, causal=causal, window=window)

    qf = q.reshape(b * h, s, hd)
    kf = k.reshape(b * hkv, s, hd)
    vf = v.reshape(b * hkv, s, hd)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, _g=g: (bh // _g, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, _g=g: (bh // _g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 128), jnp.float32),
                        pltpu.VMEM((block_q, 128), jnp.float32),
                        pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)
