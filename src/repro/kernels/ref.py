"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated elementwise against these
references (interpret mode on CPU, sweeping shapes/dtypes). The math here is
the paper's PE dataflow (Fig. 4d): ``w = (q - zero) * scale``, then MAC with
the input activation, accumulated in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedLinear, unpack_int4


def dequant_ref(qweight: jax.Array, scales: jax.Array, zeros: jax.Array,
                group_size: int, dtype=jnp.float32) -> jax.Array:
    """Unpack + dequantize packed weights → float ``[K, N]``."""
    q = unpack_int4(qweight)  # [K, N] int32
    k, n = q.shape
    g = k // group_size
    qg = q.reshape(g, group_size, n).astype(jnp.float32)
    w = (qg - zeros[:, None, :].astype(jnp.float32)) \
        * scales[:, None, :].astype(jnp.float32)
    return w.reshape(k, n).astype(dtype)


def awq_matmul_ref(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                   zeros: jax.Array, group_size: int,
                   compute_dtype=jnp.float32) -> jax.Array:
    """``x [M, K] @ dequant(qweight) [K, N] -> [M, N] float32``."""
    w = dequant_ref(qweight, scales, zeros, group_size, compute_dtype)
    return jnp.dot(x.astype(compute_dtype), w,
                   preferred_element_type=jnp.float32)


def awq_matmul_ref_packed(x: jax.Array, p: PackedLinear,
                          compute_dtype=jnp.float32) -> jax.Array:
    return awq_matmul_ref(x, p.qweight, p.scales, p.zeros, p.group_size,
                          compute_dtype)


def awq_gateup_ref(x: jax.Array, qw_gate, s_gate, z_gate, qw_up, s_up, z_up,
                   group_size: int, compute_dtype=jnp.float32) -> jax.Array:
    """Fused SwiGLU FFN front: ``silu(x @ Wg) * (x @ Wu)`` (paper Table I's
    dominant 51% row, gate+up projections)."""
    g = awq_matmul_ref(x, qw_gate, s_gate, z_gate, group_size, compute_dtype)
    u = awq_matmul_ref(x, qw_up, s_up, z_up, group_size, compute_dtype)
    return jax.nn.silu(g) * u


def paged_attention_ref(q, k_pool, ks, v_pool, vs, page_table, pos, *,
                        scale=None):
    """Oracle for the fused dequant + paged-attention decode kernel.

    q [B, Hkv, G, hd]; k/v pools [N, P, Hkv, hd] int8; ks/vs [N, P, Hkv]
    f32 scale strips; page_table [B, pages_per_slot] int32; pos [B] int32
    (inclusive last valid position). Gathers the slot's pages into logical
    order, dequantizes, then runs plain masked softmax attention —
    exactly the jnp fallback path in `models.attention`.
    """
    b, hkv, g, hd = q.shape
    page_size = k_pool.shape[1]
    s_slot = page_table.shape[1] * page_size
    scale = scale if scale is not None else hd ** -0.5
    k = (k_pool.astype(jnp.float32)
         * ks[..., None].astype(jnp.float32))[page_table]
    v = (v_pool.astype(jnp.float32)
         * vs[..., None].astype(jnp.float32))[page_table]
    k = k.reshape(b, s_slot, hkv, hd)
    v = v.reshape(b, s_slot, hkv, hd)
    sc = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), k) * scale
    valid = jnp.arange(s_slot)[None, :] <= pos[:, None]    # [B, S]
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p, v)


def paged_attention_chunk_ref(q, k_pool, ks, v_pool, vs, page_table, pos, *,
                              scale=None):
    """Oracle for the multi-query (chunked-prefill) paged-attention kernel.

    q [B, C, Hkv, G, hd] — C queries per batch row (a prefill chunk, or a
    single decode token at C=1); k/v pools [N, P, Hkv, hd] int8 with
    ks/vs [N, P, Hkv] f32 scale strips; page_table [B, pages_per_slot]
    int32 (one table row per batch row — all C queries of a row belong to
    the same request slot); pos [B, C] int32 absolute query positions,
    ``-1`` marking padding queries (masked everywhere, output zero).

    Each query attends causally over its slot's committed pages:
    ``k_pos <= pos[b, c]``. Every position at or below a valid query's
    position holds real committed KV (earlier chunks, aliased
    shared-prefix pages, or this chunk's own tokens written before the
    read), so the arange-based mask is exact.
    """
    b, c, hkv, g, hd = q.shape
    page_size = k_pool.shape[1]
    s_slot = page_table.shape[1] * page_size
    scale = scale if scale is not None else hd ** -0.5
    k = (k_pool.astype(jnp.float32)
         * ks[..., None].astype(jnp.float32))[page_table]
    v = (v_pool.astype(jnp.float32)
         * vs[..., None].astype(jnp.float32))[page_table]
    k = k.reshape(b, s_slot, hkv, hd)
    v = v.reshape(b, s_slot, hkv, hd)
    sc = jnp.einsum("bckgd,bskd->bckgs", q.astype(jnp.float32), k) * scale
    causal = (jnp.arange(s_slot)[None, None, :]
              <= pos[:, :, None])                          # [B, C, S]
    sc = jnp.where(causal[:, :, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bckgs,bskd->bckgd", p, v)
    return jnp.where((pos >= 0)[:, :, None, None, None], out, 0.0)


def flash_attention_ref(q, k, v, *, scale=None, causal=True,
                        window: int = 0):
    """Oracle for the flash kernel: plain masked softmax attention.

    q [B, H, S, hd], k/v [B, Hkv, S, hd] → [B, H, S, hd] (GQA broadcast).
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, hkv, g, s, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, s, hd).astype(q.dtype)
