"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated elementwise against these
references (interpret mode on CPU, sweeping shapes/dtypes). The math here is
the paper's PE dataflow (Fig. 4d): ``w = (q - zero) * scale``, then MAC with
the input activation, accumulated in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedLinear, unpack_int4


def dequant_ref(qweight: jax.Array, scales: jax.Array, zeros: jax.Array,
                group_size: int, dtype=jnp.float32) -> jax.Array:
    """Unpack + dequantize packed weights → float ``[K, N]``."""
    q = unpack_int4(qweight)  # [K, N] int32
    k, n = q.shape
    g = k // group_size
    qg = q.reshape(g, group_size, n).astype(jnp.float32)
    w = (qg - zeros[:, None, :].astype(jnp.float32)) \
        * scales[:, None, :].astype(jnp.float32)
    return w.reshape(k, n).astype(dtype)


def awq_matmul_ref(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                   zeros: jax.Array, group_size: int,
                   compute_dtype=jnp.float32) -> jax.Array:
    """``x [M, K] @ dequant(qweight) [K, N] -> [M, N] float32``."""
    w = dequant_ref(qweight, scales, zeros, group_size, compute_dtype)
    return jnp.dot(x.astype(compute_dtype), w,
                   preferred_element_type=jnp.float32)


def awq_matmul_ref_packed(x: jax.Array, p: PackedLinear,
                          compute_dtype=jnp.float32) -> jax.Array:
    return awq_matmul_ref(x, p.qweight, p.scales, p.zeros, p.group_size,
                          compute_dtype)


def awq_gateup_ref(x: jax.Array, qw_gate, s_gate, z_gate, qw_up, s_up, z_up,
                   group_size: int, compute_dtype=jnp.float32) -> jax.Array:
    """Fused SwiGLU FFN front: ``silu(x @ Wg) * (x @ Wu)`` (paper Table I's
    dominant 51% row, gate+up projections)."""
    g = awq_matmul_ref(x, qw_gate, s_gate, z_gate, group_size, compute_dtype)
    u = awq_matmul_ref(x, qw_up, s_up, z_up, group_size, compute_dtype)
    return jax.nn.silu(g) * u


def paged_attention_ref(q, k_pool, ks, v_pool, vs, page_table, pos, *,
                        scale=None):
    """Oracle for the fused dequant + paged-attention decode kernel.

    q [B, Hkv, G, hd]; k/v pools [N, P, Hkv, hd] int8; ks/vs [N, P, Hkv]
    f32 scale strips; page_table [B, pages_per_slot] int32; pos [B] int32
    (inclusive last valid position). Gathers the slot's pages into logical
    order, dequantizes, then runs plain masked softmax attention —
    exactly the jnp fallback path in `models.attention`.
    """
    b, hkv, g, hd = q.shape
    page_size = k_pool.shape[1]
    s_slot = page_table.shape[1] * page_size
    scale = scale if scale is not None else hd ** -0.5
    k = (k_pool.astype(jnp.float32)
         * ks[..., None].astype(jnp.float32))[page_table]
    v = (v_pool.astype(jnp.float32)
         * vs[..., None].astype(jnp.float32))[page_table]
    k = k.reshape(b, s_slot, hkv, hd)
    v = v.reshape(b, s_slot, hkv, hd)
    sc = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), k) * scale
    valid = jnp.arange(s_slot)[None, :] <= pos[:, None]    # [B, S]
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p, v)


def chunk_visibility_ref(pos, *, s_slot, rpos=None, amask=None,
                         window: int = 0):
    """Shared mask semantics for the generalized chunk attention read.

    Returns the boolean visibility ``[B, C, S_slot]`` of every slot
    position to every in-span query under the three-part rule:

      * **committed span** (``k < pos[b, 0]``): visible when inside the
        sliding window, ``k > rpos[b, i] - window`` (always, if
        ``window == 0``) — the causal watermark test;
      * **in-span** (``pos[b, 0] <= k < pos[b, 0] + C``): visible iff
        ``amask[b, i, k - pos[b, 0]]`` — the explicit ancestor-mask
        block (callers fold any in-span window bound into ``amask``);
      * everything else (future slots, stale table tails): masked.

    ``pos[b, i]`` is token *i*'s KV **slot** position — in-span tokens
    always occupy contiguous slots from the committed watermark
    ``pos[b, 0]`` (``-1`` marks padding). ``rpos`` is the **logical**
    (RoPE/depth) position, defaulting to ``pos``; the two differ only
    for tree-speculation rows, where siblings share a depth but not a
    slot. ``amask=None`` reproduces plain causality: in-span token j
    visible to query i iff ``j <= i`` and token j is not padding.
    """
    b, c = pos.shape
    if rpos is None:
        rpos = pos
    if amask is None:
        tri = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])
        amask = tri[None] & (pos >= 0)[:, None, :]         # [B, C, C]
        if window:
            amask = amask & (jnp.arange(c)[None, None, :]
                             > jnp.arange(c)[None, :, None] - window)
    base = pos[:, 0][:, None, None]                        # [B, 1, 1]
    k_slot = jnp.arange(s_slot)[None, None, :]             # [1, 1, S]
    committed = k_slot < base
    if window:
        committed = committed & (k_slot > rpos[:, :, None] - window)
    off = k_slot - base                                    # [B, 1, S]
    in_span = (off >= 0) & (off < c)
    offc = jnp.clip(off, 0, c - 1)
    vis_in = jnp.take_along_axis(
        amask.astype(bool), jnp.broadcast_to(offc, (b, c, s_slot)), axis=2)
    return (pos >= 0)[:, :, None] & (committed | (in_span & vis_in))


def paged_attention_chunk_ref(q, k_pool, ks, v_pool, vs, page_table, pos, *,
                              scale=None, rpos=None, amask=None,
                              window: int = 0):
    """Oracle for the multi-query (chunked-prefill) paged-attention kernel.

    q [B, C, Hkv, G, hd] — C queries per batch row (a prefill chunk, a
    speculation tree, or a single decode token at C=1); k/v pools
    [N, P, Hkv, hd] int8 with ks/vs [N, P, Hkv] f32 scale strips;
    page_table [B, pages_per_slot] int32 (one table row per batch row —
    all C queries of a row belong to the same request slot); pos [B, C]
    int32 absolute query **slot** positions, ``-1`` marking padding
    queries (masked everywhere, output zero).

    Visibility follows `chunk_visibility_ref`: committed pages pass the
    causal watermark (+ optional sliding-window) test, in-span keys pass
    through the explicit ``[C, C]`` ancestor-mask block (plain causality
    when ``amask=None``). Rows whose mask is empty — padding queries or
    all-masked ancestor rows — produce exactly 0, matching the kernel's
    ``l == 0`` flush.
    """
    b, c, hkv, g, hd = q.shape
    page_size = k_pool.shape[1]
    s_slot = page_table.shape[1] * page_size
    scale = scale if scale is not None else hd ** -0.5
    k = (k_pool.astype(jnp.float32)
         * ks[..., None].astype(jnp.float32))[page_table]
    v = (v_pool.astype(jnp.float32)
         * vs[..., None].astype(jnp.float32))[page_table]
    k = k.reshape(b, s_slot, hkv, hd)
    v = v.reshape(b, s_slot, hkv, hd)
    sc = jnp.einsum("bckgd,bskd->bckgs", q.astype(jnp.float32), k) * scale
    vis = chunk_visibility_ref(pos, s_slot=s_slot, rpos=rpos, amask=amask,
                               window=window)              # [B, C, S]
    vism = vis[:, :, None, None, :]
    sc = jnp.where(vism, sc, -1e30)
    # masked-row-exact-zero softmax: rows with an empty mask keep l = 0
    # and flush to 0 instead of averaging garbage
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.where(vism, jnp.exp(sc - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    return jnp.einsum("bckgs,bskd->bckgd", p, v)


def flash_attention_ref(q, k, v, *, scale=None, causal=True,
                        window: int = 0):
    """Oracle for the flash kernel: plain masked softmax attention.

    q [B, H, S, hd], k/v [B, Hkv, S, hd] → [B, H, S, hd] (GQA broadcast).
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, hkv, g, s, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, s, hd).astype(q.dtype)
