"""Pallas TPU fused dequant + paged-attention kernel (decode + chunked
prefill).

Why it exists: with int8 KV pages (§Perf A4 at serving scale) the serving
hot loop is bandwidth-bound on the page pool. The pure-jnp path in
`models.attention` gathers the slot's pages into a logical
``[B, S_slot, Hkv, hd]`` view, dequantizes it, then attends — XLA
materializes the gathered + dequantized (bf16) copy in HBM, paying ~2.5×
the pool's int8 byte traffic. This kernel reads the int8 codes and their
float32 scale strips page-by-page straight out of the pool (the page
table rides in scalar-prefetch memory and drives the BlockSpec index
maps — vLLM-TPU style), dequantizes in VMEM, and carries online softmax
state across the page grid axis, so nothing but the final output ever
leaves VMEM in float.

Two entry points over one kernel body:

  * `paged_attention_chunk` — **multi-query blocks** (chunked prefill):
    ``C`` queries per batch row share one page-table row and are masked
    causally against *per-token* absolute positions, so one page read is
    amortized over the whole chunk — the compute-density win that makes
    hybrid prefill+decode steps pay for themselves.
  * `paged_attention` — the single-token decode form (``C = 1``), kept as
    the stable API for the decode hot path and the kernel test suite.

Layout: q ``[B, C, Hkv, G, hd]`` (head = kv_head·G + group), pools
``[N, P, Hkv, hd]`` int8 with scales ``[N, P, Hkv]`` f32, page_table
``[B, pages_per_slot]`` int32, pos ``[B, C]`` int32 (inclusive last valid
absolute position per query; ``-1`` = padding query, fully masked). Grid
``(B, Hkv, pages_per_slot)``, pages innermost (accumulation axis); the
C·G query rows of a (batch, kv-head) cell ride the MXU together.

Off-TPU the wrappers drop to `kernels.ref.paged_attention_chunk_ref`
(numerically equal up to online-softmax reassociation); interpret mode
runs the kernel body as a CPU program for the allclose sweeps in
tests/test_paged_attention.py and tests/test_chunked_prefill.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def supported() -> bool:
    """Whether the compiled kernel path should be used for decode."""
    return jax.default_backend() == "tpu"


def _paged_attn_kernel(tables_ref, pos_ref, rpos_ref,  # scalar prefetch
                       q_ref, k_ref, ks_ref, v_ref, vs_ref, am_ref,
                       o_ref, m_ref, l_ref, acc_ref, *,
                       page_size: int, n_blocks: int, n_chunk: int,
                       n_groups: int, scale: float, window: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [gp, hd]
    # fused dequant: int8 codes × per-(position, head) scale strip, VMEM-only
    k = k_ref[0][:, 0].astype(jnp.float32) \
        * ks_ref[0][:, :1].astype(jnp.float32)             # [P, hd]
    v = v_ref[0][:, 0].astype(jnp.float32) \
        * vs_ref[0][:, :1].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # per-row query position: row r of the q block is chunk token r // G.
    # pos lives in SMEM (scalar prefetch); a vector gather out of SMEM is
    # not expressible, so select it with a static unroll over the (small,
    # compile-time) chunk length — padded rows keep -1 and mask everything.
    rows = jax.lax.broadcasted_iota(jnp.int32, (s.shape[0], 1), 0) \
        // n_groups                                        # [gp, 1] chunk idx
    q_pos = jnp.full((s.shape[0], 1), -1, jnp.int32)
    for cc in range(n_chunk):
        q_pos = jnp.where(rows == cc, pos_ref[b * n_chunk + cc], q_pos)
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                             # [gp, P]
    # three-part visibility (see kernels.ref.chunk_visibility_ref):
    #   committed pages — causal watermark test against the row base
    #   (everything below pos[b, 0] is committed KV), optionally bounded
    #   below by the sliding window on the row's *logical* position;
    #   in-span keys — the explicit [C, C] ancestor-mask block, selected
    #   per slot offset with a static unroll (no VMEM gathers on TPU);
    #   padding rows (q_pos = -1) — masked everywhere.
    base = pos_ref[b * n_chunk]
    committed = k_pos < base
    if window:
        r_pos = jnp.full((s.shape[0], 1), -1, jnp.int32)
        for cc in range(n_chunk):
            r_pos = jnp.where(rows == cc, rpos_ref[b * n_chunk + cc], r_pos)
        committed = committed & (k_pos > r_pos - window)
    am = am_ref[0]                                         # [gp, C] f32
    in_span = jnp.zeros(s.shape, jnp.bool_)
    for t in range(n_chunk):
        in_span = in_span | ((k_pos == base + t) & (am[:, t:t + 1] > 0.5))
    s = jnp.where((q_pos >= 0) & (committed | in_span), s, NEG_INF)

    m_prev = m_ref[...]                                    # [gp, 128] replicated
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]                    # [gp, 1]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)
    # masked entries must contribute exactly 0: for a fully masked row
    # (padding query, q_pos = -1) m_new is still NEG_INF, so the plain
    # exp(s - m) would be exp(0) = 1 per key and the row would silently
    # average v. Valid rows are unchanged (exp(NEG_INF - m) underflows
    # to 0 anyway); fully masked rows keep l = 0 and flush to 0.
    p = jnp.where(s > NEG_INF * 0.5,
                  jnp.exp(s - m_new[:, :1]), 0.0)          # [gp, P]
    l_new = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=1)[:, None], l_prev.shape)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_blocks - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully masked row
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def default_amask(pos: jax.Array, window: int = 0) -> jax.Array:
    """Plain-causal ancestor mask for a linear chunk: in-span token j is
    visible to query i iff ``j <= i`` and token j is not padding, with
    the in-span half of any sliding-window bound folded in (committed
    pages get their window test inside the kernel)."""
    c = pos.shape[1]
    tri = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
    am = tri[None] & (pos >= 0)[:, None, :]                # [B, C, C]
    if window:
        am = am & (jnp.arange(c)[None, None, :]
                   > jnp.arange(c)[None, :, None] - window)
    return am


@functools.partial(jax.jit,
                   static_argnames=("scale", "interpret", "window"))
def paged_attention_chunk(q: jax.Array, k_pool: jax.Array, ks: jax.Array,
                          v_pool: jax.Array, vs: jax.Array,
                          page_table: jax.Array, pos: jax.Array, *,
                          scale: float | None = None,
                          rpos: jax.Array | None = None,
                          amask: jax.Array | None = None,
                          window: int = 0,
                          interpret: bool = False) -> jax.Array:
    """Fused dequant + multi-query masked attention over int8 KV pages.

    q ``[B, C, Hkv, G, hd]`` — C queries per row (prefill chunk, token
    tree, or decode at C = 1); k/v pools ``[N, P, Hkv, hd]`` int8; ks/vs
    ``[N, P, Hkv]`` f32; page_table ``[B, pages_per_slot]`` int32 (one
    row per batch row — all C queries of a row read the same slot's
    pages); pos ``[B, C]`` int32 per-query inclusive **slot** positions
    (``-1`` ⇒ padding query, output 0): in-span tokens always occupy
    contiguous slots from the committed watermark ``pos[b, 0]``.
    Returns ``[B, C, Hkv, G, hd]`` float32.

    Mask semantics (`kernels.ref.chunk_visibility_ref` is the oracle):
    committed pages — everything below ``pos[b, 0]`` — pass the causal
    watermark test, bounded below by ``k > rpos[b, i] - window`` when a
    sliding ``window`` is set (``rpos`` is the row's logical/RoPE
    position, defaulting to ``pos``; the two differ only for tree rows).
    In-span keys route through the explicit ``[B, C, C]`` ancestor-mask
    block ``amask`` (plain causality when ``None``), which lets one
    kernel serve linear chunks, speculation trees, and windowed reads.
    Stale table tails and the scratch page sit above the watermark and
    outside the span, so they never leak into the softmax.
    """
    b, c, hkv, g, hd = q.shape
    page_size = k_pool.shape[1]
    n_blocks = page_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    if rpos is None:
        rpos = pos
    if amask is None:
        amask = default_amask(pos, window)
    # expand query rows over GQA groups (row r = query r // G) and pad to
    # the same gp row quantum as q — padded rows are all-masked anyway
    am = jnp.repeat(amask.astype(jnp.float32), g, axis=1)  # [B, C·G, C]
    # fold the chunk into the row axis: row r = query (r // G) group (r % G);
    # pad rows to the fp32 sublane quantum so tiny chunks (C·G < 8) still
    # map onto full tiles — padded rows carry pos -1 and are sliced off
    rows = c * g
    gp = max(8, rows)
    qr = jnp.moveaxis(q, 1, 2).reshape(b, hkv, rows, hd)
    if gp != rows:
        qr = jnp.concatenate(
            [qr, jnp.zeros((b, hkv, gp - rows, hd), qr.dtype)], axis=2)
        am = jnp.concatenate(
            [am, jnp.zeros((b, gp - rows, c), am.dtype)], axis=1)

    grid = (b, hkv, n_blocks)
    kernel = functools.partial(_paged_attn_kernel, page_size=page_size,
                               n_blocks=n_blocks, n_chunk=c, n_groups=g,
                               scale=scale, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gp, hd),
                         lambda bi, hi, ji, tables, pos_, rpos_:
                         (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, ji, tables, pos_, rpos_,
                         _nb=n_blocks: (tables[bi * _nb + ji], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda bi, hi, ji, tables, pos_, rpos_,
                         _nb=n_blocks: (tables[bi * _nb + ji], 0, hi)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, ji, tables, pos_, rpos_,
                         _nb=n_blocks: (tables[bi * _nb + ji], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda bi, hi, ji, tables, pos_, rpos_,
                         _nb=n_blocks: (tables[bi * _nb + ji], 0, hi)),
            pl.BlockSpec((1, gp, c),
                         lambda bi, hi, ji, tables, pos_, rpos_:
                         (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, hd),
                               lambda bi, hi, ji, tables, pos_, rpos_:
                               (bi, hi, 0, 0)),
        scratch_shapes=[pltpu.VMEM((gp, 128), jnp.float32),
                        pltpu.VMEM((gp, 128), jnp.float32),
                        pltpu.VMEM((gp, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, hd), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(page_table.reshape(-1).astype(jnp.int32),
      pos.reshape(-1).astype(jnp.int32),
      rpos.reshape(-1).astype(jnp.int32),
      qr, k_pool, ks, v_pool, vs, am)
    out = out[:, :, :rows].reshape(b, hkv, c, g, hd)
    return jnp.moveaxis(out, 2, 1)


def paged_attention_chunk_sharded(q: jax.Array, k_pool: jax.Array,
                                  ks: jax.Array, v_pool: jax.Array,
                                  vs: jax.Array, page_table: jax.Array,
                                  pos: jax.Array, *, mesh,
                                  scale: float | None = None,
                                  rpos: jax.Array | None = None,
                                  amask: jax.Array | None = None,
                                  window: int = 0,
                                  interpret: bool = False) -> jax.Array:
    """Tensor-parallel form: the chunk kernel under `shard_map` over the
    KV-head axis of the ``model`` mesh axis.

    KV heads are independent throughout — the online softmax, the
    watermark/ancestor mask, and the dequant all run per (batch, kv-head)
    grid cell — so each mesh shard simply runs the unmodified kernel body
    over its local ``Hkv / |model|`` heads of the pool
    (`distributed.paged_cache_pspec` stripes the pools the same way) with
    ZERO cross-device communication inside the kernel; the output
    concatenates back along heads. Page tables, positions, and the
    ancestor-mask block are replicated (page IDs and mask bits are
    device-agnostic).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    if mesh.shape.get("model", 1) == 1:
        return paged_attention_chunk(q, k_pool, ks, v_pool, vs, page_table,
                                     pos, scale=scale, rpos=rpos,
                                     amask=amask, window=window,
                                     interpret=interpret)
    if rpos is None:
        rpos = pos
    if amask is None:
        amask = default_amask(pos, window)
    head = P(None, None, "model")                       # [N, P, Hkv]
    return shard_map(
        lambda q_, k_, ks_, v_, vs_, t_, p_, rp_, am_: paged_attention_chunk(
            q_, k_, ks_, v_, vs_, t_, p_, scale=scale, rpos=rp_, amask=am_,
            window=window, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, None, "model", None, None), P(*head, None), head,
                  P(*head, None), head, P(None, None), P(None, None),
                  P(None, None), P(None, None, None)),
        out_specs=P(None, None, "model", None, None),
        check_vma=False,
    )(q, k_pool, ks, v_pool, vs, page_table, pos, rpos, amask)


def paged_attention(q: jax.Array, k_pool: jax.Array, ks: jax.Array,
                    v_pool: jax.Array, vs: jax.Array,
                    page_table: jax.Array, pos: jax.Array, *,
                    scale: float | None = None,
                    window: int = 0,
                    interpret: bool = False) -> jax.Array:
    """Single-token decode form: q ``[B, Hkv, G, hd]``, pos ``[B]``.

    Thin wrapper over `paged_attention_chunk` with a chunk of one — the
    decode hot path and the chunked-prefill path share one kernel body.
    At C = 1 slot and logical positions coincide, so ``pos`` serves as
    both the watermark and the window anchor. Returns ``[B, Hkv, G, hd]``
    float32.
    """
    out = paged_attention_chunk(q[:, None], k_pool, ks, v_pool, vs,
                                page_table, pos[:, None], scale=scale,
                                window=window, interpret=interpret)
    return out[:, 0]
