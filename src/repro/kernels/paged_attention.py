"""Pallas TPU fused dequant + paged-attention decode kernel.

Why it exists: with int8 KV pages (§Perf A4 at serving scale) the decode
hot loop is bandwidth-bound on the page pool. The pure-jnp path in
`models.attention.attention_decode_paged` gathers the slot's pages into a
logical ``[B, S_slot, Hkv, hd]`` view, dequantizes it, then attends —
XLA materializes the gathered + dequantized (bf16) copy in HBM, paying
~2.5× the pool's int8 byte traffic. This kernel reads the int8 codes and
their float32 scale strips page-by-page straight out of the pool (the
page table rides in scalar-prefetch memory and drives the BlockSpec
index maps — vLLM-TPU style), dequantizes in VMEM, and carries online
softmax state across the page grid axis, so nothing but the final
``[B, H, hd]`` output ever leaves VMEM in float.

Layout: q ``[B, Hkv, G, hd]`` (head = kv_head·G + group, matching the
reshape in `attention_decode_paged`), pools ``[N, P, Hkv, hd]`` int8 with
scales ``[N, P, Hkv]`` f32, page_table ``[B, pages_per_slot]`` int32,
pos ``[B]`` int32 (last valid absolute position, inclusive). Grid
``(B, Hkv, pages_per_slot)``, pages innermost (accumulation axis).

Off-TPU the wrapper drops to `kernels.ref.paged_attention_ref`
(numerically equal up to online-softmax reassociation); interpret mode
runs the kernel body as a CPU program for the allclose sweeps in
tests/test_paged_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def supported() -> bool:
    """Whether the compiled kernel path should be used for decode."""
    return jax.default_backend() == "tpu"


def _paged_attn_kernel(tables_ref, pos_ref,            # scalar prefetch
                       q_ref, k_ref, ks_ref, v_ref, vs_ref,
                       o_ref, m_ref, l_ref, acc_ref, *,
                       page_size: int, n_blocks: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [G, hd]
    # fused dequant: int8 codes × per-(position, head) scale strip, VMEM-only
    k = k_ref[0][:, 0].astype(jnp.float32) \
        * ks_ref[0][:, :1].astype(jnp.float32)             # [P, hd]
    v = v_ref[0][:, 0].astype(jnp.float32) \
        * vs_ref[0][:, :1].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                             # [G, P]
    s = jnp.where(k_pos <= pos_ref[b], s, NEG_INF)

    m_prev = m_ref[...]                                    # [G, 128] replicated
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]                    # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, :1])                          # [G, P]
    l_new = l_prev * alpha + jnp.broadcast_to(
        jnp.sum(p, axis=1)[:, None], l_prev.shape)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_blocks - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully masked row
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, ks: jax.Array,
                    v_pool: jax.Array, vs: jax.Array,
                    page_table: jax.Array, pos: jax.Array, *,
                    scale: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """Fused dequant + single-token attention over int8 KV pages.

    q ``[B, Hkv, G, hd]``; k/v pools ``[N, P, Hkv, hd]`` int8; ks/vs
    ``[N, P, Hkv]`` f32; page_table ``[B, pages_per_slot]`` int32; pos
    ``[B]`` int32 (inclusive last valid position — the just-written
    token). Returns ``[B, Hkv, G, hd]`` float32. Pages past the valid
    range may map to the scratch page; their positions exceed ``pos`` and
    are masked, so stale table entries never leak into the softmax.
    """
    b, hkv, g, hd = q.shape
    n_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    n_blocks = page_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    # pad the group dim to the fp32 sublane quantum so tiny-GQA configs
    # (G < 8) still map onto full tiles; padded rows are sliced off below
    gp = max(8, g)
    if gp != g:
        q = jnp.concatenate(
            [q, jnp.zeros((b, hkv, gp - g, hd), q.dtype)], axis=2)

    grid = (b, hkv, n_blocks)
    kernel = functools.partial(_paged_attn_kernel, page_size=page_size,
                               n_blocks=n_blocks, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gp, hd),
                         lambda bi, hi, ji, tables, pos_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, ji, tables, pos_, _nb=n_blocks:
                         (tables[bi * _nb + ji], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda bi, hi, ji, tables, pos_, _nb=n_blocks:
                         (tables[bi * _nb + ji], 0, hi)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, ji, tables, pos_, _nb=n_blocks:
                         (tables[bi * _nb + ji], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda bi, hi, ji, tables, pos_, _nb=n_blocks:
                         (tables[bi * _nb + ji], 0, hi)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, hd),
                               lambda bi, hi, ji, tables, pos_: (bi, hi, 0, 0)),
        scratch_shapes=[pltpu.VMEM((gp, 128), jnp.float32),
                        pltpu.VMEM((gp, 128), jnp.float32),
                        pltpu.VMEM((gp, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, hd), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(page_table.reshape(-1).astype(jnp.int32), pos.astype(jnp.int32),
      q, k_pool, ks, v_pool, vs)
    return out[:, :, :g]
