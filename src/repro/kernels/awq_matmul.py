"""Pallas TPU kernel: fused unpack + dequantize + matmul (the MACRO_MAC unit).

This is the TPU adaptation of the paper's accelerator (§III-B, Fig. 4):

  paper (KV260 fabric)                     this kernel (TPU)
  ------------------------------------     ------------------------------------
  4× AXI 128-bit channels streaming        `pallas_call` grid pipeline: HBM→VMEM
  AWQ_MACROs from DDR                      DMA of the *packed int32* blocks,
                                           double-buffered across grid steps
  unpack unit (shift + bitmask)            `>> (4*j) & 0xF` on VREGs
  dequant (q - zero) * scale per group     group-broadcast fused in VMEM
  8×8 PE array + adder tree (FP32 MAC)     128×128 MXU `jnp.dot` (f32 accum)
  partial-sum accumulation per out chan    VMEM f32 scratch accumulated over
                                           the K grid axis

The key property preserved from the paper: weights cross the bandwidth-
critical boundary (HBM→VMEM here, DDR→PL there) in packed INT4 form, with
scales/zeros riding in the same block (block_k is a multiple of the quant
group, so dequant metadata always travels with its weights), and are only
expanded to float inside the compute unit's pipeline.

Block-shape regimes (DESIGN.md §2): decode is a GEMV (`block_m = 8`), prefill
a GEMM (`block_m = 128..256`) — one kernel, two schedules, selected by the
wrapper in `ops.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PACK
from repro.utils.compat import CompilerParams as _CompilerParams


def _unpack_dequant(qw_block, s_block, z_block, block_k: int, block_n: int,
                    group_size: int, compute_dtype):
    """[bk//8, bn] int32 → [bk, bn] float, dequantized (in-VMEM pipeline)."""
    w32 = qw_block.astype(jnp.uint32)  # [bk//8, bn]
    # Shift+mask unpack, mirroring the paper's unpack unit (Fig. 4b). The
    # stack axis is the nibble index j ⇒ original row = word_row * 8 + j.
    nibs = [((w32 >> jnp.uint32(4 * j)) & jnp.uint32(0xF))
            for j in range(PACK)]
    q = jnp.stack(nibs, axis=1).reshape(block_k, block_n)  # uint32
    groups = block_k // group_size
    qf = q.reshape(groups, group_size, block_n).astype(jnp.float32)
    z = z_block.astype(jnp.float32)[:, None, :]   # [g, 1, bn]
    s = s_block.astype(jnp.float32)[:, None, :]   # [g, 1, bn]
    w = (qf - z) * s                              # PE op, Fig. 4d
    return w.reshape(block_k, block_n).astype(compute_dtype)


def _awq_matmul_kernel(x_ref, qw_ref, s_ref, z_ref, o_ref, acc_ref, *,
                       block_k: int, block_n: int, n_k: int, group_size: int,
                       compute_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_dequant(qw_ref[...], s_ref[...], z_ref[...], block_k,
                        block_n, group_size, compute_dtype)
    x = x_ref[...].astype(compute_dtype)
    # MXU MAC with f32 accumulation (adder-tree analogue).
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "block_m", "block_n", "block_k",
                     "compute_dtype", "interpret"))
def awq_matmul_pallas(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                      zeros: jax.Array, *, group_size: int, block_m: int,
                      block_n: int, block_k: int,
                      compute_dtype=jnp.bfloat16,
                      interpret: bool = False) -> jax.Array:
    """``x [M, K] @ dequant(qweight [K//8, N]) → [M, N] float32``.

    Shape contract (enforced by the `ops.py` wrapper): M % block_m == 0,
    N % block_n == 0, K % block_k == 0, block_k % group_size == 0.
    """
    m, k = x.shape
    n = qweight.shape[1]
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    kernel = functools.partial(
        _awq_matmul_kernel, block_k=block_k, block_n=block_n, n_k=n_k,
        group_size=group_size, compute_dtype=compute_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // PACK, block_n),
                         lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, qweight, scales, zeros)


def _awq_gateup_kernel(x_ref, qg_ref, sg_ref, zg_ref, qu_ref, su_ref, zu_ref,
                       o_ref, accg_ref, accu_ref, *, block_k: int,
                       block_n: int, n_k: int, group_size: int,
                       compute_dtype):
    """Fused FFN front: silu(x@Wg) * (x@Wu) — one pass over x per K block.

    The paper's Table I shows gate+up projections are 51% of inference time;
    fusing them halves the activation traffic (x is streamed once) and skips
    the intermediate HBM round-trip for silu/mul — this is the beyond-paper
    kernel used in the §Perf hillclimb.
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...].astype(compute_dtype)
    wg = _unpack_dequant(qg_ref[...], sg_ref[...], zg_ref[...], block_k,
                         block_n, group_size, compute_dtype)
    wu = _unpack_dequant(qu_ref[...], su_ref[...], zu_ref[...], block_k,
                         block_n, group_size, compute_dtype)
    accg_ref[...] += jnp.dot(x, wg, preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(x, wu, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _flush():
        g = accg_ref[...]
        u = accu_ref[...]
        o_ref[...] = (g * jax.lax.logistic(g) * u).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "block_m", "block_n", "block_k",
                     "compute_dtype", "interpret"))
def awq_gateup_pallas(x, qw_gate, s_gate, z_gate, qw_up, s_up, z_up, *,
                      group_size: int, block_m: int, block_n: int,
                      block_k: int, compute_dtype=jnp.bfloat16,
                      interpret: bool = False) -> jax.Array:
    m, k = x.shape
    n = qw_gate.shape[1]
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(
        _awq_gateup_kernel, block_k=block_k, block_n=block_n, n_k=n_k,
        group_size=group_size, compute_dtype=compute_dtype)
    wspec = pl.BlockSpec((block_k // PACK, block_n), lambda i, j, kk: (kk, j))
    gspec = pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, kk: (kk, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
                  wspec, gspec, gspec, wspec, gspec, gspec],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32),
                        pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, qw_gate, s_gate, z_gate, qw_up, s_up, z_up)
