"""Jit-ready wrappers around the Pallas kernels (block-shape selection,
padding, platform dispatch).

The wrapper implements the two block regimes of DESIGN.md §2: a GEMV-like
schedule for decode (tiny M) and a GEMM schedule for prefill/training-shape
matmuls. VMEM budgeting note: one grid step holds
``bm*bk (x) + bk/8*bn*4 (qw) + 2*bk/GS*bn (meta) + bm*bn*4 (acc)`` bytes;
the defaults keep this well under 8 MB for every supported shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PackedLinear
from repro.kernels.awq_matmul import awq_gateup_pallas, awq_matmul_pallas


def _divisor_block(dim: int, quantum: int, target: int) -> int:
    """Largest multiple of ``quantum`` that divides ``dim`` and is <= target."""
    best = quantum
    b = quantum
    while b <= min(dim, target):
        if dim % b == 0:
            best = b
        b += quantum
    return best


def choose_blocks(m: int, k: int, n: int, group_size: int,
                  ) -> tuple[int, int, int]:
    """(block_m, block_n, block_k) for the fused kernel.

    * block_k must be a multiple of the dequant group (metadata travels with
      its weights — the AWQ_MACRO invariant) and divide K.
    * block_n multiples of 128 keep the MXU lane dimension full.
    * block_m picks the schedule: ≤ 8 rows ride one 8-sublane block (the
      decode GEMV regime — every weight block is streamed exactly once);
      larger M gets a GEMM block up to 256. The serving scheduler emits
      M = width · num_slots for every width in
      ``scheduler.width_family(chunk, spec_k)`` ({1, 2, 4, …, chunk} plus
      the k+1 spec-verify widths), so M is frequently NOT a multiple of 8
      — those pad up to the next 8-sublane boundary and take it as one
      block when ≤ 256 (single grid row) instead of degrading to bm=8.
    """
    block_k = _divisor_block(k, group_size, 1024)
    block_n = _divisor_block(n, 128, 512) if n % 128 == 0 else \
        _divisor_block(n, 8, 512)
    if m <= 8:
        block_m = 8                              # GEMV schedule
    elif m % 8 == 0:
        block_m = _divisor_block(m, 8, 256)      # GEMM, exact tiling
    else:
        padded = -(-m // 8) * 8                  # GEMM over padded rows
        block_m = padded if padded <= 256 else _divisor_block(padded, 8, 256)
    return block_m, block_n, block_k


def _pad_rows(x: jax.Array, block_m: int) -> tuple[jax.Array, int]:
    m = x.shape[0]
    pad = (-m) % block_m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def awq_matmul(x: jax.Array, p: PackedLinear, *,
               compute_dtype=jnp.bfloat16,
               interpret: bool | None = None) -> jax.Array:
    """Fused quantized matmul ``x [M, K] -> [M, N] float32``.

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere
    (the kernel body then runs as a reference-shaped CPU program — used by
    the allclose test sweeps).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = x.shape[-1]
    bm, bn, bk = choose_blocks(x.shape[0], k, p.n, p.group_size)
    xp, m = _pad_rows(x, bm)
    y = awq_matmul_pallas(
        xp, p.qweight, p.scales, p.zeros, group_size=p.group_size,
        block_m=bm, block_n=bn, block_k=bk, compute_dtype=compute_dtype,
        interpret=interpret)
    return y[:m]


def awq_gateup(x: jax.Array, gate: PackedLinear, up: PackedLinear, *,
               compute_dtype=jnp.bfloat16,
               interpret: bool | None = None) -> jax.Array:
    """Fused ``silu(x@Wg) * (x@Wu)`` — single pass over activations."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if gate.group_size != up.group_size or gate.n != up.n:
        raise ValueError("gate/up shape mismatch")
    k = x.shape[-1]
    bm, bn, bk = choose_blocks(x.shape[0], k, gate.n, gate.group_size)
    xp, m = _pad_rows(x, bm)
    y = awq_gateup_pallas(
        xp, gate.qweight, gate.scales, gate.zeros, up.qweight, up.scales,
        up.zeros, group_size=gate.group_size, block_m=bm, block_n=bn,
        block_k=bk, compute_dtype=compute_dtype, interpret=interpret)
    return y[:m]
