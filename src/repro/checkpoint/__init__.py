from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,  # noqa: F401
                                           restore, save)
