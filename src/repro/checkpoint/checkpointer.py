"""Fault-tolerant checkpointing: atomic saves, async writer, elastic restore.

Format: one ``step_<k>.npz`` per step holding every leaf keyed by its tree
path (stable across runs because params are ordered dicts), plus a LATEST
pointer written *after* the npz rename — a crash mid-save can never corrupt
the restore point (the paper-scale analogue is OCDBT/tensorstore; the
atomicity protocol is the same: tmp + rename + pointer).

Elastic restore: `restore(..., shardings=...)` device_puts every leaf with
the *target* mesh's NamedSharding — restoring a checkpoint written on a
16×16 mesh onto 2×16×16 (or onto fewer devices after a node failure) is a
pure resharding, no format change. The data pipeline being a pure function
of (seed, step) makes the resume exact end-to-end.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Any

import jax
import numpy as np

from repro.utils.tree import flatten_with_paths

_LATEST = "LATEST"


def _state_paths(state: Any) -> list[tuple[str, Any]]:
    return flatten_with_paths(state)


def save(ckpt_dir: str, step: int, state: Any) -> str:
    """Atomic synchronous save. Returns the checkpoint file path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = {p: np.asarray(jax.device_get(v))
              for p, v in _state_paths(state)}
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **leaves)
    os.replace(tmp, path)                      # atomic on POSIX
    ptr_tmp = os.path.join(ckpt_dir, _LATEST + ".tmp")
    with open(ptr_tmp, "w") as f:
        f.write(str(step))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, _LATEST))
    return path


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, _LATEST)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, template: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template`` (ShapeDtypeStructs ok).

    ``shardings``: optional pytree of NamedSharding matching template —
    leaves are device_put with the target sharding (elastic re-shard).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as blob:
        flat_tpl = _state_paths(template)
        loaded = []
        for p, tpl in flat_tpl:
            arr = blob[p]
            if hasattr(tpl, "dtype"):
                arr = arr.astype(tpl.dtype)
            loaded.append(arr)
    treedef = jax.tree.structure(template)
    state = jax.tree.unflatten(treedef, loaded)
    if shardings is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state, shardings)
    return state, step


class AsyncCheckpointer:
    """Background-thread writer: the train loop never blocks on disk.

    `save` snapshots to host memory (device_get — this is the only sync
    point), enqueues, and returns; a worker drains the queue with the
    atomic protocol above. `wait()` flushes (used before exit/tests).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: list[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, leaves = item
            try:
                os.makedirs(self.ckpt_dir, exist_ok=True)
                path = os.path.join(self.ckpt_dir, f"step_{step:08d}.npz")
                tmp = path + ".tmp.npz"
                with open(tmp, "wb") as f:
                    np.savez(f, **leaves)
                os.replace(tmp, path)
                ptr = os.path.join(self.ckpt_dir, _LATEST)
                with open(ptr + ".tmp", "w") as f:
                    f.write(str(step))
                os.replace(ptr + ".tmp", ptr)
                self._gc()
            except BaseException as e:  # surfaced on wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        ckpts = sorted(f for f in os.listdir(self.ckpt_dir)
                       if f.startswith("step_") and f.endswith(".npz"))
        for f in ckpts[:-self.keep]:
            os.remove(os.path.join(self.ckpt_dir, f))

    def save(self, step: int, state: Any) -> None:
        leaves = {p: np.asarray(jax.device_get(v))
                  for p, v in _state_paths(state)}
        self._q.put((int(step), leaves))

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err[0]

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
