"""Analytic per-cell cost model: exact FLOPs/bytes from the architecture.

Why this exists (EXPERIMENTS.md §Roofline discusses the cross-checks): the
compiled artifact on the CPU backend has two systematic distortions —
(a) `cost_analysis()` counts while(scan) bodies once (fixed by the
trip-count-aware `hlo_costs`), and (b) XLA-CPU widens bf16 dots to f32,
materializing f32 copies of bf16 tensors (e.g. the KV cache) that a TPU
would never create. Dot FLOPs and collective bytes parse cleanly from HLO
text; HBM BYTES do not. This module therefore computes the memory term
analytically from the model definition — every matmul, attention score,
cache line and optimizer word, with the AWQ INT4 stream priced at its true
4.5 bits/weight — and the dry-run records both (analytic + HLO upper bound).

Conventions:
  * activations bf16 (2B), scores/softmax f32 (4B), master params f32,
  * weight-only quant: 0.5625 B/weight (INT4 + scales/zeros at GS=64,
    byte-exact AWQ_MACRO rate) for quantizable linears, fp16 for the rest,
  * training weight traffic per param: bf16 fwd read + remat re-read + bwd
    read (3×2B) + f32 grad write+read (8B) + Adam m/v read+write (16B) +
    f32 master read+write (8B) = 38 B,
  * per-chip numbers assume the sharding rules' actual placement: tensors
    whose dims don't divide the mesh axis are counted replicated.
"""
from __future__ import annotations

import dataclasses

from repro.configs import SHAPES, ShapeCell
from repro.configs.base import LayerKind, ModelConfig

AWQ_BYTES_PER_W = 4.5 / 8          # byte-exact AWQ_MACRO rate at GS=64
ACT = 2                            # bf16 activations
F32 = 4


def _linear_dims(cfg: ModelConfig, kind: LayerKind) -> list[tuple[int, int]]:
    """(K, N) of every linear in one block of this kind (MoE listed once
    per expert via the 'experts' multiplier below)."""
    d = cfg.d_model
    dims: list[tuple[int, int]] = []
    if kind.mixer in ("attn", "hymba"):
        dims += [(d, cfg.q_dim), (d, cfg.kv_dim), (d, cfg.kv_dim),
                 (cfg.q_dim, d)]
    if kind.mixer == "mla":
        nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        dims += [(d, cfg.num_heads * (nope + rope)),
                 (d, cfg.kv_lora_rank + rope),
                 (cfg.kv_lora_rank, cfg.num_heads * (nope + cfg.v_head_dim)),
                 (cfg.num_heads * cfg.v_head_dim, d)]
    if kind.mixer in ("mamba", "hymba"):
        di, gd, nh = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state, \
            cfg.ssm_nheads
        dims += [(d, di), (d, di), (d, gd), (d, gd), (d, nh), (di, d)]
    if kind.mlp == "glu":
        dims += [(d, cfg.d_ff), (d, cfg.d_ff), (cfg.d_ff, d)]
    elif kind.mlp == "plain":
        dims += [(d, cfg.d_ff), (cfg.d_ff, d)]
    return dims


def _moe_dims(cfg: ModelConfig) -> tuple[list[tuple[int, int]],
                                         list[tuple[int, int]]]:
    """(per-routed-expert dims, shared/dense-path dims) for a MoE block."""
    d = cfg.d_model
    routed = [(d, cfg.moe_d_ff), (d, cfg.moe_d_ff), (cfg.moe_d_ff, d)]
    shared = []
    if cfg.num_shared_experts:
        sf = cfg.shared_d_ff
        shared = [(d, sf), (d, sf), (sf, d)]
    shared.append((d, cfg.num_experts))  # router
    return routed, shared


def _quantizable(k: int, n: int, gs: int = 64) -> bool:
    return k % gs == 0 and n % 8 == 0 and k * n >= 16384


@dataclasses.dataclass
class CellCosts:
    flops: float = 0.0             # executed matmul+attention flops, global
    weight_bytes: float = 0.0      # weight traffic per step, global
    act_bytes: float = 0.0         # activation/score materialization, global
    cache_bytes: float = 0.0       # KV/state cache traffic per step, global
    opt_bytes: float = 0.0         # optimizer/grad traffic (train), global

    @property
    def total_bytes(self) -> float:
        return (self.weight_bytes + self.act_bytes + self.cache_bytes
                + self.opt_bytes)


def cell_costs(cfg: ModelConfig, cell: ShapeCell, quant: bool) -> CellCosts:
    """Global per-step costs for one (arch × shape) cell."""
    b, s = cell.global_batch, cell.seq_len
    train = cell.step == "train"
    decode = cell.step == "decode"
    toks = b if decode else b * s
    c = CellCosts()

    wq_b = AWQ_BYTES_PER_W if quant else (2 if not train else 38)
    wfp_b = 2 if not train else 38

    def add_linear(k: int, n: int, tok: float, n_mats: float = 1.0):
        c.flops += 2.0 * k * n * tok * n_mats
        c.weight_bytes += k * n * n_mats * \
            (wq_b if (quant and _quantizable(k, n)) else wfp_b)
        c.act_bytes += tok * (k + n) * ACT

    kinds = cfg.layer_kinds()
    for kind in kinds:
        if kind.mlp == "moe":
            routed, shared = _moe_dims(cfg)
            for k, n in routed:
                # every expert's weights stream once per step; compute only
                # on the top_k-dispatched share of tokens
                add_linear(k, n, toks * cfg.top_k / cfg.num_experts,
                           n_mats=cfg.num_experts)
            for k, n in shared:
                add_linear(k, n, toks)
            dims = [t for t in _linear_dims(cfg, kind)]
        else:
            dims = _linear_dims(cfg, kind)
        for k, n in dims:
            add_linear(k, n, toks)

        # --- mixer state/score traffic ---
        if kind.mixer in ("attn", "hymba", "mla"):
            if kind.mixer == "mla":
                qk_dim = cfg.num_heads * (cfg.qk_nope_head_dim
                                          + cfg.qk_rope_head_dim)
                v_dim = cfg.num_heads * cfg.v_head_dim
                kv_line = cfg.kv_lora_rank + cfg.qk_rope_head_dim  # latent
            else:
                qk_dim = cfg.q_dim
                v_dim = cfg.q_dim
                kv_line = 2 * cfg.kv_dim
            ctx = min(kind.window, s) if kind.window else s
            # int8 KV cache (§Perf A4): 1 B/elem + f32 scale per (pos, head)
            kv_byte = (1.0 + F32 / cfg.head_dim) \
                if (cfg.kv_quant == "int8" and kind.mixer != "mla") else ACT
            if decode:
                # read the whole cache line per step + scores
                c.cache_bytes += b * ctx * kv_line * kv_byte \
                    + b * kv_line * kv_byte
                c.flops += 2.0 * b * ctx * (qk_dim + v_dim)
                c.act_bytes += b * cfg.num_heads * ctx * F32  # probs
            else:
                # causal S×ctx scores in f32 (written+read by softmax), ×3
                # for backward (dS, recompute) when training
                pairs = (s * ctx / 2) if not kind.window else (s * ctx)
                pairs = min(pairs, s * s / 2)
                factor = 3.0 if train else 1.0
                c.flops += 2.0 * b * pairs * (qk_dim + v_dim) * factor
                c.act_bytes += 2.0 * b * cfg.num_heads * pairs * F32 * factor
                if cell.step == "prefill":
                    c.cache_bytes += b * ctx * kv_line * ACT  # cache write
        if kind.mixer in ("mamba", "hymba"):
            nh, hd, ds = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
            if decode:
                c.cache_bytes += 2.0 * b * nh * hd * ds * F32  # state rw
                c.flops += 2.0 * 3 * b * nh * hd * ds
            else:
                q = min(cfg.ssm_chunk, s)
                factor = 3.0 if train else 1.0
                # intra-chunk quadratic + state build/apply
                c.flops += (2.0 * b * s * q * nh * (ds + hd) / 2
                            + 4.0 * b * s * nh * hd * ds) * factor
                c.act_bytes += b * s * nh * (hd + 2 * ds) * F32 * factor

    # --- embeddings / head / loss ---
    v, d = cfg.vocab_size, cfg.d_model
    emb_fp = 2 if not train else 38
    c.weight_bytes += v * d * emb_fp * (2 if not cfg.tie_embeddings
                                        and not cfg.is_encoder else 1)
    head_toks = toks if (train or cfg.is_encoder) else b
    c.flops += 2.0 * v * d * head_toks * (3.0 if train else 1.0)
    c.act_bytes += head_toks * v * F32 * (2.0 if train else 1.0)  # logits

    if train:
        n_params = cfg.n_params()
        c.opt_bytes += 0  # already folded into the 38 B/param weight rate
        # remat: one extra forward of all matmul flops
        c.flops *= 4.0 / 3.0

    return c


def analytic_terms(cfg: ModelConfig, cell_name: str, chips: int,
                   quant: bool) -> dict:
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
    cell = SHAPES[cell_name]
    cc = cell_costs(cfg, cell, quant)
    return {
        "analytic_flops_global": cc.flops,
        "analytic_bytes_global": cc.total_bytes,
        "analytic_weight_bytes": cc.weight_bytes,
        "analytic_act_bytes": cc.act_bytes,
        "analytic_cache_bytes": cc.cache_bytes,
        "analytic_compute_s": cc.flops / chips / PEAK_FLOPS,
        "analytic_memory_s": cc.total_bytes / chips / HBM_BW,
    }


# ---------------------------------------------------------------------------
# Disaggregated-serving split policy (serving.disagg / ROADMAP #5)
# ---------------------------------------------------------------------------
# Prefill is compute-bound (S×ctx score work per admitted token), decode is
# bandwidth-bound (whole cache line + full weight stream per emitted token).
# The policy compares each side's arithmetic intensity to the machine
# balance point and predicts the prompt length past which one prefill's
# wall time convoys a full decode step — the crossover where running the
# two phases on separate engines starts to pay for the page transfer.

def serving_cell(step: str, seq_len: int, batch: int = 1) -> ShapeCell:
    """Ad-hoc shape cell for serving-side placement decisions (the fixed
    `SHAPES` registry covers the paper's report grid, not every serving
    point the scheduler sees)."""
    return ShapeCell(f"{step}_{seq_len}x{batch}", seq_len, batch, step)


def serving_intensity(cfg: ModelConfig, *, step: str, seq_len: int,
                      batch: int = 1, quant: bool = False,
                      chips: int = 1) -> dict:
    """Roofline terms for one serving-side dispatch shape.

    ``intensity`` is FLOPs/byte; a dispatch is compute-bound when it
    exceeds the machine balance (PEAK_FLOPS / HBM_BW), else memory-bound.
    """
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
    cc = cell_costs(cfg, serving_cell(step, seq_len, batch), quant)
    t_c = cc.flops / chips / PEAK_FLOPS
    t_m = cc.total_bytes / chips / HBM_BW
    return {
        "flops": cc.flops,
        "bytes": cc.total_bytes,
        "intensity": cc.flops / max(cc.total_bytes, 1.0),
        "compute_s": t_c,
        "memory_s": t_m,
        "time_s": max(t_c, t_m),
        "bound": "compute" if t_c >= t_m else "memory",
    }


def _prefill_time_s(cfg: ModelConfig, seq_len: int, quant: bool,
                    chips: int) -> float:
    return serving_intensity(cfg, step="prefill", seq_len=seq_len,
                             quant=quant, chips=chips)["time_s"]


def disagg_report(cfg: ModelConfig, *, decode_batch: int = 8,
                  context: int = 4096, quant: bool = False,
                  prefill_chips: int = 1, decode_chips: int = 1) -> dict:
    """Roofline-derived prefill/decode disaggregation policy for one arch.

    Returns the two sides' arithmetic intensity vs the machine balance,
    whether disaggregation is predicted to pay (prefill compute-bound AND
    decode memory-bound — the phases want different hardware operating
    points), and ``crossover_prompt_tokens``: the smallest prompt whose
    single prefill costs more wall time than one full decode step over
    ``decode_batch`` slots at ``context`` — past it, a unified engine
    admitting that prompt stalls every decoding slot by more than one
    inter-token interval, which is exactly the convoy the disagg bench
    measures. ``None`` when no prompt up to ``context`` crosses (unified
    stays the right default — small deployments land here).
    """
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
    pre = serving_intensity(cfg, step="prefill", seq_len=context,
                            quant=quant, chips=prefill_chips)
    dec = serving_intensity(cfg, step="decode", seq_len=context,
                            batch=decode_batch, quant=quant,
                            chips=decode_chips)
    # bracket the crossover by doubling, then bisect to page granularity
    crossover = None
    lo, s = 1, 16
    while s <= context:
        if _prefill_time_s(cfg, s, quant, prefill_chips) > dec["time_s"]:
            hi = s
            while hi - lo > 16:
                mid = (lo + hi) // 2
                if _prefill_time_s(cfg, mid, quant,
                                   prefill_chips) > dec["time_s"]:
                    hi = mid
                else:
                    lo = mid
            crossover = hi
            break
        lo, s = s, s * 2
    return {
        "machine_balance": PEAK_FLOPS / HBM_BW,
        "prefill_intensity": pre["intensity"],
        "decode_intensity": dec["intensity"],
        "prefill_bound": pre["bound"],
        "decode_bound": dec["bound"],
        "prefill_time_s": pre["time_s"],
        "decode_step_time_s": dec["time_s"],
        "disaggregate": (pre["bound"] == "compute"
                         and dec["bound"] == "memory"),
        "crossover_prompt_tokens": crossover,
    }
