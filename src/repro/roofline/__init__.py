from repro.roofline.analysis import (RooflineTerms, analyze_compiled,  # noqa: F401
                                     collective_bytes_from_hlo, roofline_terms)
