"""Roofline terms from the compiled dry-run artifact (no hardware needed).

Per the assignment:
    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

`cost_analysis()` supplies FLOPs/bytes; collective bytes are parsed from the
compiled HLO text by summing the **operand** sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Partitioning note (verified empirically in EXPERIMENTS.md §Dry-run): under
SPMD the compiled module is the single per-device program, so
cost_analysis/HLO numbers are *per-chip*. The roofline denominators below
therefore use per-chip peaks (the assignment's ``chips ×`` denominators with
the matching global numerators — dividing per-chip work by per-chip peak is
the same quantity).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(?:[a-z]+\d*)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s4": 0.5,
                "u4": 0.5, "c64": 8, "c128": 16}


def _type_bytes(t: str) -> float:
    """'f32[256,128]{1,0}' → bytes."""
    m = re.match(r"([a-z]+[\d\w]*?)\[([\d,]*)\]", t)
    if not m:
        return 0.0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"                 # result name
    r"((?:\([^=]*?\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"  # result type
    r"([\w\-]+)\(")                                          # op name


def _result_bytes(type_str: str) -> float:
    """Bytes of a result type, tuples summed."""
    return sum(_type_bytes(m.group(0)) for m in re.finditer(
        r"[a-z]+[\d\w]*?\[[\d,]*\]", type_str))


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name → its instruction lines.

    Header lines start at column 0 and end with '{':
      ``%name (params...) -> type {`` / ``ENTRY %main (...) -> ... {``.
    Signatures contain nested parens (tuple params), so the name is taken as
    the first token rather than regex-parsing the full signature.
    """
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        if (not line.startswith(" ") and line.rstrip().endswith("{")
                and not line.startswith("HloModule")):
            tok = line.strip()
            if tok.startswith("ENTRY"):
                tok = tok[len("ENTRY"):].strip()
            name = tok.lstrip("%").split("(")[0].split()[0] if tok else ""
            if name:
                cur = name
                comps[cur] = []
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _loop_multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Effective execution count per computation.

    `lax.scan` compiles to `while(...), condition=%c, body=%b`; the trip
    count is the constant bound in the condition's compare. cost_analysis
    and a naive text scan count loop bodies ONCE — this multiplier map is
    how the roofline corrects collective bytes for scanned layer stacks
    (compose through nesting: a scan inside a scan multiplies).
    """
    # trip count per while-body: find its condition's compare constant
    body_trip: dict[str, float] = {}
    calls: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    for cname, lines in comps.items():
        for ln in lines:
            wm = re.search(r"while\(.*?condition=%?([\w.\-]+), "
                           r"body=%?([\w.\-]+)", ln)
            if not wm:
                wm = re.search(r"while\(.*?body=%?([\w.\-]+), "
                               r"condition=%?([\w.\-]+)", ln)
                if wm:
                    body, cond = wm.group(1), wm.group(2)
                else:
                    body = cond = None
            else:
                cond, body = wm.group(1), wm.group(2)
            if body:
                trip = 1.0
                for cl in comps.get(cond, []):
                    cm = re.search(r"constant\((\d+)\)", cl)
                    if cm:
                        trip = max(trip, float(cm.group(1)))
                body_trip[body] = trip
                calls[cname].append((body, trip))
            # non-while computation calls execute once per call site
            for sub in re.finditer(
                    r"(?:to_apply|body|calls|computation)=%?([\w.\-]+)", ln):
                if sub.group(1) != body and sub.group(1) in comps:
                    calls[cname].append((sub.group(1), 1.0))

    mult: dict[str, float] = {}

    def fill(cname: str, m: float):
        mult[cname] = max(mult.get(cname, 0.0), m)
        for child, k in calls.get(cname, []):
            if child != cname:
                fill(child, m * k)

    # entry computations: those never called
    called = {c for lst in calls.values() for c, _ in lst}
    for c in comps:
        if c not in called:
            fill(c, 1.0)
    for c in comps:
        mult.setdefault(c, 1.0)
    return mult


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Sum **operand** bytes per collective kind from compiled HLO text.

    Compiled/scheduled HLO references operands by name only, so we build a
    symbol table (name → result bytes) first, then resolve each collective's
    operand list. Collectives inside while (scan) bodies are multiplied by
    the loop trip count (`_loop_multipliers`); async -start/-done pairs are
    counted once at -start.
    """
    comps = _split_computations(hlo_text)
    if not comps:  # fallback: treat whole text as one computation
        comps = {"entry": hlo_text.splitlines()}
    mults = _loop_multipliers(comps)

    table: dict[str, float] = {}
    coll: list[tuple[str, str, float]] = []
    for cname, lines in comps.items():
        m_c = mults.get(cname, 1.0)
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rtype, op = m.groups()
            table[name] = _result_bytes(rtype)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                call = line.split("(", 1)[1]
                depth, end = 1, len(call)
                for i, ch in enumerate(call):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                coll.append((base, call[:end], m_c))

    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for kind, args, m_c in coll:
        b = 0.0
        for om in re.finditer(r"%([\w.\-]+)", args):
            b += table.get(om.group(1), 0.0)
        if "%" not in args:
            b += _result_bytes(args)
        out[kind] += b * m_c
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0    # analytic 6·N·D (or 6·N_active·D)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        if self.flops == 0:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline step time (MFU-like)."""
        if self.step_time_s == 0:
            return 0.0
        return (self.model_flops / self.chips / PEAK_FLOPS) \
            / self.step_time_s

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "collective_bytes_per_chip": self.collective_bytes,
            "chips": self.chips,
            "model_flops_global": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


# ---------------------------------------------------------------------------
# Full text-based HLO cost model (trip-count aware)
# ---------------------------------------------------------------------------

_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^()]*\))|(?:[a-z]+[\d\w]*?"
                       r"\[[^\]]*\](?:\{[^}]*\})?))")
_SHAPE_DIMS_RE = re.compile(r"[a-z]+[\d\w]*?\[([\d,]*)\]")

# Ops whose operands+results cross the HBM boundary at the top level of a
# scheduled computation. Elementwise/layout ops (add, convert, transpose,
# broadcast, …) are excluded — a TPU compile fuses those into neighbors, and
# counting them would bill the same buffer once per elementwise op. What
# remains is one materialization per fusion/matmul/reduction/scatter-gather
# boundary: the TPU-semantics HBM traffic estimate.
_MEM_OPS = ("fusion", "custom-call", "dot", "convolution", "scatter",
            "gather", "dynamic-slice", "dynamic-update-slice", "copy",
            "reduce", "reduce-window", "sort", "concatenate")


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_DIMS_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def hlo_costs(hlo_text: str) -> dict[str, float]:
    """FLOPs / HBM bytes / collective bytes from compiled HLO text, with
    while-loop bodies multiplied by their trip counts.

    This replaces `compiled.cost_analysis()` for the roofline because XLA's
    cost analysis visits each computation ONCE — a scanned 40-layer stack
    would be undercounted 40×. Method:
      * flops  — every `dot` line: 2 × prod(result dims) × K, K from the
        lhs operand's contracting dims (per-computation symbol tables built
        from instruction results and header params), × loop multiplier.
      * bytes  — operand+result bytes of top-level memory-moving ops in
        control-flow computations (entry + while bodies); fusion-internal
        computations are excluded (register/VMEM-resident).
      * collective bytes — operand bytes of collective ops × multiplier.
    """
    comps = _split_computations(hlo_text)
    mults = _loop_multipliers(comps)

    # per-computation symbol tables (params from headers need re-parse)
    tables: dict[str, dict[str, float]] = {}
    type_tables: dict[str, dict[str, str]] = {}
    header_params: dict[str, dict[str, str]] = {}
    for line in hlo_text.splitlines():
        if (not line.startswith(" ") and line.rstrip().endswith("{")
                and not line.startswith("HloModule")):
            tok = line.strip()
            if tok.startswith("ENTRY"):
                tok = tok[len("ENTRY"):].strip()
            name = tok.lstrip("%").split("(")[0].split()[0] if tok else ""
            if not name:
                continue
            sig = tok[len(name) + (1 if tok.startswith("%") else 0):]
            header_params[name] = {pm.group(1): pm.group(2)
                                   for pm in _PARAM_RE.finditer(sig)}

    # computations called via calls=/to_apply= are fusion-internal
    internal: set[str] = set()
    for lines in comps.values():
        for ln in lines:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", ln):
                internal.add(m.group(1))

    flops = 0.0
    mem_bytes = 0.0
    for cname, lines in comps.items():
        mult = mults.get(cname, 1.0)
        table: dict[str, str] = dict(header_params.get(cname, {}))
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            name, rtype, op = m.groups()
            table[name] = rtype
            if op == "dot":
                k = 1.0
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                lhs_m = re.search(r"dot\(%?([\w.\-]+)", ln)
                if cm and lhs_m and lhs_m.group(1) in table:
                    ld = _dims(table[lhs_m.group(1)])
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(ld):
                            k *= ld[int(ci)]
                rd = _dims(rtype)
                n_out = 1.0
                for d in rd:
                    n_out *= d
                flops += 2.0 * n_out * k * mult
            if cname not in internal and any(
                    op == mo or op.startswith(mo + ".") for mo in _MEM_OPS):
                call = ln.split("(", 1)[1] if "(" in ln else ""
                ops_b = [_result_bytes(table[om.group(1)])
                         for om in re.finditer(r"%([\w.\-]+)",
                                               call.split("),")[0])
                         if om.group(1) in table]
                rb = _result_bytes(rtype)
                # Slice-semantics ops move only the slice, not the (possibly
                # giant, aliased-in-place) backing buffer — e.g. per-layer
                # reads/writes against a scan-stacked KV-cache carry.
                if op.startswith("dynamic-slice"):
                    b = 2.0 * rb
                elif op.startswith("dynamic-update-slice"):
                    upd = ops_b[1] if len(ops_b) > 1 else rb
                    b = 2.0 * upd
                elif op.startswith("gather"):
                    b = 2.0 * rb + (ops_b[1] if len(ops_b) > 1 else 0.0)
                elif op.startswith("scatter"):
                    upd = ops_b[-1] if ops_b else rb
                    b = 2.0 * upd + (ops_b[1] if len(ops_b) > 2 else 0.0)
                elif op.startswith("fusion") and rb in ops_b:
                    # In-place update fusion (scan ys-accumulation / cache
                    # write): the result aliases the same-sized operand —
                    # the buffer is NOT re-read/re-written wholesale, only
                    # the updated region moves (≈ the other operands).
                    others = list(ops_b)
                    others.remove(rb)
                    b = sum(others) + (max(others) if others else 0.0)
                else:
                    b = rb + sum(ops_b)
                mem_bytes += b * mult

    coll = collective_bytes_from_hlo(hlo_text)
    return {"flops": flops, "bytes": mem_bytes, **coll}


def analyze_compiled(compiled, chips: int,
                     model_flops: float = 0.0) -> RooflineTerms:
    costs = hlo_costs(compiled.as_text())
    return RooflineTerms(flops=costs["flops"], bytes_accessed=costs["bytes"],
                         collective_bytes=costs["total"], chips=chips,
                         model_flops=model_flops)


def roofline_terms(flops, bytes_accessed, collective_bytes, chips,
                   model_flops=0.0) -> RooflineTerms:
    return RooflineTerms(flops, bytes_accessed, collective_bytes, chips,
                         model_flops)
