from repro.data.pipeline import SyntheticDataset, make_dataset  # noqa: F401
