"""Deterministic, resumable, shard-aware synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` (numpy Philox keyed on
both), so:
  * resume-after-failure is EXACT — restoring a checkpoint at step k and
    re-creating the iterator replays the identical stream (tested),
  * multi-host sharding needs no coordination — each host slices its rows
    of the global batch by `host_slice` (process_index-based at real scale).

The token stream is a vocab-reduced Markov chain rather than iid uniform so
training loss has signal to descend (next-token entropy < log V); audio
features are band-limited noise; vision stubs are unit-normal patch
embeddings, matching the assignment's "frontend is a STUB" rule.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.Generator(np.random.Philox(
            key=[np.uint64(self.seed), np.uint64(step)]))
        cfg, b, s = self.cfg, self.global_batch, self.seq_len
        if cfg.frontend == "audio":
            t = np.arange(s)[None, :, None]
            phase = rng.uniform(0, 2 * np.pi, (b, 1, cfg.frontend_dim))
            freq = rng.uniform(0.01, 0.3, (b, 1, cfg.frontend_dim))
            feats = (np.sin(freq * t + phase)
                     + 0.1 * rng.standard_normal((b, s, cfg.frontend_dim)))
            labels = rng.integers(0, cfg.vocab_size, (b, s))
            return {"features": feats.astype(np.float32),
                    "labels": labels.astype(np.int32)}
        # Markov-ish token stream over a reduced alphabet: tok_{t+1} =
        # (a * tok_t + drift) mod A with occasional jumps — compressible.
        alpha = min(cfg.vocab_size, 4096)
        tok = np.empty((b, s + 1), np.int64)
        tok[:, 0] = rng.integers(0, alpha, b)
        jumps = rng.random((b, s)) < 0.1
        jump_to = rng.integers(0, alpha, (b, s))
        for t in range(s):
            nxt = (tok[:, t] * 31 + 7) % alpha
            tok[:, t + 1] = np.where(jumps[:, t], jump_to[:, t], nxt)
        batch = {"tokens": tok[:, :-1].astype(np.int32),
                 "labels": tok[:, 1:].astype(np.int32)}
        if cfg.frontend == "vision":
            batch["images"] = rng.standard_normal(
                (b, cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
            # image span is prepended by the model; labels align to text part
        return batch

    def host_slice(self, batch: dict, host_id: int, n_hosts: int) -> dict:
        per = self.global_batch // n_hosts
        return {k: v[host_id * per:(host_id + 1) * per]
                for k, v in batch.items()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0) -> SyntheticDataset:
    return SyntheticDataset(cfg, global_batch, seq_len, seed)
