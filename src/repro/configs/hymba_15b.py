"""hymba-1.5b — 32L d=1600 25H GQA(kv=5) hd=64 d_ff=5504 V=32001,
parallel attn∥Mamba heads, ssm_state=16, SWA(1024) with full attention at
layers {0, 15, 31}.

[arXiv:2411.13676; hf]. Runs long_500k (hybrid: bounded-window KV + O(1)
SSM state). V=32001 is not 16-divisible → embedding shards its d_model axis
instead (sharding fallback rule).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        head_dim=64, d_ff=5504, vocab_size=32_001,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        tie_embeddings=True,
        sliding_window=1024, global_layers=(0, 15, 31),
        ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        ssm_conv=4, rope_theta=10_000.0, max_seq_len=524_288,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        num_layers=5, d_model=128, num_heads=2, num_kv_heads=1,
        head_dim=64, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu", tie_embeddings=True,
        sliding_window=32, global_layers=(0, 2, 4),
        ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        ssm_conv=4, max_seq_len=128, attn_chunk=32, logits_chunk=32,
        ssm_chunk=32,
    )
