"""Model/config schema shared by every architecture.

A `ModelConfig` fully determines parameter shapes, the per-layer block kinds
(`layer_kinds()`), and the input pytrees for each assigned shape cell
(`input_specs` lives in `launch/specs.py` so this module stays jax-light).

`LayerKind` is the unit the stack builder groups into scan segments: runs of
identical kinds are scanned over stacked params (compile-time O(1) in run
length), kind changes break segments (gemma3's 5:1 local:global, hymba's
3 full-attention layers, deepseek's first dense layer).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "mla", "mamba", "hymba"]
Mlp = Literal["glu", "plain", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: Mixer = "attn"
    mlp: Mlp = "glu"
    window: int = 0          # 0 = full attention; >0 = sliding-window size
    is_global: bool = True   # False for windowed layers

    @property
    def tag(self) -> str:
        w = f"w{self.window}" if self.window else "full"
        return f"{self.mixer}-{w}-{self.mlp}"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"     # dense | moe | hybrid | ssm | audio | vlm
    # trunk ----------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab_size: int = 256
    act: str = "silu"              # activation inside the MLP
    mlp_type: str = "glu"          # "glu" (gate*up) | "plain" (single up)
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rms_plus_one: bool = False     # gemma convention: weight = 1 + gamma
    # attention ------------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0     # glm4: 0.5 (partial rotary)
    local_rope_theta: float = 0.0  # gemma3: different theta on local layers
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0
    global_every: int = 0          # gemma3: layer (i+1) % global_every == 0 is global
    global_layers: tuple[int, ...] = ()  # hymba: explicit global layer ids
    # embeddings -----------------------------------------------------------
    tie_embeddings: bool = False
    scale_embed: bool = False      # gemma: multiply embeddings by sqrt(d)
    # MoE ------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_expert_gate: bool = False   # qwen2-moe sigmoid gate on shared out
    first_dense_layers: int = 0        # deepseek-v2: layer 0 keeps dense MLP
    norm_topk_prob: bool = False
    router_aux_weight: float = 0.001
    capacity_factor: float = 1.25
    # MLA (deepseek-v2) ------------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / hymba) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # structure ------------------------------------------------------------
    is_encoder: bool = False
    frontend: str = "none"        # none | audio (hubert) | vision (phi3-v)
    frontend_dim: int = 0         # raw feature dim fed by the stub frontend
    num_patches: int = 0          # vlm: image patch tokens per sample
    max_seq_len: int = 4096
    # numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    kv_quant: str = "none"         # "none" | "int8" — quantized KV cache
                                   # (§Perf A4: decode is cache-bound once
                                   # weights are INT4; per-(token, head)
                                   # absmax scales, KIVI-style)
    logits_chunk: int = 512        # seq chunk for the chunked-vocab CE loss
    attn_chunk: int = 1024         # q-chunk for long-sequence attention
    remat: bool = True

    # ------------------------------------------------------------------ api
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def shared_d_ff(self) -> int:
        return self.num_shared_experts * self.moe_d_ff

    def _is_global(self, i: int) -> bool:
        if self.sliding_window == 0:
            return True
        if self.global_layers:
            return i in self.global_layers
        if self.global_every:
            return (i + 1) % self.global_every == 0
        return False

    def layer_kinds(self) -> tuple[LayerKind, ...]:
        kinds = []
        for i in range(self.num_layers):
            g = self._is_global(i)
            window = 0 if g else self.sliding_window
            if self.family == "ssm":
                kinds.append(LayerKind(mixer="mamba", mlp="none"))
                continue
            mixer: Mixer = "attn"
            if self.kv_lora_rank:
                mixer = "mla"
            elif self.family == "hybrid":
                mixer = "hymba"
            if self.num_experts and i >= self.first_dense_layers:
                mlp: Mlp = "moe"
            else:
                mlp = self.mlp_type  # type: ignore[assignment]
            kinds.append(LayerKind(mixer=mixer, mlp=mlp, window=window,
                                   is_global=g))
        return tuple(kinds)

    def segments(self) -> tuple[tuple[LayerKind, int], ...]:
        """Consecutive runs of identical layer kinds (scan units)."""
        segs: list[tuple[LayerKind, int]] = []
        for kind in self.layer_kinds():
            if segs and segs[-1][0] == kind:
                segs[-1] = (kind, segs[-1][1] + 1)
            else:
                segs.append((kind, 1))
        return tuple(segs)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + per-layer), for rooflines."""
        d = self.d_model
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings and not self.is_encoder:
            n += d * self.vocab_size
        for kind in self.layer_kinds():
            n += 2 * d  # two norms (approximation: biases/extra norms ~0)
            if kind.mixer == "attn":
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif kind.mixer == "mla":
                rope, nope = self.qk_rope_head_dim, self.qk_nope_head_dim
                n += d * self.num_heads * (nope + rope)       # q proj
                n += d * (self.kv_lora_rank + rope)           # kv down
                n += self.kv_lora_rank * self.num_heads * (nope + self.v_head_dim)
                n += self.num_heads * self.v_head_dim * d     # o proj
            elif kind.mixer == "mamba":
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_nheads
                n += d * (2 * di + 2 * self.ssm_ngroups * ds + nh) + di * d
            elif kind.mixer == "hymba":
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_nheads
                n += d * (2 * di + 2 * self.ssm_ngroups * ds + nh) + di * d
            if kind.mlp == "glu":
                n += 3 * d * self.d_ff
            elif kind.mlp == "plain":
                n += 2 * d * self.d_ff
            elif kind.mlp == "moe":
                n += d * self.num_experts  # router
                n += self.num_experts * 3 * d * self.moe_d_ff
                if self.num_shared_experts:
                    n += 3 * d * self.shared_d_ff
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.num_experts:
            return self.n_params()
        full = self.n_params()
        routed_all = sum(1 for k in self.layer_kinds() if k.mlp == "moe") * \
            self.num_experts * 3 * self.d_model * self.moe_d_ff
        routed_active = routed_all * self.top_k / self.num_experts
        return int(full - routed_all + routed_active)
