"""hubert-xlarge — 48L encoder d=1280 16H MHA d_ff=5120, codebook vocab 504.

[arXiv:2106.07447; unverified]. Encoder-only (bidirectional attention, no
decode step → decode_32k/long_500k skipped). The conv waveform frontend is
a STUB per assignment: `input_specs()` provides precomputed frame embeddings
[B, S, 512] which a linear `frame_proj` maps to d_model. Training objective:
masked-unit prediction = CE over the 504-codeword vocabulary. LayerNorm +
plain GELU MLP (wav2vec2 family), no RoPE (rope_fraction=0).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
        head_dim=80, d_ff=5120, vocab_size=504,
        act="gelu", mlp_type="plain", norm_type="layernorm", norm_eps=1e-5,
        rope_fraction=0.0, is_encoder=True,
        frontend="audio", frontend_dim=512,
        tie_embeddings=False, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio",
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=64, d_ff=256, vocab_size=64,
        act="gelu", mlp_type="plain", norm_type="layernorm", norm_eps=1e-5,
        rope_fraction=0.0, is_encoder=True,
        frontend="audio", frontend_dim=32,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
