"""phi-3-vision-4.2b — 32L d=3072 32H MHA hd=96 d_ff=8192 V=32064 + CLIP stub.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]. Backbone = phi3-mini; the
CLIP-ViT frontend is a STUB per assignment: `input_specs()` provides
precomputed patch embeddings [B, 256, 1024], linearly projected and
prepended to the token sequence (labels masked over the image span).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        head_dim=96, d_ff=8192, vocab_size=32_064,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        tie_embeddings=False, rope_theta=10_000.0,
        frontend="vision", frontend_dim=1024, num_patches=256,
        max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-vision-smoke", family="vlm",
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=64, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu",
        frontend="vision", frontend_dim=32, num_patches=8,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
