"""gemma-2b — 18L d=2048 8H MQA(kv=1) hd=256 d_ff=16384 V=256000, GeGLU.

[arXiv:2403.08295; hf]. Gemma conventions: embeddings scaled by sqrt(d),
RMSNorm weight stored as (1 + gamma), tied lm head, GeGLU MLP, MQA.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        head_dim=256, d_ff=16384, vocab_size=256_000,
        act="gelu", mlp_type="glu", norm_type="rmsnorm",
        rms_plus_one=True, scale_embed=True, tie_embeddings=True,
        rope_theta=10_000.0, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=1,
        head_dim=64, d_ff=256, vocab_size=512,
        act="gelu", mlp_type="glu", rms_plus_one=True, scale_embed=True,
        tie_embeddings=True, max_seq_len=128, attn_chunk=32,
        logits_chunk=32,
    )
