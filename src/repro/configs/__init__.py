"""Architecture registry: 10 assigned archs + the paper's qwen2.5-0.5b.

Each module exposes ``config()`` (the exact published dims) and
``smoke_config()`` (a reduced same-family variant for CPU tests). Shape
cells and skip rules (DESIGN.md §4) live in `SHAPES` / `cells_for`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs import (deepseek_v2_lite, gemma3_4b, gemma_2b, glm4_9b,
                           hubert_xlarge, hymba_15b, mamba2_130m,
                           phi3_vision, qwen2_moe_a27b, qwen25_05b,
                           smollm_360m)
from repro.configs.base import LayerKind, ModelConfig  # noqa: F401

_REGISTRY: dict[str, tuple[Callable, Callable]] = {
    "gemma-2b": (gemma_2b.config, gemma_2b.smoke_config),
    "gemma3-4b": (gemma3_4b.config, gemma3_4b.smoke_config),
    "glm4-9b": (glm4_9b.config, glm4_9b.smoke_config),
    "smollm-360m": (smollm_360m.config, smollm_360m.smoke_config),
    "qwen2-moe-a2.7b": (qwen2_moe_a27b.config, qwen2_moe_a27b.smoke_config),
    "deepseek-v2-lite-16b": (deepseek_v2_lite.config,
                             deepseek_v2_lite.smoke_config),
    "hymba-1.5b": (hymba_15b.config, hymba_15b.smoke_config),
    "hubert-xlarge": (hubert_xlarge.config, hubert_xlarge.smoke_config),
    "mamba2-130m": (mamba2_130m.config, mamba2_130m.smoke_config),
    "phi-3-vision-4.2b": (phi3_vision.config, phi3_vision.smoke_config),
    "qwen25-05b": (qwen25_05b.config, qwen25_05b.smoke_config),
}

ASSIGNED_ARCHS = tuple(a for a in _REGISTRY if a != "qwen25-05b")


def list_archs() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    return _REGISTRY[name][0]()


def get_smoke_config(name: str) -> ModelConfig:
    return _REGISTRY[name][1]()


# ---------------------------------------------------------------------------
# Shape cells (assignment): seq_len × global_batch × lowered step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: runs for SSM/hybrid/local-global.
_LONG_OK = ("mamba2-130m", "hymba-1.5b", "gemma3-4b")


def cells_for(arch: str) -> list[str]:
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder:
        cells.append("decode_32k")
        if arch in _LONG_OK:
            cells.append("long_500k")
    return cells


def skipped_cells(arch: str) -> dict[str, str]:
    cfg = get_config(arch)
    skips = {}
    if cfg.is_encoder:
        skips["decode_32k"] = "encoder-only: no autoregressive decode step"
        skips["long_500k"] = "encoder-only: no decode step"
    elif arch not in _LONG_OK:
        skips["long_500k"] = ("pure full-attention arch: 500k decode needs "
                              "sub-quadratic attention (DESIGN.md §4)")
    return skips
