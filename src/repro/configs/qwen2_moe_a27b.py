"""qwen2-moe-a2.7b — 24L d=2048 16H MHA d_ff(expert)=1408 V=151936,
MoE 60 routed top-4 + 4 shared experts with sigmoid gate.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. QKV bias (qwen convention).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=5632, vocab_size=151_936,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        qkv_bias=True, tie_embeddings=False,
        num_experts=60, top_k=4, moe_d_ff=1408, num_shared_experts=4,
        shared_expert_gate=True, norm_topk_prob=False,
        rope_theta=1_000_000.0, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", family="moe",
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=64, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu", qkv_bias=True,
        num_experts=8, top_k=2, moe_d_ff=128, num_shared_experts=2,
        shared_expert_gate=True, capacity_factor=2.0,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
