"""qwen2.5-0.5b — the paper's reproduction target.

24L d=896 14H GQA(kv=2) hd=64 d_ff=4864 V=151936, QKV bias, tied
embeddings [Qwen2.5 report / hf:Qwen/Qwen2.5-0.5B]. The compression-rate
benchmark (paper Table III: 988 MB → 443.81 MB, 55.1%) packs THIS config
through the byte-exact AWQ_MACRO serializer with the paper's GS=64.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen25-05b", family="dense",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        head_dim=64, d_ff=4864, vocab_size=151_936,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen25-05b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=1,
        head_dim=64, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu", qkv_bias=True, tie_embeddings=True,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
