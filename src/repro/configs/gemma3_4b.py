"""gemma3-4b — 34L d=2560 8H GQA(kv=4) hd=256 d_ff=10240 V=262144.

[hf:google/gemma-3-4b-pt; unverified]. 5:1 local:global interleave (sliding
window 1024, layer (i+1)%6==0 is global), QK-norm, dual rope theta (1M
global / 10k local), gemma norm/embedding conventions. Runs long_500k:
29/34 layers are windowed (sub-quadratic); the 5 global layers are O(S) per
decode step, which is the decode regime anyway (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
        head_dim=256, d_ff=10240, vocab_size=262_144,
        act="gelu", mlp_type="glu", norm_type="rmsnorm",
        rms_plus_one=True, scale_embed=True, tie_embeddings=True,
        qk_norm=True, sliding_window=1024, global_every=6,
        rope_theta=1_000_000.0, local_rope_theta=10_000.0,
        max_seq_len=524_288,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        num_layers=7, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=64, d_ff=256, vocab_size=512,
        act="gelu", mlp_type="glu", rms_plus_one=True, scale_embed=True,
        tie_embeddings=True, qk_norm=True, sliding_window=32,
        global_every=3, rope_theta=1_000_000.0, local_rope_theta=10_000.0,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
