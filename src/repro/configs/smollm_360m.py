"""smollm-360m — 32L d=960 15H GQA(kv=5) hd=64 d_ff=2560 V=49152.

[hf:HuggingFaceTB/SmolLM-360M; hf]. Llama-family small model, tied
embeddings, SwiGLU.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
        head_dim=64, d_ff=2560, vocab_size=49_152,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        tie_embeddings=True, rope_theta=10_000.0, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke", family="dense",
        num_layers=2, d_model=192, num_heads=3, num_kv_heads=1,
        head_dim=64, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu", tie_embeddings=True,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
