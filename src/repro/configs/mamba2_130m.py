"""mamba2-130m — 24L d=768, attention-free SSD, ssm_state=128, V=50280.

[arXiv:2405.21060; unverified]. expand=2 → d_inner=1536, headdim=64 →
24 SSM heads, 1 B/C group, conv window 4. Tied embeddings. Attention-free →
constant-size decode state → runs long_500k natively.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=0, vocab_size=50_280,
        norm_type="rmsnorm", tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        ssm_conv=4, ssm_chunk=256, max_seq_len=524_288,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=64, d_ff=0, vocab_size=512,
        tie_embeddings=True,
        ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        ssm_conv=4, ssm_chunk=32, max_seq_len=128, attn_chunk=32,
        logits_chunk=32,
    )
