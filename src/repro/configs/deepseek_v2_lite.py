"""deepseek-v2-lite-16b — 27L d=2048, MLA (kv_lora=512, rope/nope split
heads 64+128, v=128), MoE 64 routed top-6 + 2 shared, first layer dense.

[arXiv:2405.04434; hf]. Assignment note (DESIGN.md §4): the spec line reads
"MoE 64e top-6" with a prose mention of 160 routed; we follow the bracketed
64-expert figure. MLA decode uses the absorbed form with a latent cache
(models/mla.py).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=192, d_ff=10944, vocab_size=102_400,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=64, top_k=6, moe_d_ff=1408, num_shared_experts=2,
        first_dense_layers=1, norm_topk_prob=True,
        rope_theta=10_000.0, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        num_layers=3, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=96, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu",
        kv_lora_rank=64, qk_nope_head_dim=64, qk_rope_head_dim=32,
        v_head_dim=64,
        num_experts=8, top_k=2, moe_d_ff=128, num_shared_experts=1,
        first_dense_layers=1, norm_topk_prob=True, capacity_factor=2.0,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
