"""glm4-9b — 40L d=4096 32H GQA(kv=2) hd=128 d_ff=13696 V=151552.

[hf:THUDM/glm-4-9b; hf]. Partial rotary (half the head dims), SwiGLU,
QKV bias, untied head.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
        head_dim=128, d_ff=13696, vocab_size=151_552,
        act="silu", mlp_type="glu", norm_type="rmsnorm",
        rope_fraction=0.5, qkv_bias=True, tie_embeddings=False,
        rope_theta=10_000.0, max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        act="silu", mlp_type="glu", rope_fraction=0.5, qkv_bias=True,
        max_seq_len=128, attn_chunk=32, logits_chunk=32,
    )
