"""Whole-model post-training quantization (PTQ) pipeline.

Mirrors the paper's fully-automated flow (§III-A: "Our approach is fully
automated, allowing for seamless inference deployment ... with the AutoAWQ
library, the binary file, and the JSON file"):

    1. run a calibration forward pass under `CalibrationCapture` (eager),
    2. per linear: AWQ scale search on the captured activations,
    3. group-quantize the scaled weight, pack into the TPU layout
       (`PackedLinear`), keep the inverse activation scale,
    4. (optionally) serialize byte-exact AWQ_MACRO blobs for the
       compression-rate benchmark.

Model params are nested dicts; linears are sub-dicts ``{"w": [K,N]}`` (plus
optional ``"b"``). Scan-stacked layers carry leading layer dims
(``[L, K, N]`` or ``[G, L, K, N]``); capture names address them as
``blocks@i/...`` segments. Layers without captured stats fall back to plain
round-to-nearest group quantization (scale = 1), so PTQ of an uncalibrated
model is still valid — just without the activation-aware protection.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.awq import AWQConfig, search_awq_scale
from repro.core.calibration import LinearStats
from repro.core.packing import (PACK, PackedLinear, pack_linear,
                                packed_linear_nbytes)
from repro.core.quantize import QuantConfig, quantize_groupwise

# Param-path substrings never quantized (AWQ convention: embeddings, norms,
# tiny routers and positional tables stay in high precision).
DEFAULT_EXCLUDE = ("embed", "norm", "router", "lm_head", "conv", "a_log",
                   "dt_bias", "ssm_d", "pos_", "scale", "patch_proj")


@dataclasses.dataclass
class PTQReport:
    """Bookkeeping from one `quantize_params` run."""

    quantized: list[str] = dataclasses.field(default_factory=list)
    skipped: list[str] = dataclasses.field(default_factory=list)
    calibrated: list[str] = dataclasses.field(default_factory=list)
    packed_bytes: int = 0          # byte-exact AWQ_MACRO size of quantized linears
    dense_bytes_fp16: int = 0      # fp16 size of the same linears

    @property
    def compression_ratio(self) -> float:
        if self.dense_bytes_fp16 == 0:
            return 1.0
        return self.packed_bytes / self.dense_bytes_fp16


def _is_linear(node: Any) -> bool:
    return (isinstance(node, dict) and "w" in node
            and hasattr(node["w"], "ndim") and node["w"].ndim >= 2
            and all(k in ("w", "b") for k in node))


def _quantizable(path: str, node: dict, qcfg: QuantConfig,
                 exclude: tuple[str, ...]) -> bool:
    w = node["w"]
    k, n = w.shape[-2], w.shape[-1]
    if any(e in path.lower() for e in exclude):
        return False
    # N must tile into AWQ macros, whose channel width equals the int4
    # pack width along K (core/packing.PACK) — one source of truth.
    if k % qcfg.group_size or n % PACK:
        return False
    return k * n >= 16384  # skip tiny projections (paper keeps them on CPU)


def _quantize_2d(w: jax.Array, stats: LinearStats | None,
                 cfg: AWQConfig) -> tuple[jax.Array, jax.Array, jax.Array,
                                          jax.Array]:
    """Returns (q, scales, zeros, input_scale[K]) for one [K, N] weight."""
    k = w.shape[0]
    if stats is not None and stats.rows.shape[0] >= 8:
        s, _ = search_awq_scale(jnp.asarray(stats.rows), w, cfg)
    else:
        s = jnp.ones((k,), jnp.float32)
    w_scaled = w.astype(jnp.float32) * s[:, None]
    q, scales, zeros = quantize_groupwise(w_scaled, cfg.quant)
    return q, scales, zeros, 1.0 / s


def quantize_params(params: Any,
                    calib: dict[str, LinearStats] | None = None,
                    cfg: AWQConfig | None = None,
                    exclude: tuple[str, ...] = DEFAULT_EXCLUDE,
                    select: Callable[[str], bool] | None = None,
                    ) -> tuple[Any, PTQReport]:
    """Replace every quantizable linear in ``params`` with a `PackedLinear`.

    Args:
      params: nested-dict model params (float).
      calib:  capture stats from `CalibrationCapture.stats` (or None → RTN).
      cfg:    AWQ search + quant config (GS=64 INT4 asym by default, §III-A).
      select: optional extra predicate on the linear's path.

    Returns:
      (new_params, PTQReport).
    """
    cfg = cfg or AWQConfig()
    calib = calib or {}
    report = PTQReport()

    def stats_for(path_parts: list[str], idx: tuple[int, ...]) -> LinearStats | None:
        # Capture-name convention: "<param-path>@<i[,j]>" for stacked layers
        # (see models/stack.py), plain path otherwise.
        base = "/".join(path_parts)
        if not idx:
            return calib.get(base)
        return calib.get(f"{base}@{','.join(str(int(v)) for v in idx)}")

    def visit(node: Any, path_parts: list[str]) -> Any:
        path = "/".join(path_parts)
        if _is_linear(node):
            if not _quantizable(path, node, cfg.quant, exclude) or (
                    select is not None and not select(path)):
                report.skipped.append(path)
                return node
            w = node["w"]
            bias = node.get("b")
            lead = w.shape[:-2]
            k, n = w.shape[-2], w.shape[-1]
            if lead:  # stacked layers: quantize each slice
                w_flat = w.reshape(-1, k, n)
                qs, ss, zs, iscs, any_calib = [], [], [], [], False
                for i in range(w_flat.shape[0]):
                    idx = np.unravel_index(i, lead)
                    st = stats_for(path_parts[:-1] + [path_parts[-1]],
                                   tuple(int(v) for v in idx))
                    any_calib = any_calib or st is not None
                    q, sc, z, isc = _quantize_2d(w_flat[i], st, cfg)
                    qs.append(q); ss.append(sc); zs.append(z); iscs.append(isc)
                from repro.core.packing import pack_int4
                packed = PackedLinear(
                    qweight=jnp.stack([pack_int4(q) for q in qs]).reshape(
                        *lead, k // PACK, n),
                    scales=jnp.stack(ss).reshape(*lead, k // cfg.quant.group_size, n),
                    zeros=jnp.stack(zs).astype(jnp.int8).reshape(
                        *lead, k // cfg.quant.group_size, n),
                    input_scale=jnp.stack(iscs).reshape(*lead, k),
                    bias=bias,
                    group_size=cfg.quant.group_size,
                )
                n_lin = int(np.prod(lead))
                if any_calib:
                    report.calibrated.append(path)
            else:
                st = stats_for(path_parts, ())
                q, sc, z, isc = _quantize_2d(w, st, cfg)
                packed = pack_linear(q, sc, z, isc, bias, cfg.quant)
                n_lin = 1
                if st is not None:
                    report.calibrated.append(path)
            report.quantized.append(path)
            report.packed_bytes += n_lin * packed_linear_nbytes(
                k, n, cfg.quant.group_size)
            report.dense_bytes_fp16 += n_lin * k * n * 2
            return packed
        if isinstance(node, dict):
            return {k2: visit(v, path_parts + [k2]) for k2, v in node.items()}
        return node

    return visit(params, []), report


def model_size_bytes(params: Any, quantized: bool,
                     cfg: QuantConfig | None = None,
                     exclude: tuple[str, ...] = DEFAULT_EXCLUDE) -> int:
    """Serialized model size: fp16 baseline vs AWQ_MACRO-packed (paper Table III).

    Baseline = every param in fp16 (the paper's 988 MB convention). Quantized
    = quantizable linears in byte-exact AWQ_MACRO format, everything else
    fp16.
    """
    cfg = cfg or QuantConfig()
    total = 0

    def visit(node: Any, path_parts: list[str]) -> None:
        nonlocal total
        path = "/".join(path_parts)
        if isinstance(node, PackedLinear):  # already-quantized params
            lead = int(np.prod(node.qweight.shape[:-2])) \
                if node.qweight.ndim > 2 else 1
            total += lead * packed_linear_nbytes(node.k, node.n,
                                                 node.group_size)
            if node.bias is not None:
                total += int(np.prod(node.bias.shape)) * 2
            return
        if _is_linear(node):
            w = node["w"]
            lead = int(np.prod(w.shape[:-2])) if w.ndim > 2 else 1
            k, n = w.shape[-2], w.shape[-1]
            if quantized and _quantizable(path, node, cfg, exclude):
                total += lead * packed_linear_nbytes(k, n, cfg.group_size)
            else:
                total += lead * k * n * 2
            if node.get("b") is not None:
                total += int(np.prod(node["b"].shape)) * 2
            return
        if isinstance(node, dict):
            for k2, v in node.items():
                visit(v, path_parts + [k2])
            return
        if hasattr(node, "shape"):
            total += int(np.prod(node.shape)) * 2

    visit(params, [])
    return total
