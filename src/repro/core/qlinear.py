"""Quantized-linear application — the runtime half of the paper's technique.

``qlinear_apply`` is the single dispatch point between:

  * ``ref``    — pure-jnp unpack → dequant → matmul. This is what the
                 multi-pod dry-run lowers (XLA sees the real int32 weight
                 stream, so `cost_analysis` reflects the ~3.56× weight-byte
                 reduction), and the oracle the Pallas kernel is tested
                 against.
  * ``kernel`` — the Pallas fused unpack+dequant+MAC kernel
                 (`repro.kernels`), the TPU analogue of the paper's
                 MACRO_MAC units. On CPU it runs in interpret mode (tests).

The hybrid execution strategy of the paper (§III: MACs on the FPGA fabric,
non-linear ops on the CPU) maps to: every quantized matmul goes through this
module (MXU pipeline), while RoPE/RMSNorm/SiLU stay as plain XLA ops on the
VPU. `ExecutionConfig.offload_min_flops` implements the paper's
"intelligently offloads compute-intensive operations" knob: matmuls below
the threshold stay on the generic path (for tiny decode GEMVs the kernel
launch overhead is not worth it on either platform).
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.packing import PackedLinear, dequantize_packed


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """Runtime knobs for the quantized path.

    Frozen/hashable on purpose: the config is read at TRACE time, so a
    compiled function bakes in whatever was active when it was traced.
    Callers that jit must therefore treat the config as part of the
    compilation key — `serving.GenerationEngine` keys every compiled
    dispatch on the active config (so `set_execution_config` takes
    effect on the next step, triggering a retrace), and one-off callers
    can pass ``cfg=`` to `qlinear_apply` explicitly.
    """

    impl: str = "auto"              # "auto" | "ref" | "kernel" | "kernel_interpret"
    compute_dtype: jnp.dtype = jnp.bfloat16
    offload_min_flops: float = 2 ** 20  # hybrid threshold (paper §III)


_EXEC = ExecutionConfig()


def set_execution_config(**kw) -> ExecutionConfig:
    global _EXEC
    _EXEC = dataclasses.replace(_EXEC, **kw)
    return _EXEC


def get_execution_config() -> ExecutionConfig:
    return _EXEC


@contextlib.contextmanager
def execution_config(cfg: ExecutionConfig):
    """Pin the ambient execution config for the duration of the block.

    Trace-scoped: wrap the *tracing* of a jitted function so every
    `qlinear_apply` inside it sees ``cfg`` instead of the mutable global
    (which a finished trace would otherwise have captured silently).
    """
    global _EXEC
    prev, _EXEC = _EXEC, cfg
    try:
        yield cfg
    finally:
        _EXEC = prev


def _resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    platform = jax.default_backend()
    return "kernel" if platform == "tpu" else "ref"


def qlinear_apply(p: PackedLinear, x: jax.Array,
                  impl: str | None = None,
                  cfg: ExecutionConfig | None = None) -> jax.Array:
    """``y = (x * input_scale) @ dequant(qweight) + bias``.

    ``x``: [..., K]; returns [..., N] in x.dtype. ``cfg`` defaults to the
    ambient config (see `execution_config` for the trace-time contract).
    """
    cfg = cfg if cfg is not None else _EXEC
    impl = _resolve_impl(impl or cfg.impl)
    orig_dtype = x.dtype
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)

    # AWQ inverse activation scale (explicit form; foldable into the
    # producing norm — see core/awq.fold_into_norm).
    x2 = (x2.astype(jnp.float32) * p.input_scale[None, :]).astype(
        cfg.compute_dtype)

    m = x2.shape[0]
    flops = 2.0 * m * k * p.n
    if impl == "kernel" and flops < cfg.offload_min_flops:
        impl = "ref"  # hybrid threshold: tiny GEMV stays on the generic path

    if impl in ("kernel", "kernel_interpret"):
        from repro.kernels import ops as kops  # lazy: avoid circular import
        y = kops.awq_matmul(x2, p, compute_dtype=cfg.compute_dtype,
                            interpret=(impl == "kernel_interpret"))
    else:
        w = dequantize_packed(p, cfg.compute_dtype)
        y = jnp.dot(x2, w, preferred_element_type=jnp.float32)

    y = y.astype(orig_dtype)
    if p.bias is not None:
        y = y + p.bias.astype(orig_dtype)
    return y.reshape(*lead, p.n)
