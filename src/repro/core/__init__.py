from repro.core.awq import AWQConfig, search_awq_scale  # noqa: F401
from repro.core.calibration import CalibrationCapture  # noqa: F401
from repro.core.packing import PackedLinear, pack_int4, unpack_int4  # noqa: F401
from repro.core.pipeline import quantize_params, model_size_bytes  # noqa: F401
from repro.core.qlinear import (ExecutionConfig, execution_config,  # noqa: F401
                                get_execution_config, qlinear_apply,
                                set_execution_config)
from repro.core.quantize import QuantConfig, quantize_groupwise  # noqa: F401
