"""Activation-aware Weight Quantization (AWQ) — the paper's software layer.

AWQ (Lin et al., MLSys'24; used directly by the reproduced paper, §III-A)
observes that ~1% of weight channels are *salient* as measured by the
magnitude of the **activations** that multiply them, not by the weight values
themselves. Instead of keeping those channels in FP16 (hardware-unfriendly,
Fig. 2a of the paper), AWQ applies a per-input-channel scale ``s`` before
round-to-nearest group quantization:

    W'[k, n] = W[k, n] * s[k]          (weights scaled UP on salient channels)
    x'[k]    = x[k] / s[k]             (activations scaled DOWN, foldable)

so that the effective quantization error on salient channels shrinks. The
scale is searched per linear layer over a 1-parameter family

    s = act_mean ** alpha / max(act_mean ** alpha)   (normalized),
    alpha ∈ [0, 1] on a small grid,

minimizing ``|| X @ W − (X / s) @ Q(W · s) ||²`` on calibration activations
— exactly the AutoAWQ search the paper runs (they additionally pick
group_size=64 over the default 128 based on WNLI accuracy).

Hardware note (DESIGN.md §2): the paper folds ``1/s`` into the preceding
operation on the CPU side. We instead keep an explicit ``input_scale`` vector
on the quantized linear and apply ``x * inv_s`` at runtime — an O(K) VPU
multiply that XLA fuses into the surrounding elementwise chain. A fold into
the preceding RMSNorm gamma is available (``fold_into_norm``) and is used by
the serving path when the producer is a norm; the numerics are identical.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantConfig, fake_quantize


@dataclasses.dataclass(frozen=True)
class AWQConfig:
    """Search hyper-parameters for the activation-aware scale search."""

    quant: QuantConfig = QuantConfig()
    n_grid: int = 20          # alpha grid resolution (AutoAWQ default)
    max_calib_rows: int = 512  # activation rows kept per linear for the search
    duo_scaling: bool = True   # also weigh by 1/w_max like AutoAWQ's v2 search
    eps: float = 1e-4


def activation_scale_candidates(act_mean: jax.Array,
                                w: jax.Array,
                                cfg: AWQConfig) -> jax.Array:
    """All candidate per-channel scales ``[n_grid, K]`` for the alpha grid.

    ``act_mean`` is mean(|x|) per input channel, shape [K]; ``w`` is [K, N].
    """
    act = jnp.clip(act_mean.astype(jnp.float32), cfg.eps, None)
    w_max = jnp.clip(jnp.max(jnp.abs(w), axis=1).astype(jnp.float32), cfg.eps,
                     None)  # [K]
    alphas = jnp.arange(cfg.n_grid, dtype=jnp.float32) / cfg.n_grid

    def one(alpha):
        if cfg.duo_scaling:
            s = act ** alpha / (w_max ** (1.0 - alpha) + cfg.eps)
        else:
            s = act ** alpha
        s = s / jnp.sqrt(jnp.max(s) * jnp.min(s) + cfg.eps)  # normalize range
        return jnp.clip(s, cfg.eps, None)

    return jax.vmap(one)(alphas)  # [n_grid, K]


def _search_loss(x: jax.Array, w: jax.Array, s: jax.Array,
                 qcfg: QuantConfig) -> jax.Array:
    """Reconstruction MSE of the scaled-quantized layer on calibration rows."""
    w_scaled = w * s[:, None]
    w_q = fake_quantize(w_scaled, qcfg)
    y_ref = x @ w
    y_q = (x / s[None, :]) @ w_q
    return jnp.mean((y_ref - y_q) ** 2)


def search_awq_scale(x_sample: jax.Array, w: jax.Array,
                     cfg: AWQConfig) -> tuple[jax.Array, jax.Array]:
    """Grid-search the activation-aware scale for one linear.

    Args:
      x_sample: calibration activations [rows, K] (float32).
      w:        weight [K, N].
    Returns:
      (best_scale [K] float32, best_loss scalar).
    """
    x = x_sample.astype(jnp.float32)
    if x.shape[0] > cfg.max_calib_rows:
        x = x[: cfg.max_calib_rows]
    act_mean = jnp.mean(jnp.abs(x), axis=0)
    cands = activation_scale_candidates(act_mean, w, cfg)  # [G, K]
    losses = jax.vmap(lambda s: _search_loss(x, w.astype(jnp.float32), s,
                                             cfg.quant))(cands)
    best = jnp.argmin(losses)
    return cands[best], losses[best]


def search_awq_scale_shared(x_samples: Sequence[jax.Array],
                            ws: Sequence[jax.Array],
                            cfg: AWQConfig) -> jax.Array:
    """One shared scale for several linears fed by the same activation.

    AWQ applies a single scale per *producer* (e.g. one scale shared by the
    q/k/v projections that all read the post-norm hidden state), because the
    inverse scale is folded once into that producer. Loss = sum over
    consumers.
    """
    x = x_samples[0].astype(jnp.float32)
    if x.shape[0] > cfg.max_calib_rows:
        x = x[: cfg.max_calib_rows]
    act_mean = jnp.mean(jnp.abs(x), axis=0)
    w_cat = jnp.concatenate([w.astype(jnp.float32) for w in ws], axis=1)
    cands = activation_scale_candidates(act_mean, w_cat, cfg)
    losses = jax.vmap(lambda s: _search_loss(x, w_cat, s, cfg.quant))(cands)
    return cands[jnp.argmin(losses)]


def fold_into_norm(norm_gamma: jax.Array, inv_s: jax.Array) -> jax.Array:
    """Fold the activation inverse-scale into a preceding (RMS/Layer)Norm.

    ``norm(x) * gamma`` feeding ``linear`` becomes ``norm(x) * (gamma*inv_s)``
    — zero runtime cost, numerically identical to the explicit multiply.
    """
    return norm_gamma * inv_s.astype(norm_gamma.dtype)
