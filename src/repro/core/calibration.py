"""Calibration capture for AWQ — records per-linear input activations.

AWQ needs, per quantized linear, the mean |x| per input channel plus a small
sample of activation rows (to evaluate the reconstruction loss of each
candidate scale). The paper runs AutoAWQ offline with a calibration set; here
the capture is a context manager that model code consults on every linear:

    with CalibrationCapture() as cap:
        model.apply(params, calib_tokens)      # un-jitted, eager
    stats = cap.stats                          # {linear_name: LinearStats}

Capture only works **eagerly** (outside jit/scan) because it stores concrete
values; `transformer.apply` therefore switches its scan-over-layers to a
python loop whenever `capture_active()` — calibration batches are small, so
the eager pass is cheap. Names are '@i'-suffixed for scan-stacked layers so
the PTQ pipeline can address per-layer statistics inside a stacked param.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_ACTIVE: "CalibrationCapture | None" = None


@dataclasses.dataclass
class LinearStats:
    """Running activation statistics for one linear layer."""

    sum_abs: np.ndarray   # [K] running sum of |x|
    count: int            # rows accumulated
    rows: np.ndarray      # [<=max_rows, K] sampled activation rows

    @property
    def act_mean(self) -> np.ndarray:
        return self.sum_abs / max(self.count, 1)


class CalibrationCapture:
    def __init__(self, max_rows: int = 512):
        self.max_rows = max_rows
        self.stats: dict[str, LinearStats] = {}

    def record(self, name: str, x) -> None:
        x = np.asarray(x, dtype=np.float32).reshape(-1, x.shape[-1])
        st = self.stats.get(name)
        if st is None:
            st = LinearStats(sum_abs=np.zeros(x.shape[-1], np.float32),
                             count=0, rows=x[: self.max_rows].copy())
            self.stats[name] = st
        else:
            room = self.max_rows - st.rows.shape[0]
            if room > 0:
                st.rows = np.concatenate([st.rows, x[:room]], axis=0)
        st.sum_abs += np.abs(x).sum(axis=0)
        st.count += x.shape[0]

    def __enter__(self):
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("nested CalibrationCapture not supported")
        _ACTIVE = self
        return self

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = None
        return False


def capture_active() -> bool:
    return _ACTIVE is not None


def record_linear_input(name: str | None, x) -> None:
    """Called by ``layers.linear`` on every application (no-op when idle)."""
    if _ACTIVE is not None and name is not None:
        _ACTIVE.record(name, x)
