"""Weight packing — the paper's ``AWQ_MACRO`` layout, adapted for TPU.

Two layouts live here on purpose (DESIGN.md §2):

1. **TPU compute layout** (`pack_int4`/`unpack_int4`): qweights are packed 8
   consecutive K-rows per int32 word → tensor ``[K//8, N] int32``; scales and
   zeros stay as lane-aligned ``[K//GS, N]`` tensors. One VMEM block of the
   Pallas kernel carries whole dequant groups (block_k % GS == 0), which is
   the TPU analogue of the paper's bandwidth-aligned 128-bit AXI strips: the
   dequant metadata always travels with the weights it dequantizes, enabling
   on-the-fly dequantization inside the MAC pipeline.

2. **Byte-exact ``AWQ_MACRO`` serialization** (`awq_macro_bytes` et al.): the
   paper's Fig. 3 block — GS×8 INT4 qweights + 8 FP16 scales + a 128-bit
   zeros strip (8×INT4 used, 96 bits zero padding). This is the layout the
   55.1 % compression claim is measured against, so the compression benchmark
   serializes through it byte-for-byte.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantConfig

PACK = 8  # int4 values per int32 word


# ---------------------------------------------------------------------------
# TPU compute layout
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack uint4-coded ``[K, N] int32`` → ``[K//8, N] int32``.

    Nibble ``j`` of word ``w`` holds row ``w*8 + j`` (little-endian nibbles),
    mirroring the paper's unpack unit which shifts+masks 8 INT4 chunks out of
    each 32-bit word (Fig. 4b).
    """
    k, n = q.shape
    if k % PACK != 0:
        raise ValueError(f"K={k} not divisible by {PACK}")
    qq = q.astype(jnp.uint32).reshape(k // PACK, PACK, n)
    shifts = (4 * jnp.arange(PACK, dtype=jnp.uint32))[None, :, None]
    word = jnp.sum(qq << shifts, axis=1, dtype=jnp.uint32)
    return word.astype(jnp.int32)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` → ``[K, N] int32`` in [0, 15]."""
    kp, n = packed.shape
    w = packed.astype(jnp.uint32)[:, None, :]  # [K//8, 1, N]
    shifts = (4 * jnp.arange(PACK, dtype=jnp.uint32))[None, :, None]
    nib = (w >> shifts) & jnp.uint32(0xF)
    return nib.reshape(kp * PACK, n).astype(jnp.int32)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedLinear:
    """A quantized linear layer's on-device tensors (TPU layout).

    Attributes:
      qweight:     [K//8, N] int32 — packed uint4 codes.
      scales:      [K//GS, N] float (bf16/f32) — per-(group, out-chan) scale.
      zeros:       [K//GS, N] int8 — asymmetric zero-points (uint4 codes).
      input_scale: [K] float32 — AWQ inverse activation scale (x * input_scale
                   before the matmul); ones when folded into the producer.
      bias:        [N] or None.
      group_size:  static.
    """

    qweight: jax.Array
    scales: jax.Array
    zeros: jax.Array
    input_scale: jax.Array
    bias: jax.Array | None
    group_size: int

    @property
    def k(self) -> int:
        return self.qweight.shape[-2] * PACK  # last-2 dims: leading = layers

    @property
    def n(self) -> int:
        return self.qweight.shape[-1]

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ([(ga("qweight"), self.qweight), (ga("scales"), self.scales),
                 (ga("zeros"), self.zeros),
                 (ga("input_scale"), self.input_scale),
                 (ga("bias"), self.bias)], self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, group_size=aux)


def pack_linear(q: jax.Array, scales: jax.Array, zeros: jax.Array,
                input_scale: jax.Array | None, bias: jax.Array | None,
                cfg: QuantConfig) -> PackedLinear:
    k = q.shape[0]
    if input_scale is None:
        input_scale = jnp.ones((k,), jnp.float32)
    return PackedLinear(
        qweight=pack_int4(q),
        scales=scales.astype(jnp.float32),
        zeros=zeros.astype(jnp.int8),
        input_scale=input_scale.astype(jnp.float32),
        bias=bias,
        group_size=cfg.group_size,
    )


def dequantize_packed(p: PackedLinear,
                      dtype=jnp.float32) -> jax.Array:
    """Materialize the float weight ``[K, N]`` (reference path only)."""
    q = unpack_int4(p.qweight)
    g = p.k // p.group_size
    qg = q.reshape(g, p.group_size, p.n).astype(jnp.float32)
    w = (qg - p.zeros[:, None, :].astype(jnp.float32)) * \
        p.scales[:, None, :].astype(jnp.float32)
    return w.reshape(p.k, p.n).astype(dtype)


# ---------------------------------------------------------------------------
# Byte-exact AWQ_MACRO serialization (paper Fig. 3) — compression benchmark
# ---------------------------------------------------------------------------

def awq_macro_nbytes(group_size: int) -> int:
    """Bytes of one AWQ_MACRO covering GS×8 weights.

    qweights: GS*8 nibbles = GS*4 bytes; scales: 8×FP16 = 16 B; zeros strip:
    128 bits = 16 B (8×INT4 used + 96 bits padding, per §III-A).
    """
    return group_size * 4 + 16 + 16


def macro_count(k: int, n: int, group_size: int) -> int:
    """#macros for a [K, N] linear: one per (K-group, 8 output channels)."""
    if k % group_size or n % 8:
        raise ValueError(f"[{k},{n}] not tileable by GS={group_size}x8")
    return (k // group_size) * (n // 8)


def packed_linear_nbytes(k: int, n: int, group_size: int) -> int:
    """Exact serialized size of one quantized linear in AWQ_MACRO format."""
    return macro_count(k, n, group_size) * awq_macro_nbytes(group_size)


def awq_macro_bytes(q: np.ndarray, scales: np.ndarray, zeros: np.ndarray,
                    group_size: int) -> bytes:
    """Serialize a whole [K, N] quantized linear into AWQ_MACRO strips.

    Layout per macro (paper Fig. 3, one macro = GS rows × 8 output channels):
      [GS*8 nibbles qweights][8 × fp16 scales][8 nibbles zeros + 96-bit pad]
    Nibble order within the qweight strip is row-major over (GS, 8) with
    little-endian nibble packing inside each byte.
    """
    k, n = q.shape
    g = k // group_size
    out = bytearray()
    q = q.astype(np.uint8)
    zeros = zeros.astype(np.uint8)
    scales16 = scales.astype(np.float16)
    for gi in range(g):
        rows = slice(gi * group_size, (gi + 1) * group_size)
        for nj in range(0, n, 8):
            tile = q[rows, nj:nj + 8].reshape(-1)          # GS*8 nibbles
            lo, hi = tile[0::2], tile[1::2]
            out += (lo | (hi << 4)).astype(np.uint8).tobytes()
            out += scales16[gi, nj:nj + 8].tobytes()        # 16 B
            ztile = zeros[gi, nj:nj + 8]
            zlo, zhi = ztile[0::2], ztile[1::2]
            out += (zlo | (zhi << 4)).astype(np.uint8).tobytes()  # 4 B used
            out += b"\x00" * 12                             # 96-bit padding
    return bytes(out)


def parse_awq_macro_bytes(buf: bytes, k: int, n: int, group_size: int):
    """Inverse of :func:`awq_macro_bytes` (round-trip tested)."""
    g = k // group_size
    q = np.zeros((k, n), np.uint8)
    scales = np.zeros((g, n), np.float16)
    zeros = np.zeros((g, n), np.uint8)
    mb = awq_macro_nbytes(group_size)
    idx = 0
    for gi in range(g):
        rows = slice(gi * group_size, (gi + 1) * group_size)
        for nj in range(0, n, 8):
            macro = buf[idx * mb:(idx + 1) * mb]
            idx += 1
            qb = np.frombuffer(macro[: group_size * 4], np.uint8)
            nib = np.empty(group_size * 8, np.uint8)
            nib[0::2] = qb & 0xF
            nib[1::2] = qb >> 4
            q[rows, nj:nj + 8] = nib.reshape(group_size, 8)
            scales[gi, nj:nj + 8] = np.frombuffer(
                macro[group_size * 4: group_size * 4 + 16], np.float16)
            zb = np.frombuffer(
                macro[group_size * 4 + 16: group_size * 4 + 20], np.uint8)
            znib = np.empty(8, np.uint8)
            znib[0::2] = zb & 0xF
            znib[1::2] = zb >> 4
            zeros[gi, nj:nj + 8] = znib
    return q, scales, zeros
