"""Group-wise asymmetric INT4 quantization primitives (AWQ numerics).

The paper quantizes every linear weight matrix of Qwen2.5-0.5B to INT4 with
asymmetric zero-points and a group size of 64 along the input-channel (K) axis
(Section III-A: "the packing process is performed with a GS of 64").

Weight convention throughout the framework: ``W`` has shape ``[K, N]``
(input-channels, output-channels) and a linear layer computes ``y = x @ W``.
Quantization groups are contiguous runs of ``group_size`` rows (K axis), one
(scale, zero) pair per (group, output-channel) — i.e. scales/zeros have shape
``[K // group_size, N]``. This matches AWQ/AutoAWQ semantics where scales are
per-(group, out-feature).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

INT4_MIN = 0
INT4_MAX = 15  # asymmetric uint4 representation, like AutoAWQ


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration for weight-only group quantization.

    Attributes:
      bits: quantization bit-width (paper uses 4).
      group_size: rows of W sharing one (scale, zero) pair. Paper uses 64
        ("higher accuracy score ... with the WNLI benchmark other than a GS of
        128"); AWQ's default is 128.
      sym: symmetric (zero fixed at mid-point) vs asymmetric (paper/AutoAWQ).
      compute_dtype: dtype weights are dequantized to inside the matmul
        pipeline. The paper uses FP32 because the KV260 fabric has no FP16
        units; on TPU bf16 feeds the MXU natively (see DESIGN.md §2).
    """

    bits: int = 4
    group_size: int = 64
    sym: bool = False
    compute_dtype: jnp.dtype = jnp.float32

    @property
    def qmax(self) -> int:
        return (1 << self.bits) - 1

    def validate_k(self, k: int) -> None:
        if k % self.group_size != 0:
            raise ValueError(
                f"K={k} must be divisible by group_size={self.group_size}")


def quantize_groupwise(w: jax.Array, cfg: QuantConfig):
    """Quantize ``w [K, N]`` to (q, scales, zeros).

    Returns:
      q:      uint-coded weights in int32, shape [K, N], values in [0, 2^bits-1]
      scales: [K // GS, N] float32
      zeros:  [K // GS, N] int32 (asymmetric zero-points, same coding as q)
    """
    k, n = w.shape
    cfg.validate_k(k)
    g = k // cfg.group_size
    wg = w.reshape(g, cfg.group_size, n).astype(jnp.float32)

    if cfg.sym:
        amax = jnp.max(jnp.abs(wg), axis=1)  # [G, N]
        qhalf = cfg.qmax // 2
        scales = amax / qhalf
        scales = jnp.where(scales == 0, 1.0, scales)
        zeros = jnp.full((g, n), qhalf + 1, dtype=jnp.int32)
        q = jnp.round(wg / scales[:, None, :]) + (qhalf + 1)
    else:
        wmax = jnp.max(wg, axis=1)
        wmin = jnp.min(wg, axis=1)
        scales = (wmax - wmin) / cfg.qmax
        scales = jnp.where(scales == 0, 1.0, scales)
        zeros = jnp.clip(jnp.round(-wmin / scales), 0, cfg.qmax).astype(jnp.int32)
        q = jnp.round(wg / scales[:, None, :]) + zeros[:, None, :]

    q = jnp.clip(q, 0, cfg.qmax).astype(jnp.int32)
    return q.reshape(k, n), scales, zeros


def dequantize_groupwise(q: jax.Array, scales: jax.Array, zeros: jax.Array,
                         cfg: QuantConfig) -> jax.Array:
    """Inverse of :func:`quantize_groupwise` → float ``[K, N]``.

    Mirrors the PE-element dataflow of the paper's accelerator (Fig. 4d):
    ``w = (q - zero) * scale``.
    """
    k, n = q.shape
    g = k // cfg.group_size
    qg = q.reshape(g, cfg.group_size, n).astype(jnp.float32)
    w = (qg - zeros[:, None, :].astype(jnp.float32)) * scales[:, None, :]
    return w.reshape(k, n).astype(cfg.compute_dtype)


def fake_quantize(w: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Quantize-dequantize roundtrip (the operator AWQ's search minimizes)."""
    q, s, z = quantize_groupwise(w, cfg)
    return dequantize_groupwise(q, s, z, cfg).astype(w.dtype)


def quantization_mse(w: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Mean squared quantization error of plain round-to-nearest."""
    return jnp.mean((fake_quantize(w, cfg) - w) ** 2)


@partial(jax.jit, static_argnames=("bits", "group_size", "sym"))
def _fake_quantize_jit(w, *, bits, group_size, sym):
    cfg = QuantConfig(bits=bits, group_size=group_size, sym=sym)
    return fake_quantize(w, cfg)


def fake_quantize_fast(w: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Jitted fake-quant used inside the AWQ grid search."""
    return _fake_quantize_jit(w, bits=cfg.bits, group_size=cfg.group_size,
                              sym=cfg.sym)
