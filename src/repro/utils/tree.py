"""Path-aware pytree helpers used across the framework.

Params everywhere are nested dicts of ``jnp.ndarray`` (the pure-JAX module
convention, DESIGN.md §7). Sharding rules, quantization pipelines and
checkpoint schemas all address leaves by their '/'-joined dict path, so the
helpers here are the single place that defines that addressing.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def path_str(path) -> str:
    """'/'-joined string for a jax key-path."""
    return "/".join(_key_str(k) for k in path)


def map_with_path(fn: Callable[[str, Any], Any], tree: Any, *rest: Any) -> Any:
    """``jax.tree_util.tree_map_with_path`` with string paths.

    ``fn(path, leaf, *other_leaves) -> new_leaf``.
    """
    return jax.tree_util.tree_map_with_path(
        lambda p, x, *r: fn(path_str(p), x, *r), tree, *rest)


def flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(path_str(p), v) for p, v in leaves]


def leaf_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def leaf_count(tree: Any) -> int:
    """Total number of scalar elements across all leaves."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape))
    return total
