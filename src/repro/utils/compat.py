"""Version-tolerant lookups for jax APIs that moved between releases.

The container pins one jax, but the repo is exercised against several
(CI, TPU pods, dev laptops); every rename we depend on gets resolved here
once instead of per call site:

  * Pallas-TPU compiler params: ``TPUCompilerParams`` → ``CompilerParams``
  * ``shard_map``: ``jax.experimental.shard_map`` → ``jax.shard_map``
    (handled in `repro.distributed.sharding.shard_map`, which also
    translates the ``check_rep`` → ``check_vma`` kwarg rename)
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# post-rename name first so new jax doesn't emit deprecation warnings
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
