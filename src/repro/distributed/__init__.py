from repro.distributed.sharding import (  # noqa: F401
    batch_axes, cache_pspec, constrain, current_mesh, make_sharding,
    param_pspec, pspec_tree, shard_map, use_mesh)
