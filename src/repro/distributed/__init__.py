from repro.distributed.sharding import (  # noqa: F401
    batch_axes, cache_pspec, constrain, current_mesh, make_sharding,
    paged_cache_pspec, param_pspec, pspec_tree, serving_mesh, shard_map,
    use_mesh)
