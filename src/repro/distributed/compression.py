"""Gradient compression: int8 all-reduce with error feedback (EF-SGD style).

Two tiers of gradient-communication reduction in this framework:

  1. **bf16 backward** (default, `TrainConfig.grad_comm_dtype`) — params are
     cast to bf16 before the loss, so the implicit DP all-reduce XLA emits
     moves bf16: 2× fewer bytes, zero code outside the train step.
  2. **int8 + error feedback** (this module) — 4× fewer bytes again, for
     the bandwidth-starved cross-pod (DCI) hop. Each worker quantizes its
     LOCAL gradient against a shared per-tensor scale and remembers the
     quantization residual (`ef`), which is added back before the next
     step's quantization — the classic error-feedback construction that
     keeps the *accumulated* update unbiased (Seide et al. 1-bit SGD;
     Karimireddy et al. EF-SGD).

The compressed reduction is an explicit `shard_map` collective
(`int8_psum_mean`): scale = psum-max/127 (one scalar per tensor), int8
codes psum'd in int32, mean in f32. `training/dp_compressed.py` wires it
into a data-parallel train step; tests prove loss parity with the f32
reduction on a multi-device mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ef(g: jax.Array, ef: jax.Array, scale: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Quantize (g + ef) to int8 at ``scale``; return (codes, new ef)."""
    x = g.astype(jnp.float32) + ef
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_ef = x - q.astype(jnp.float32) * scale
    return q, new_ef


def int8_psum_mean(g: jax.Array, ef: jax.Array, axis_names
                   ) -> tuple[jax.Array, jax.Array]:
    """Mean of ``g`` over mesh axes via int8 codes + error feedback.

    Must run inside `shard_map` (manual axes). Comm per tensor: one f32
    scalar (scale agreement) + n int8 codes — 4× less than bf16, 8× less
    than f32.
    """
    x = g.astype(jnp.float32) + ef
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_names)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q, new_ef = quantize_ef(g, ef, scale)
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        n *= jax.lax.psum(1, a)
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    return total.astype(jnp.float32) * scale / n, new_ef


def init_ef(grads_like) -> dict:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
