"""Sharding rules: logical axes → the (pod, data, model) production mesh.

Design (DESIGN.md §5):
  * DP spans ``pod × data`` (the pod axis only ever carries gradient
    all-reduce in training; serving treats pods as independent replicas).
  * TP spans ``model``: column-parallel QKV/gate/up, row-parallel O/down,
    vocab-parallel embedding/lm_head, expert-FFN dim for MoE.
  * Decode KV caches are sequence-sharded over ``model`` (SP-decode): at
    decode_32k/long_500k batch sizes the cache, not the weights, dominates
    per-chip HBM, and sequence sharding keeps softmax/attention communication
    to three tiny all-reduces per layer.

Every rule checks divisibility against the actual mesh axis sizes and falls
back to replication — head counts like hymba's 25 or vocabs like 32001 are
not forced onto a 16-way axis (the fallback is recorded by the dry-run's
memory analysis, not hidden).

Quantized params: a `PackedLinear`'s qweight [K/8, N], scales/zeros [K/GS, N]
and input_scale [K] inherit the parent linear's K/N sharding, so the packed
INT4 stream shards exactly like the float weight it replaces (the paper's
AWQ_MACRO blocks stay intact per device because every shard keeps whole
quant groups: K/8 and K/GS divide evenly whenever K does).
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    """Activate a mesh for `constrain` calls inside model code."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev


def current_mesh() -> Mesh | None:
    return _MESH


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that jointly carry the batch (DP) dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# Logical activation axes → mesh axes. Several logical names map to the same
# mesh axis ("model"); `_resolve` allocates greedily in dimension order and
# never assigns one mesh axis twice.
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_groups": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "cache_seq": ("model",),
    "seq": ("model",),       # sequence parallelism (long-context prefill)
    "model": ("model",),
    "expert_cap": ("pod", "data"),
    "ssm_inner": ("model",),
}


def _resolve(mesh: Mesh, logical: tuple, shape: tuple[int, ...]) -> P:
    """Map logical axes → PartitionSpec.

    Drops axes that are absent from the mesh, don't divide the dimension, or
    were already assigned to an earlier dimension (first match wins).
    """
    out = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        axes = LOGICAL_RULES.get(name, (name,))
        axes = tuple(a for a in axes
                     if a in mesh.axis_names and a not in used)
        if axes and dim % _axis_size(mesh, axes) == 0:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, logical: tuple) -> jax.Array:
    """`with_sharding_constraint` by logical axis names (no-op without mesh)."""
    mesh = _MESH
    if mesh is None:
        return x
    spec = _resolve(mesh, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-based)
# ---------------------------------------------------------------------------

# Column-parallel (shard output/N dim) vs row-parallel (shard input/K dim).
_COL_LINEARS = ("wq", "wk", "wv", "gate", "up", "wz", "wx", "wb", "wc",
                "wdt", "q_proj", "kv_down", "kv_up", "patch_proj",
                "frame_proj")
_ROW_LINEARS = ("wo", "down", "out_proj")


def _linear_axes(parent: str, k: int, n: int, mesh: Mesh, cfg=None
                 ) -> tuple[str | None, str | None]:
    """(K-axis, N-axis) logical sharding for a linear named ``parent``."""
    msize = mesh.shape.get("model", 1)
    if parent in _ROW_LINEARS:
        return ("model" if k % msize == 0 else None), None
    if parent in _COL_LINEARS:
        # Attention projections only shard if whole heads land per device —
        # otherwise replicate (divisibility rule; see module docstring).
        if cfg is not None and parent in ("wq", "wk", "wv"):
            heads = cfg.num_heads if parent == "wq" else cfg.num_kv_heads
            if heads % msize != 0:
                return None, None
        return None, ("model" if n % msize == 0 else None)
    return None, None


def param_pspec(path: str, leaf: Any, mesh: Mesh, cfg=None) -> P:
    """PartitionSpec for one param leaf addressed by its tree path.

    Handles float linears (``.../<name>/w``), PackedLinear leaves
    (``.../<name>/qweight`` etc.), embeddings, norms and stacked leading
    layer dims (spec is right-aligned; leading dims unsharded).
    """
    shape = tuple(leaf.shape)
    parts = path.split("/")
    leafname = parts[-1]
    parent = parts[-2] if len(parts) >= 2 else ""
    msize = mesh.shape.get("model", 1)

    def pad(spec_tail: list, ndim_tail: int) -> P:
        return P(*([None] * (len(shape) - ndim_tail) + spec_tail))

    if "embed" in path and leafname == "table":
        v, d = shape[-2], shape[-1]
        if v % msize == 0:
            return pad(["model", None], 2)
        if d % msize == 0:
            return pad([None, "model"], 2)
        return P(*([None] * len(shape)))

    if parent == "lm_head" and leafname == "w":
        d, v = shape[-2], shape[-1]
        return pad([None, "model" if v % msize == 0 else None], 2)

    if parent == "experts" or (len(parts) >= 3 and parts[-3] == "experts"):
        # experts/<gate|up|down>/w with shape [..., E, K, N]
        name = parent if leafname == "w" else parts[-2]
        if leafname in ("w", "qweight", "scales", "zeros"):
            if name in ("gate", "up"):
                ax = "model" if shape[-1] % msize == 0 else None
                return pad([None, None, ax], 3)
            if name == "down":
                if leafname == "w":  # float (training): row-parallel on F
                    ax = "model" if shape[-2] % msize == 0 else None
                    return pad([None, ax, None], 3)
                # packed (serving): F-sharding would split quant groups
                # (F/|model| rarely a GS multiple) — shard the OUTPUT dim
                # instead; dequant then stays shard-local (§Perf B4).
                ax = "model" if shape[-1] % msize == 0 else None
                return pad([None, None, ax], 3)
        if leafname == "input_scale":
            # replicated: applied to the (gathered) full-K activations
            return P(*([None] * len(shape)))
        return P(*([None] * len(shape)))

    if leafname in ("w", "qweight", "scales", "zeros") and len(shape) >= 2:
        k_ax, n_ax = _linear_axes(parent, shape[-2], shape[-1], mesh, cfg)
        if leafname != "w" and k_ax is not None:
            # Quantized row-parallel linear: each K-shard must hold WHOLE
            # dequant groups (the AWQ_MACRO invariant), or the group-reshape
            # un-shards the weight and XLA gathers it every step (§Perf A2).
            # rows → K: qweight packs PACK/row, scales/zeros are per-group.
            # The group size comes from the quant config (cfg override or
            # the pipeline default), not a magic literal.
            from repro.core.packing import PACK
            from repro.core.quantize import QuantConfig
            gs = (getattr(cfg, "quant_group_size", None)
                  or QuantConfig().group_size)
            k_full = shape[-2] * (PACK if leafname == "qweight" else gs)
            if (k_full // msize) % gs != 0:
                # flip to column-parallel (tiny output all-gather instead)
                k_ax = None
                n_ax = "model" if shape[-1] % msize == 0 else None
        if k_ax and shape[-2] % msize != 0:
            k_ax = None
        return pad([k_ax, n_ax], 2)

    if leafname == "input_scale":
        k_ax, _ = _linear_axes(parent, shape[-1], 0, mesh, cfg)
        return pad([k_ax if shape[-1] % msize == 0 else None], 1)

    if leafname == "b" and len(parts) >= 2:
        _, n_ax = _linear_axes(parent, 0, shape[-1], mesh, cfg)
        return pad([n_ax if shape[-1] % msize == 0 else None], 1)

    return P(*([None] * len(shape)))  # norms, scalars, conv, A_log, ...


def zero1_pspec(pspec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: additionally shard optimizer moments over the data axis.

    Picks the first dimension that is unsharded and divisible by |data| —
    on top of whatever TP sharding the param already has.
    """
    dsize = mesh.shape.get("data", 1)
    if dsize == 1:
        return pspec
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (ax, dim) in enumerate(zip(spec, shape)):
        if ax is None and dim % dsize == 0 and dim >= dsize:
            spec[i] = "data"
            return P(*spec)
    return P(*spec)


# ---------------------------------------------------------------------------
# Decode-cache sharding
# ---------------------------------------------------------------------------

def cache_pspec(path: str, leaf: Any, mesh: Mesh, cfg=None) -> P:
    """Sharding for KV/SSM decode caches.

    Layout per leaf (leading dim may be a stacked segment-layer dim):
      k/v      [L, B, S, H, hd] → batch on B; S over model (SP-decode) when
               divisible, else heads.
      ckv/kpe  [L, B, S, R]     → batch on B, S over model (MLA latent).
      conv_*   [L, B, d_conv, C] → batch on B, channels over model.
      state    [L, B, nh, hd, ds]→ batch on B, heads over model if divisible.
    """
    shape = tuple(leaf.shape)
    parts = path.split("/")
    leafname = parts[-1]
    msize = mesh.shape.get("model", 1)
    b_ax = "batch"

    def full(tail: list) -> P:
        lead = [None] * (len(shape) - len(tail))
        mesh_ready = _resolve(mesh, tuple(lead + tail), shape)
        return mesh_ready

    if leafname in ("k", "v"):
        s_dim, h_dim = shape[-3], shape[-2]
        if s_dim % msize == 0 and s_dim >= 8 * msize:
            return full([b_ax, "model", None, None])
        if h_dim % msize == 0:
            return full([b_ax, None, "model", None])
        return full([b_ax, None, None, None])
    if leafname in ("ks", "vs"):  # int8 KV-cache scales [.., B, S, H]
        s_dim = shape[-2]
        if s_dim % msize == 0 and s_dim >= 8 * msize:
            return full([b_ax, "model", None])
        return full([b_ax, None, None])
    if leafname in ("ckv", "kpe"):
        s_dim = shape[-2]
        if s_dim % msize == 0 and s_dim >= 8 * msize:
            return full([b_ax, "model", None])
        return full([b_ax, None, None])
    if leafname.startswith("conv"):
        return full([b_ax, None, "model"])
    if leafname == "state":
        return full([b_ax, "model", None, None])
    # fallback: batch on the second-to-last... be conservative: batch on dim
    # right after the stacked layer dim if it matches the global batch.
    return full([b_ax] + [None] * (len(shape) - (len(shape) - 1)))


# ---------------------------------------------------------------------------
# Serving page-pool sharding (tensor-parallel paged KV)
# ---------------------------------------------------------------------------

def paged_cache_pspec(path: str, leaf: Any, mesh: Mesh, cfg=None) -> P:
    """Sharding for the serving engine's paged decode cache.

    Page pools shard over **KV heads** on the ``model`` axis — page IDs
    index the (replicated) leading ``num_pages`` dim, so the host-side
    pager's free list / refcounts / page tables stay device-agnostic and
    a physical page is simply striped across the mesh:

      k/v pools   [L, N, P, Hkv, hd] → heads over ``model``
      ks/vs strips[L, N, P, Hkv]     → heads over ``model``
      ring k/v    [L, B, W, Hkv, hd] → heads over ``model`` (same rule)

    Bounded per-slot state (SSM states, MLA latents, conv tails) is
    replicated — its footprint is small by construction. Head counts that
    don't divide the axis fall back to replication here, but the serving
    engine refuses such meshes up front (a clear construction-time error
    beats a silently-replicated pool).
    """
    shape = tuple(leaf.shape)
    leafname = path.split("/")[-1]
    msize = mesh.shape.get("model", 1)
    if leafname in ("k", "v") and len(shape) >= 2 and shape[-2] % msize == 0:
        return P(*([None] * (len(shape) - 2) + ["model", None]))
    if leafname in ("ks", "vs") and shape and shape[-1] % msize == 0:
        return P(*([None] * (len(shape) - 1) + ["model"]))
    return P(*([None] * len(shape)))


def spill_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for page strips crossing the device↔host spill tier.

    Preemption gathers an evicted slot's pages out of the (head-sharded)
    pools as ``[L, n_pages, P, ...]`` strips and parks their bytes in
    host memory; restore scatters them back into freshly drawn pages.
    The strips leave the mesh **replicated**: the gather's out-sharding
    performs the per-device head-shard collection in the same dispatch
    (one all-gather over ``model`` for the strip, not the pool), so the
    host tier holds one complete device-agnostic copy — int8 codes plus
    scale strips when the pool is quantized, i.e. the spilled bytes stay
    int8-recompressed. On restore the scatter's in-sharding re-stripes
    the replicated strip back over KV heads via `paged_cache_pspec`, so
    each device writes only its head shard. Page IDs (the gather/scatter
    index operand) use the same replicated sharding.
    """
    return NamedSharding(mesh, P())


def handoff_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for KV page strips crossing ENGINES (disaggregated
    prefill → decode handoff, `serving.disagg`).

    Identical to `spill_sharding` by construction, and that identity is
    the load-bearing property of cross-mesh disaggregation: because the
    gather's out-sharding leaves the strip **replicated** (the all-gather
    over ``model`` happens inside the prefill engine's dispatch), the
    wire image carries no trace of the prefill mesh. A decode engine on a
    *different* mesh — more chips, fewer chips, or no mesh at all — feeds
    the same strip to its scatter, whose in-sharding re-stripes it over
    the decode mesh's KV-head axis via `paged_cache_pspec`. The handoff
    is therefore a reshard-on-adopt: no per-mesh-pair transfer code, and
    host page IDs stay device-agnostic on both sides.
    """
    return spill_sharding(mesh)


def serving_mesh(model: int | None = None) -> Mesh:
    """A 1-D ``('model',)`` mesh over the first ``model`` local devices.

    The serving engine's tensor-parallel axis: weights column/row-shard
    through `param_pspec`, page pools shard over KV heads through
    `paged_cache_pspec`, and everything host-visible (page tables, token
    blocks, sampled tokens) stays replicated. ``model=None`` takes every
    local device; ``model=1`` is the degenerate mesh whose dispatches are
    identical to the unsharded path.
    """
    devices = jax.devices()
    n = len(devices) if model is None else model
    if n < 1 or n > len(devices):
        raise ValueError(f"serving_mesh(model={model}): have "
                         f"{len(devices)} devices")
    return Mesh(np.asarray(devices[:n]), ("model",))


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------

def pspec_tree(tree: Any, mesh: Mesh, rule, cfg=None) -> Any:
    """Map ``rule(path, leaf, mesh, cfg) -> PartitionSpec`` over a pytree."""
    from repro.utils.tree import map_with_path
    return map_with_path(lambda p, x: rule(p, x, mesh, cfg), tree)


def make_sharding(tree: Any, mesh: Mesh, rule, cfg=None) -> Any:
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        pspec_tree(tree, mesh, rule, cfg),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# shard_map compat
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """`jax.shard_map` on new jax, `jax.experimental.shard_map` on old.

    The replication-check kwarg was renamed `check_rep` → `check_vma` across
    the move; callers use the new name and we translate when falling back.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
