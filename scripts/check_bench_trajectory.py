#!/usr/bin/env python
"""Gate the serving bench trajectory across the run history.

Compares the two most recent *smoke* records in ``BENCH_serving.json``
(like-for-like: smoke and full runs have different workloads) and fails
when the newest run regresses against the previous one:

  * **throughput metrics** — every ``serving/*`` metric whose name ends
    in ``_tps`` or contains ``tokens_per_step`` must not drop by more
    than the tolerance (default 0.8, i.e. only a catastrophic >80 % drop
    fails — CI machines are noisy, and this gate exists to catch
    "the fast path silently stopped being used", not 10 % jitter).
    Override with ``--tolerance`` or ``BENCH_TRAJECTORY_TOLERANCE``.
  * **identity metrics** — any ``*token_identity*`` metric or
    ``identity_sections`` entry that was ``True`` in the previous record
    must still be ``True`` (and still be present): a True→False or
    True→missing flip is a hard fail at any tolerance, because it means
    an asserted equivalence was lost or silently stopped running.

Identity keys present in the newest record but absent from the previous
one are **new sections** (a PR added a gate), reported informationally
and never failed: the gate compares what both records know about, and
growth is not a regression.

After the pairwise gate the script prints a per-metric **throughput
trajectory table** across ALL stored smoke records (newest last), so a
slow multi-PR drift is visible even when every adjacent pair stayed
inside tolerance.

With fewer than two smoke records the gate warns and exits 0 — a fresh
clone (or a just-initialised history) must not be red. Each record is
stamped with its git commit and jax version by ``bench_serving.py``, so
a failure here names the commit pair that bracketed the regression.
"""
import argparse
import json
import os
import pathlib
import sys

TPS_HINTS = ("_tps",)
STEP_HINTS = ("tokens_per_step",)
IDENTITY_HINT = "token_identity"


def _numeric(value):
    """Parse the bench's stringly-typed metric values ("1151.7", "61.6%",
    "2.1x"); None when the value isn't a number."""
    s = str(value).strip().rstrip("%x")
    try:
        return float(s)
    except ValueError:
        return None


def _is_throughput(name):
    return (any(name.endswith(h) for h in TPS_HINTS)
            or any(h in name for h in STEP_HINTS))


def _stamp(rec):
    commit = str(rec.get("git_commit", "unknown"))[:12]
    return f"{commit} @ {rec.get('timestamp', 0):.0f}"


def compare(prev, last, tolerance):
    """Return a list of regression strings (empty = trajectory ok)."""
    bad = []
    pm, lm = prev.get("metrics", {}), last.get("metrics", {})
    for name, pval in sorted(pm.items()):
        if IDENTITY_HINT in name:
            if str(pval) == "True" and str(lm.get(name)) != "True":
                bad.append(f"identity lost: {name} "
                           f"{pval} -> {lm.get(name, '<missing>')}")
            continue
        if not _is_throughput(name):
            continue
        p, c = _numeric(pval), _numeric(lm.get(name))
        if p is None or p <= 0:
            continue
        if c is None:
            bad.append(f"throughput metric vanished: {name} (was {pval})")
        elif c < p * (1.0 - tolerance):
            bad.append(f"throughput collapsed: {name} {p:.1f} -> {c:.1f} "
                       f"({(1 - c / p) * 100:.0f}% drop > "
                       f"{tolerance * 100:.0f}% tolerance)")
    ps = prev.get("identity_sections", {})
    ls = last.get("identity_sections", {})
    for sec, val in sorted(ps.items()):
        if val is True and ls.get(sec) is not True:
            bad.append(f"identity section lost: {sec} "
                       f"True -> {ls.get(sec, '<missing>')}")
    return bad


def new_sections(prev, last):
    """Identity keys the newest record added (informational, never a
    failure): new gated sections and new ``*token_identity*`` metrics."""
    added = sorted(set(last.get("identity_sections", {}))
                   - set(prev.get("identity_sections", {})))
    added += sorted(k for k in last.get("metrics", {})
                    if IDENTITY_HINT in k
                    and k not in prev.get("metrics", {}))
    return added


def trajectory_table(records):
    """Per-metric throughput table across ALL smoke records, oldest to
    newest ('-' where a record predates the metric). Returns the printed
    lines so tests can assert on them."""
    names = sorted({n for r in records for n in r.get("metrics", {})
                    if _is_throughput(n)})
    if not names:
        return []
    heads = [str(r.get("git_commit", "unknown"))[:8] for r in records]
    width = max(len(n) for n in names)
    lines = ["TRAJECTORY-TABLE: throughput across "
             f"{len(records)} smoke record(s) (oldest -> newest)",
             "  " + " " * width + "  " + "  ".join(f"{h:>10}"
                                                   for h in heads)]
    for name in names:
        cells = []
        for r in records:
            v = _numeric(r.get("metrics", {}).get(name))
            cells.append("-" if v is None else f"{v:.1f}")
        lines.append(f"  {name:<{width}}  "
                     + "  ".join(f"{c:>10}" for c in cells))
    for ln in lines:
        print(ln)
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="compare the two most recent smoke bench records")
    ap.add_argument("--history-file", default=None,
                    help="run-history JSON (default: repo-root "
                         "BENCH_serving.json)")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="max fractional throughput drop (default 0.8, "
                         "env BENCH_TRAJECTORY_TOLERANCE)")
    args = ap.parse_args(argv)
    tol = args.tolerance
    if tol is None:
        tol = float(os.environ.get("BENCH_TRAJECTORY_TOLERANCE", "0.8"))
    if not 0.0 < tol < 1.0:
        print(f"TRAJECTORY: bad tolerance {tol} (need 0 < t < 1)")
        return 2
    path = pathlib.Path(args.history_file) if args.history_file else \
        pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_serving.json"
    try:
        history = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"TRAJECTORY: warn-only — history unreadable ({e})")
        return 0
    smoke = [r for r in history if isinstance(r, dict) and r.get("smoke")]
    if len(smoke) < 2:
        print(f"TRAJECTORY: warn-only — {len(smoke)} smoke record(s), "
              "need 2 to compare")
        return 0
    prev, last = smoke[-2], smoke[-1]
    bad = compare(prev, last, tol)
    tag = f"{_stamp(prev)} vs {_stamp(last)}"
    added = new_sections(prev, last)
    if added:
        print(f"TRAJECTORY: new identity section(s) in latest record "
              f"(informational): {', '.join(added)}")
    for b in bad:
        print(f"TRAJECTORY: {b}")
    trajectory_table(smoke)
    if bad:
        print(f"TRAJECTORY: FAILED ({len(bad)} regressions, {tag})")
        return 1
    n_tps = sum(1 for k in prev.get("metrics", {}) if _is_throughput(k))
    n_id = (sum(1 for k in prev.get("metrics", {}) if IDENTITY_HINT in k)
            + len(prev.get("identity_sections", {})))
    print(f"TRAJECTORY: ok ({n_tps} throughput + {n_id} identity metrics, "
          f"tolerance {tol:.0%}, {tag})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
