#!/usr/bin/env bash
# Tier-1 regression gate: per-file timeouts, JUnit XML, machine-checkable
# failure counts vs. the recorded baseline.
#
#   scripts/run_tier1.sh [results_dir]
#
# Gates, in order: docs-link checker, ruff lint (skipped with a notice if
# ruff is not installed), the serving benchmark's --smoke mode (chunked
# serving exercised end-to-end), the bench-trajectory checker (the fresh
# smoke record vs the previous one — throughput within tolerance,
# identities still True), then every tests/test_*.py in its own pytest
# process under a timeout (one hanging file must not sink the whole
# gate), writing per-file JUnit XML into results_dir (default
# results/tier1) and printing a summary line
#
#   TIER1 files=<n> passed=<p> failed=<f> errors=<e> skipped=<s> \
#       timeout=<t> doclinks=<d> lint=<l> bench=<b> traj=<j>
#
# and exits non-zero if failures+errors+timeouts exceed the baseline in
# scripts/tier1_baseline.txt (tracked in git — update it deliberately when
# the known-red set changes; override with TIER1_BASELINE_FILE).
set -u
cd "$(dirname "$0")/.."

RESULTS_DIR="${1:-results/tier1}"
PER_FILE_TIMEOUT="${TIER1_TIMEOUT:-600}"
BASELINE_FILE="${TIER1_BASELINE_FILE:-scripts/tier1_baseline.txt}"
mkdir -p "$RESULTS_DIR"
rm -f "$RESULTS_DIR"/*.xml "$RESULTS_DIR"/*.log   # never count a stale run
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# --- hypothesis profile: the property-based suites (the pager/scheduler
# state-machine harness in tests/test_pager_statemachine.py, plus the
# packing/quantize tests) select their settings via HYPOTHESIS_PROFILE.
# Default to the small derandomized "tier1" profile so local gate runs are
# fast and bit-reproducible; CI exports HYPOTHESIS_PROFILE=ci for the
# 500-example stateful run. No-op when hypothesis is not installed — the
# suites fall back to their seeded random-walk drivers.
export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-tier1}"
echo "HYPOTHESIS_PROFILE=$HYPOTHESIS_PROFILE"

# --- report the device count this gate runs with: the CI matrix runs the
# gate once on the single real device and once under
# XLA_FLAGS=--xla_force_host_platform_device_count=4 (exercising the
# mesh-sharded serving paths), and the log must say which one this was
python - <<'PY'
import jax
print(f"DEVICES: count={jax.device_count()} backend={jax.default_backend()}")
PY

# --- docs-link gate: every relative link in docs/*.md + README.md and every
# examples/ or benchmarks/ path referenced in docs must exist, so the docs
# cannot rot silently as the tree moves under them
python - <<'PY'
import os
import re
import sys

errors = []
doc_files = ["README.md"] if os.path.exists("README.md") else []
if os.path.isdir("docs"):
    doc_files += sorted(os.path.join("docs", f) for f in os.listdir("docs")
                        if f.endswith(".md"))
if not doc_files:
    print("DOCS-LINKS: no docs found")
    sys.exit(1)
for path in doc_files:
    base = os.path.dirname(path)
    text = open(path, encoding="utf-8").read()
    # markdown links, skipping absolute URLs and intra-page anchors
    for target in re.findall(r"\]\(([^)#][^)]*)\)", text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#")[0]
        if target and not os.path.exists(os.path.join(base, target)):
            errors.append(f"{path}: broken link -> {target}")
    # bare examples/ and benchmarks/ path mentions (inline code etc.)
    for target in set(re.findall(r"(?:examples|benchmarks|scripts)/"
                                 r"[\w./-]+\.(?:py|sh)", text)):
        if not os.path.exists(target):
            errors.append(f"{path}: missing path -> {target}")
for e in errors:
    print("DOCS-LINKS:", e)
print(f"DOCS-LINKS files={len(doc_files)} errors={len(errors)}")
sys.exit(1 if errors else 0)
PY
link_rc=$?

# --- lint gate: ruff (config in pyproject.toml — conservative rule set:
# syntax errors, undefined names, unused imports). The container may not
# ship ruff; skip with a notice rather than failing on a missing tool.
lint_rc=0
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples scripts
    lint_rc=$?
    echo "LINT: ruff check rc=$lint_rc"
elif python -c "import ruff" >/dev/null 2>&1; then
    python -m ruff check src tests benchmarks examples scripts
    lint_rc=$?
    echo "LINT: ruff check rc=$lint_rc"
else
    echo "LINT: ruff not installed — skipped"
fi

# --- serving smoke gate: exercise the chunked serving path end-to-end
# (engine + scheduler + pager + kernels fallback) through the benchmark's
# reduced mode; asserts token identity, prefix-FLOP accounting, and the
# multi-replica router section (registered identity key router_vs_single:
# a 1-replica fleet must stream byte-identical to the bare engine, and
# affinity placement must out-skip random on the clustered burst)
bench_rc=0
if timeout "${TIER1_BENCH_TIMEOUT:-600}" \
        python benchmarks/bench_serving.py --smoke \
        >"$RESULTS_DIR/bench_serving_smoke.log" 2>&1; then
    echo "BENCH-SMOKE: ok ($(grep -c '^serving/' \
        "$RESULTS_DIR/bench_serving_smoke.log") metrics)"
else
    bench_rc=1
    echo "BENCH-SMOKE: FAILED (see $RESULTS_DIR/bench_serving_smoke.log)"
    tail -5 "$RESULTS_DIR/bench_serving_smoke.log"
fi

# --- bench history gate: the smoke run must have appended a parseable,
# schema'd record to the tracked BENCH_serving.json run history
if [ "$bench_rc" -eq 0 ]; then
    python - <<'PY'
import json
import sys

try:
    hist = json.load(open("BENCH_serving.json"))
except Exception as e:  # missing or unparseable both gate red
    print(f"BENCH-HISTORY: unreadable ({e})")
    sys.exit(1)
if not (isinstance(hist, list) and hist):
    print("BENCH-HISTORY: empty or not a record list")
    sys.exit(1)
rec = hist[-1]
need = ("schema", "timestamp", "smoke", "metrics", "identity_sections",
        "awq", "git_commit", "jax_version", "replica_topology")
missing = [k for k in need if k not in rec]
if missing:
    print(f"BENCH-HISTORY: last record missing keys {missing}")
    sys.exit(1)
print(f"BENCH-HISTORY: ok ({len(hist)} records, "
      f"last smoke={rec['smoke']} schema={rec['schema']} "
      f"commit={str(rec['git_commit'])[:12]})")
PY
    bench_rc=$?
fi

# --- bench trajectory gate: the record the smoke run just appended must
# not collapse vs the previous smoke record — throughput metrics within
# tolerance, asserted identities still True. Warn-only (rc 0) when the
# history has fewer than two smoke records.
traj_rc=0
if [ "$bench_rc" -eq 0 ]; then
    python scripts/check_bench_trajectory.py
    traj_rc=$?
fi

timeouts=0
for f in tests/test_*.py; do
    name=$(basename "$f" .py)
    timeout "$PER_FILE_TIMEOUT" python -m pytest -q "$f" \
        --junitxml="$RESULTS_DIR/$name.xml" >"$RESULTS_DIR/$name.log" 2>&1
    rc=$?
    if [ "$rc" -eq 124 ]; then
        echo "TIMEOUT $f (>${PER_FILE_TIMEOUT}s)"
        timeouts=$((timeouts + 1))
    fi
done

python - "$RESULTS_DIR" "$timeouts" "$BASELINE_FILE" "$link_rc" \
    "$lint_rc" "$bench_rc" "$traj_rc" <<'PY'
import glob
import os
import sys
import xml.etree.ElementTree as ET

results_dir, timeouts, baseline_path = (sys.argv[1], int(sys.argv[2]),
                                        sys.argv[3])
link_errors = int(sys.argv[4])
lint_errors = 1 if int(sys.argv[5]) else 0
bench_errors = 1 if int(sys.argv[6]) else 0
traj_errors = 1 if int(sys.argv[7]) else 0
tests = passed = failed = errors = skipped = files = 0
for path in sorted(glob.glob(os.path.join(results_dir, "*.xml"))):
    files += 1
    suite = ET.parse(path).getroot()
    if suite.tag == "testsuites":
        suite = suite.find("testsuite")
    t = int(suite.get("tests", 0))
    f = int(suite.get("failures", 0))
    e = int(suite.get("errors", 0))
    s = int(suite.get("skipped", 0))
    tests += t
    failed += f
    errors += e
    skipped += s
    passed += t - f - e - s
red = (failed + errors + timeouts + link_errors + lint_errors
       + bench_errors + traj_errors)
print(f"TIER1 files={files} passed={passed} failed={failed} "
      f"errors={errors} skipped={skipped} timeout={timeouts} "
      f"doclinks={link_errors} lint={lint_errors} bench={bench_errors} "
      f"traj={traj_errors}")

if not os.path.exists(baseline_path):
    with open(baseline_path, "w") as fh:
        fh.write(f"{red}\n")
    print(f"baseline recorded: red={red}")
    sys.exit(1 if red else 0)
baseline = int(open(baseline_path).read().strip())
if red > baseline:
    print(f"REGRESSION: red={red} > baseline={baseline}")
    sys.exit(1)
print(f"ok: red={red} <= baseline={baseline}")
PY
