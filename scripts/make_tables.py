"""Render §Roofline markdown tables from dry-run JSON records."""
import glob
import json
import os
import sys


def load(d):
    recs = {}
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        recs[(r["arch"], r["cell"], r["mesh"])] = r
    return recs


def table(base_dir="results/dryrun", opt_dir="results/dryrun_v2"):
    base = load(base_dir)
    opt = load(opt_dir) if os.path.isdir(opt_dir) else {}
    hdr = ("| arch | cell | mesh | quant | compute_s | memory_s | "
           "collective_s | dominant | useful | rl_frac | opt step_s | Δ |")
    sep = "|" + "---|" * 12
    print(hdr)
    print(sep)
    for key in sorted(base):
        r = base[key]
        o = opt.get(key)
        step_b = r["step_time_s"]
        if o:
            imp = step_b / o["step_time_s"] if o["step_time_s"] else 1
            extra = f"{o['step_time_s']:.3e} | {imp:4.1f}× |"
        else:
            extra = "— | — |"
        print(f"| {r['arch']} | {r['cell']} | {r['mesh']} | {r['quant']} | "
              f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
              f"{r['collective_s']:.3e} | {r['dominant']} | "
              f"{r['useful_flops_fraction']:.3f} | "
              f"{r['roofline_fraction']:.3f} | {extra}")


if __name__ == "__main__":
    table(*(sys.argv[1:] or []))
