"""§Roofline aggregator: results/dryrun/*.json → the per-cell terms table.

Reads every dry-run record (written by `repro.launch.dryrun`) and prints the
three-term roofline per (arch × shape × mesh), the dominant term, MODEL_FLOPS
/ HLO_FLOPs, and the skip list — i.e. the EXPERIMENTS.md §Roofline source.

Also prints the serving-disaggregation table: per decoder arch, the
prefill vs decode arithmetic intensity against the machine balance, which
side of the roofline each phase lands on, and the predicted crossover
prompt length past which splitting the two phases onto separate engines
pays (one prefill admission outweighs a full decode step — the policy
`serving.disagg.DisaggController` uses to place requests).
"""
from __future__ import annotations

import glob
import json
import os

import repro.configs as C
from repro.roofline.costmodel import disagg_report

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")

DISAGG_DECODE_BATCH = 128
DISAGG_CONTEXT = 4096


def run_disagg(csv_rows: list) -> dict:
    """Prefill-vs-decode intensity + predicted disagg crossover per arch."""
    hdr = (f"{'arch':22s} {'prefill F/B':>11s} {'decode F/B':>10s} "
           f"{'prefill':>8s} {'decode':>7s} {'disagg?':>7s} "
           f"{'crossover':>9s}")
    print()
    print(f"serving disaggregation (decode batch {DISAGG_DECODE_BATCH}, "
          f"context {DISAGG_CONTEXT}):")
    print(hdr)
    print("-" * len(hdr))
    reports = {}
    for arch in C.list_archs():
        cfg = C.get_config(arch)
        if cfg.is_encoder:
            csv_rows.append((f"roofline/disagg/{arch}", "skipped",
                             "encoder arch — no prefill/decode split"))
            continue
        rep = disagg_report(cfg, decode_batch=DISAGG_DECODE_BATCH,
                            context=DISAGG_CONTEXT)
        reports[arch] = rep
        cross = rep["crossover_prompt_tokens"]
        print(f"{arch:22s} {rep['prefill_intensity']:11.1f} "
              f"{rep['decode_intensity']:10.1f} "
              f"{rep['prefill_bound']:>8s} {rep['decode_bound']:>7s} "
              f"{str(rep['disaggregate']):>7s} "
              f"{str(cross):>9s}")
        csv_rows.append((
            f"roofline/disagg/{arch}",
            str(cross),
            f"prefill {rep['prefill_bound']}-bound "
            f"{rep['prefill_intensity']:.0f} F/B, decode "
            f"{rep['decode_bound']}-bound {rep['decode_intensity']:.0f} "
            f"F/B, balance {rep['machine_balance']:.0f}, "
            f"disaggregate={rep['disaggregate']}"))
    return reports


def load_records(results_dir: str = RESULTS) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    return (f"{r['arch']:22s} {r['cell']:12s} {r['mesh']:6s} "
            f"{r['quant']:9s} "
            f"{r['compute_s']:9.3e} {r['memory_s']:9.3e} "
            f"{r['collective_s']:9.3e} {r['dominant']:10s} "
            f"{r['useful_flops_fraction']:6.3f} "
            f"{r['roofline_fraction']:6.3f}")


def lever(r: dict) -> str:
    """One sentence: what would move this cell's dominant term down."""
    dom, step = r["dominant"], r["step"]
    if dom == "memory" and step == "decode":
        return ("cache bytes dominate → KV-cache int8/int4 "
                "(kv_quant, §Perf A4) or MLA-style latent caches")
    if dom == "memory":
        return ("score/activation HBM traffic → flash-tiled attention "
                "(kernels/flash_attention) keeps scores in VMEM")
    if dom == "collective" and step == "train":
        return ("DP gradient all-reduce floor → bf16 comm (on), grad "
                "reduce-scatter aligned to ZeRO-1 shards, overlap via "
                "latency-hiding scheduler")
    if dom == "collective":
        return ("sharding-induced gathers → group-aligned quantized "
                "sharding (§Perf A2) / shard_map-local dispatch (§Perf B2)")
    if dom == "compute" and r["useful_flops_fraction"] < 0.2:
        return ("low useful fraction → remove replicated attention "
                "(q-chunk sharding, §Perf C2) or redundant remat")
    return "near compute roofline → larger per-chip batch or fuse epilogues"


def run(csv_rows: list) -> dict:
    recs = load_records()
    hdr = (f"{'arch':22s} {'cell':12s} {'mesh':6s} {'quant':9s} "
           f"{'compute_s':>9s} {'memory_s':>9s} {'collect_s':>9s} "
           f"{'dominant':10s} {'useful':>6s} {'rl_frac':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in recs:
        print(fmt_row(r))
        print(f"{'':22s} ↳ {lever(r)}")
        csv_rows.append((
            f"roofline/{r['arch']}/{r['cell']}/{r['mesh']}/{r['quant']}",
            f"{r['step_time_s']*1e6:.1f}",
            f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}"))
    # skip list (assignment: note them)
    for arch in C.list_archs():
        for cell, why in C.skipped_cells(arch).items():
            csv_rows.append((f"roofline/{arch}/{cell}", "skipped", why))
    disagg = run_disagg(csv_rows)
    return {"cells": len(recs), "disagg": disagg}


if __name__ == "__main__":
    rows = []
    run(rows)
