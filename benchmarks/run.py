"""Benchmark harness — one module per paper table/figure.

  Table I   → bench_latency_breakdown (MAC-share of decode latency)
  Table III → bench_compression (model size), bench_throughput (tok/s +
              Eq. 1 score), bench_accuracy (quantization quality proxy)
  Table II  → bench_kernels (structural accelerator numbers)
  §Roofline → roofline (aggregated dry-run terms, if results exist)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os
import traceback


def main() -> None:
    rows: list[tuple[str, str, str]] = []
    from benchmarks import (bench_accuracy, bench_compression,
                            bench_kernels, bench_latency_breakdown,
                            bench_serving, bench_throughput)
    modules = [
        ("latency_breakdown", bench_latency_breakdown),
        ("compression", bench_compression),
        ("accuracy", bench_accuracy),
        ("throughput", bench_throughput),
        ("serving", bench_serving),
        ("kernels", bench_kernels),
    ]
    failures = []
    for name, mod in modules:
        try:
            mod.run(rows)
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
    if os.path.isdir(os.environ.get("DRYRUN_DIR", "results/dryrun")):
        try:
            from benchmarks import roofline
            roofline.run(rows)
        except Exception as e:
            failures.append(("roofline", repr(e)))

    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r))
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
