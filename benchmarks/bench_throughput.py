"""Paper Table III (throughput): 2.8 → 5.1 tok/s on the KV260.

Three views:
 1. KV260 weight-stream roofline — decode is weight-bandwidth-bound on the
    19.2 GB/s DDR: tok/s ≤ BW / weight-bytes-per-token. The INT4 AWQ_MACRO
    stream cuts bytes/token 988 MB → 444 MB (the paper's own argument for
    why compression ≈ doubles decode throughput: 5.1/2.8 = 1.82×).
 2. TPU v5e decode roofline from the analytic cost model (serve dry-run
    terms), float vs AWQ — the adapted large-scale version of the claim.
 3. Measured wall-clock on this CPU host: smoke-scale qwen25 decode, float
    vs AWQ-ref path (same code path the container can actually execute).

Plus the paper's Eq. (1) composite score re-computed from our ratios.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.configs import SHAPES
from repro.core import quantize_params
from repro.core.qlinear import set_execution_config
from repro.data import make_dataset
from repro.models import build_model
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
from repro.roofline.costmodel import cell_costs
from repro.serving import GenerationEngine

KV260_BW = 19.2e9  # paper §II-B


def kv260_model(csv_rows: list) -> dict:
    from benchmarks.bench_compression import sizes_for
    s = sizes_for("qwen25-05b")
    tps_fp16 = KV260_BW / (s["baseline_mb"] * 1e6)
    tps_awq = KV260_BW / (s["awq_gs64_mb"] * 1e6)
    csv_rows.append(("throughput/kv260_weightstream_fp16_tps",
                     f"{tps_fp16:.2f}", "bandwidth bound (paper meas 2.8)"))
    csv_rows.append(("throughput/kv260_weightstream_awq_tps",
                     f"{tps_awq:.2f}", "bandwidth bound (paper meas 5.1)"))
    csv_rows.append(("throughput/kv260_speedup", f"{tps_awq/tps_fp16:.2f}x",
                     "paper 1.82x"))
    return {"speedup": tps_awq / tps_fp16}


def v5e_roofline(csv_rows: list) -> dict:
    cfg = C.get_config("qwen25-05b")
    cell = SHAPES["decode_32k"]
    out = {}
    for quant in (False, True):
        cc = cell_costs(cfg, cell, quant)
        step = max(cc.flops / PEAK_FLOPS, cc.total_bytes / HBM_BW)  # 1 chip
        tps = cell.global_batch / step
        tag = "awq" if quant else "fp16"
        out[tag] = tps
        csv_rows.append((f"throughput/v5e_decode32k_{tag}_tps_per_chip",
                         f"{tps:.0f}",
                         f"w={cc.weight_bytes/1e9:.2f}GB "
                         f"cache={cc.cache_bytes/1e9:.2f}GB/step"))
    csv_rows.append(("throughput/v5e_decode_speedup",
                     f"{out['awq']/out['fp16']:.2f}x",
                     "batch128/32k-ctx (cache-dominated)"))
    # batch-1 serving: the paper's actual regime, weights dominate
    import dataclasses
    cell1 = dataclasses.replace(cell, global_batch=1, seq_len=1024)
    for quant in (False, True):
        cc = cell_costs(cfg, cell1, quant)
        tps = 1.0 / max(cc.flops / PEAK_FLOPS, cc.total_bytes / HBM_BW)
        out[f"b1_{'awq' if quant else 'fp16'}"] = tps
    csv_rows.append(("throughput/v5e_decode_b1_speedup",
                     f"{out['b1_awq']/out['b1_fp16']:.2f}x",
                     "batch1/1k-ctx (weight-dominated — paper's regime)"))
    return out


def measured_cpu(csv_rows: list) -> dict:
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ds = make_dataset(cfg, 4, 32)
    prompt = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"])}
    out = {}
    for tag, p in (("fp32", params), ("awq", quantize_params(params)[0])):
        set_execution_config(impl="ref", compute_dtype=jnp.float32)
        eng = GenerationEngine(m, p, max_seq=96)
        eng.generate(prompt, 4)  # warmup/compile
        t0 = time.perf_counter()
        toks = eng.generate(prompt, 32)
        dt = time.perf_counter() - t0
        out[tag] = toks.size / dt
        csv_rows.append((f"throughput/cpu_smoke_{tag}_tps",
                         f"{out[tag]:.1f}", "wall-clock, ref path"))
    return out


def eq1_score(csv_rows: list, acc_ratio=0.9565) -> dict:
    """Paper Eq. (1): 0.4·acc + 0.2·mem + 0.2·tp_prefill + 0.2·tp_decode,
    each normalized by the max across systems. Baseline fp16 system scores
    0.4 by construction (acc=1, others → baseline=1 is the max denominator
    only for accuracy)."""
    from benchmarks.bench_compression import sizes_for
    s = sizes_for("qwen25-05b")
    mem_ratio = s["baseline_mb"] / s["awq_gs64_mb"]   # >1 for ours
    cfg = C.get_config("qwen25-05b")
    cc_f = cell_costs(cfg, SHAPES["decode_32k"], False)
    cc_q = cell_costs(cfg, SHAPES["decode_32k"], True)
    tp_d = (cc_f.total_bytes) / (cc_q.total_bytes)
    cc_fp = cell_costs(cfg, SHAPES["prefill_32k"], False)
    cc_qp = cell_costs(cfg, SHAPES["prefill_32k"], True)
    tp_p = max(cc_fp.flops / PEAK_FLOPS, cc_fp.total_bytes / HBM_BW) / \
        max(cc_qp.flops / PEAK_FLOPS, cc_qp.total_bytes / HBM_BW)
    # normalize per Eq. 1: MAX over {baseline, ours} of each ratio
    ours = 0.4 * (acc_ratio / 1.0) + 0.2 * (mem_ratio / mem_ratio) \
        + 0.2 * (tp_p / max(tp_p, 1.0)) + 0.2 * (tp_d / max(tp_d, 1.0))
    base = 0.4 * 1.0 + 0.2 * (1.0 / mem_ratio) \
        + 0.2 * (1.0 / max(tp_p, 1.0)) + 0.2 * (1.0 / max(tp_d, 1.0))
    csv_rows.append(("throughput/eq1_score_ours", f"{ours:.3f}",
                     "paper 0.55"))
    csv_rows.append(("throughput/eq1_score_baseline", f"{base:.3f}",
                     "paper 0.40"))
    return {"ours": ours, "baseline": base}


def run(csv_rows: list) -> dict:
    out = {"kv260": kv260_model(csv_rows),
           "v5e": v5e_roofline(csv_rows),
           "cpu": measured_cpu(csv_rows)}
    from benchmarks.bench_accuracy import acc_ratio_cached
    out["eq1"] = eq1_score(csv_rows, acc_ratio_cached())
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
