"""Paper Table III (accuracy): WNLI 64.79% → 61.97% under AWQ GS=64.

WNLI is not available offline, so the proxy is held-out cross-entropy on
the synthetic Markov stream with a briefly-trained qwen25-05b smoke model:

  * fp32 baseline,
  * AWQ GS=64 (the paper's pick), AWQ GS=128 (AWQ default),
  * plain round-to-nearest (no activation-aware scale) GS=64.

Expected ordering (the paper's qualitative claims): AWQ ≪ RTN degradation,
and GS=64 ≤ GS=128 degradation. The accuracy *ratio* (quantized/baseline,
via exp(-ΔCE) perplexity ratio) feeds Eq. (1) in bench_throughput.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import (AWQConfig, CalibrationCapture, QuantConfig,
                        quantize_params)
from repro.core.qlinear import set_execution_config
from repro.data import make_dataset
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, make_train_step
from repro.training.train_step import init_train_state

_CACHE: dict = {}


def _trained_model(steps=150):
    if "model" in _CACHE:
        return _CACHE["model"]
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    state = init_train_state(m, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, TrainConfig(optimizer=AdamWConfig(
        lr=3e-3, warmup_steps=5, decay_steps=steps, weight_decay=0.0))))
    ds = make_dataset(cfg, 16, 64)
    for i in range(steps):
        state, _ = step(state, {k: jnp.asarray(v)
                                for k, v in ds.batch_at(i).items()})
    _CACHE["model"] = (cfg, m, state["params"])
    return _CACHE["model"]


def _eval_ce(m, params, cfg, n_batches=4) -> float:
    ds = make_dataset(cfg, 16, 64, seed=999)  # held out
    set_execution_config(impl="ref", compute_dtype=jnp.float32)
    tot = 0.0
    for i in range(n_batches):
        loss, _ = jax.jit(m.loss)(params, {
            k: jnp.asarray(v) for k, v in ds.batch_at(i).items()})
        tot += float(loss)
    return tot / n_batches


def run(csv_rows: list) -> dict:
    cfg, m, params = _trained_model()
    ds = make_dataset(cfg, 4, 64, seed=123)
    with CalibrationCapture() as cap:   # 2 calib batches, 512 rows/linear
        for i in range(2):
            m.loss(params, {k: jnp.asarray(v)
                            for k, v in ds.batch_at(i).items()})

    ce = {"fp32": _eval_ce(m, params, cfg)}
    variants = {
        "awq_gs64": AWQConfig(quant=QuantConfig(group_size=64)),
        "awq_gs128": AWQConfig(quant=QuantConfig(group_size=128)),
    }
    for tag, qcfg in variants.items():
        qp, _ = quantize_params(params, cap.stats, qcfg)
        ce[tag] = _eval_ce(m, qp, cfg)
    qp_rtn, _ = quantize_params(params, None,
                                AWQConfig(quant=QuantConfig(group_size=64)))
    ce["rtn_gs64"] = _eval_ce(m, qp_rtn, cfg)

    for tag, v in ce.items():
        csv_rows.append((f"accuracy/ce_{tag}", f"{v:.4f}",
                         f"delta={v-ce['fp32']:+.4f}"))
    # qualitative claims
    csv_rows.append(("accuracy/awq_beats_rtn",
                     str(ce["awq_gs64"] <= ce["rtn_gs64"] + 1e-3),
                     "paper Fig.2 claim"))
    csv_rows.append(("accuracy/gs64_vs_gs128",
                     str(ce["awq_gs64"] <= ce["awq_gs128"] + 1e-3),
                     "paper §III-A GS choice"))
    _CACHE["acc_ratio"] = float(np.exp(-(ce["awq_gs64"] - ce["fp32"])))
    csv_rows.append(("accuracy/eq1_acc_ratio", f"{_CACHE['acc_ratio']:.4f}",
                     "exp(-dCE); paper 61.97/64.79=0.956"))
    return ce


def acc_ratio_cached() -> float:
    if "acc_ratio" not in _CACHE:
        run([])
    return _CACHE["acc_ratio"]


if __name__ == "__main__":
    rows = []
    print(run(rows))
    for r in rows:
        print(",".join(r))
