"""Serving benchmark: continuous batching + paged KV vs. the static batch.

The paper's 5.1 tok/s (§III) is a single-stream number; a serving system
cares about *sustained* throughput under concurrent traffic. This bench
replays the same Poisson-arrival workload (mixed prompt lengths, mixed
token budgets) through both execution models:

  * **static batching** — requests are grouped in arrival order into
    fixed batches of ``num_slots``; each batch left-pads prompts to a
    common length and decodes until the *longest* budget in the batch is
    met (the classic convoy effect: short requests ride along as padding).
  * **continuous batching** — `GenerationEngine.submit()/step()`:
    per-request admission into slots of one fixed-shape decode batch,
    EOS/budget eviction with immediate backfill from the queue, KV held
    in the shared page pool.

Reported: sustained tok/s (useful tokens / wall), per-request latency
p50/p95 (finish − arrival), decode-step counts, and the speedup. Also
verifies that greedy continuous-batching streams are token-identical to
per-request `generate()` — throughput must not come at the cost of
changed outputs.

Memory-lever sections (the compression levers at serving scale):

  * **KV quantization** — KV bytes/token with bf16 vs. int8 page pools
    (int8 codes + f32 scale strips), and the max concurrent slots a fixed
    page-pool byte budget can hold under each regime.
  * **prefix sharing** — 8 requests sharing a 512-token system prefix,
    served with and without `prefix_id`: sustained tok/s, peak physical
    pages, and a token-identity check (shared ≡ unshared under greedy).

Runs end-to-end on CPU at smoke scale (pure JAX path; no TPU kernels).
"""
from __future__ import annotations

import time

import jax
import numpy as np

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine

NUM_REQUESTS = 16
NUM_SLOTS = 4
PAGE_SIZE = 8
MAX_SEQ = 160
ARRIVAL_RATE = 200.0       # req/s — burst load: offered load > capacity,
                           # so throughput measures the engine, not arrivals
PROMPT_LENS = (6, 10, 14, 18)
# long and short budgets interleaved, as a Poisson trace would deliver
# them — each static batch convoy-waits on one long request
TOKEN_BUDGETS = (72, 6, 8, 6, 64, 12, 8, 6, 48, 8, 6, 12, 36, 6, 8, 12)


def make_workload(cfg, seed=0):
    """(arrival_s, prompt, max_new) triples, Poisson arrivals, mixed sizes."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / ARRIVAL_RATE, NUM_REQUESTS))
    reqs = []
    for i in range(NUM_REQUESTS):
        n = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        reqs.append((float(arrivals[i]), prompt, int(TOKEN_BUDGETS[i])))
    return reqs


def _fresh_engine(m, params):
    return GenerationEngine(m, params, max_seq=MAX_SEQ, num_slots=NUM_SLOTS,
                            page_size=PAGE_SIZE)


# ---------------------------------------------------------------------------
# Static-batch baseline
# ---------------------------------------------------------------------------

def _pad_batch(prompts):
    """Left-pad to a common length (keeps the last prompt token last)."""
    s = max(len(p) for p in prompts)
    out = np.zeros((len(prompts), s), np.int32)
    for i, p in enumerate(prompts):
        out[i, s - len(p):] = p
    return out


def run_static(eng, workload):
    """Arrival-order batches of NUM_SLOTS; returns (tokens, lat, steps, dt)."""
    batches = [workload[i:i + NUM_SLOTS]
               for i in range(0, len(workload), NUM_SLOTS)]
    # warmup: compile prefill/decode for every padded batch shape
    for batch in batches:
        eng.generate({"tokens": _pad_batch([p for _, p, _ in batch])}, 2)
    t0 = time.perf_counter()
    latencies, useful, steps = [], 0, 0
    for batch in batches:
        run_until = max(mn for _, _, mn in batch)
        last_arrival = max(a for a, _, _ in batch)
        # convoy admission: the batch cannot launch before its last arrival
        while time.perf_counter() - t0 < last_arrival:
            time.sleep(0.0005)
        eng.generate({"tokens": _pad_batch([p for _, p, _ in batch])},
                     run_until)
        steps += run_until
        done = time.perf_counter() - t0
        for arrival, _, mn in batch:
            latencies.append(done - arrival)
            useful += mn
    return useful, latencies, steps, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def run_continuous(eng, workload):
    # warmup: compile prefill per prompt length + the decode step, then a
    # full drain so the timed run starts from an empty scheduler
    for _, prompt, _ in workload[: len(PROMPT_LENS)]:
        eng.submit(prompt, 2)
    eng.drain()
    pending = sorted(workload, key=lambda r: r[0])
    finish: dict[int, float] = {}
    arrival_of: dict[int, float] = {}
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i][0] <= now:
            arrival, prompt, mn = pending[i]
            rid = eng.submit(prompt, mn)
            arrival_of[rid] = arrival
            i += 1
        eng.step()
        now = time.perf_counter() - t0
        for rid in eng.collect():
            finish[rid] = now
        if len(finish) == len(workload):
            break
        if i < len(pending) and eng.idle:
            time.sleep(0.0005)
    dt = time.perf_counter() - t0
    latencies = [finish[r] - arrival_of[r] for r in finish]
    useful = sum(mn for _, _, mn in workload)
    return useful, latencies, eng.scheduler_stats.decode_steps, dt


# ---------------------------------------------------------------------------
# KV quantization: bytes/token + slots at a fixed page-pool budget
# ---------------------------------------------------------------------------

# the fixed-budget scenario: serve 512-token-context requests out of a
# 32 MiB page pool (the kind of budget an on-device accelerator has left
# after the INT4 weights)
BUDGET_BYTES = 32 * 1024 * 1024
BUDGET_CONTEXT = 512


def run_kv_quant(m, params, csv_rows):
    bpt = {}
    for quant in ("none", "int8"):
        eng = GenerationEngine(m, params, max_seq=MAX_SEQ,
                               num_slots=NUM_SLOTS, page_size=PAGE_SIZE,
                               kv_quant=quant)
        bpt[quant] = eng.paged_kv_bytes_per_token()
    reduction = 1.0 - bpt["int8"] / bpt["none"]
    pages_per_req = -(-BUDGET_CONTEXT // PAGE_SIZE)
    slots = {q: int(BUDGET_BYTES // (bpt[q] * PAGE_SIZE)) // pages_per_req
             for q in bpt}
    csv_rows.extend([
        ("serving/kv_bytes_per_token_bf16", f"{bpt['none']:.0f}",
         "page-pool bytes per cached token, all layers"),
        ("serving/kv_bytes_per_token_int8", f"{bpt['int8']:.0f}",
         "int8 codes + f32 scale strips"),
        ("serving/kv_bytes_reduction", f"{reduction:.1%}",
         "int8 vs bf16 pages (target ≥ 40%)"),
        ("serving/slots_at_32MiB_bf16", str(slots["none"]),
         f"{BUDGET_CONTEXT}-token contexts in a 32 MiB pool"),
        ("serving/slots_at_32MiB_int8", str(slots["int8"]),
         f"{slots['int8'] / max(slots['none'], 1):.1f}x the bf16 slots"),
    ])
    return {"kv_bytes_per_token": bpt, "kv_bytes_reduction": reduction,
            "budget_slots": slots}


# ---------------------------------------------------------------------------
# Prefix sharing: 8 requests over one 512-token system prefix
# ---------------------------------------------------------------------------

PREFIX_LEN = 512
PREFIX_REQUESTS = 8
PREFIX_TAIL = 16
PREFIX_NEW_TOKENS = 32


def _prefix_workload(cfg, seed=4):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (PREFIX_LEN,)).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (PREFIX_TAIL,)
                                         ).astype(np.int32)])
            for _ in range(PREFIX_REQUESTS)]


def run_prefix_sharing(m, params, csv_rows):
    prompts = _prefix_workload(m.cfg)
    max_seq = PREFIX_LEN + PREFIX_TAIL + PREFIX_NEW_TOKENS + PAGE_SIZE
    max_seq += -max_seq % PAGE_SIZE

    def serve(prefix_id):
        eng = GenerationEngine(m, params, max_seq=max_seq,
                               num_slots=PREFIX_REQUESTS,
                               page_size=PAGE_SIZE)
        # warmup: compile the decode step plus both prefill variants the
        # timed run will hit (first request commits all pages, followers
        # skip the aliased prefix); the warmup requests drain fully, so
        # their pages — and the prefix index entries — are all released
        eng.submit(prompts[0], 2, prefix_id=prefix_id)
        eng.submit(prompts[1], 2, prefix_id=prefix_id)
        eng.drain()
        t0 = time.perf_counter()
        rids = [eng.submit(p, PREFIX_NEW_TOKENS, prefix_id=prefix_id)
                for p in prompts]
        peak_pages = 0
        while not eng.idle:
            eng.step()
            peak_pages = max(peak_pages, eng._scheduler.pager.pages_in_use)
        dt = time.perf_counter() - t0
        out = eng.collect()
        toks = sum(len(out[r]) for r in rids)
        return ([list(out[r]) for r in rids], toks / dt, peak_pages,
                eng.scheduler_stats.prefix_shared_pages)

    shared_streams, shared_tps, shared_peak, aliased = serve("sys")
    plain_streams, plain_tps, plain_peak, _ = serve(None)
    identical = shared_streams == plain_streams
    csv_rows.extend([
        ("serving/prefix_shared_tps", f"{shared_tps:.1f}",
         f"{PREFIX_REQUESTS} reqs × {PREFIX_LEN}-token shared prefix"),
        ("serving/prefix_unshared_tps", f"{plain_tps:.1f}", ""),
        ("serving/prefix_peak_pages_shared", str(shared_peak),
         f"{aliased} page-aliases avoided allocation"),
        ("serving/prefix_peak_pages_unshared", str(plain_peak), ""),
        ("serving/prefix_token_identity", str(identical),
         "greedy shared ≡ unshared streams"),
    ])
    return {"prefix_shared_tps": shared_tps, "prefix_unshared_tps": plain_tps,
            "prefix_peak_pages": (shared_peak, plain_peak),
            "prefix_token_identical": identical}


def verify_token_identity(m, params, workload):
    """Greedy continuous streams ≡ per-request generate()."""
    import jax.numpy as jnp
    eng = _fresh_engine(m, params)
    rids = [eng.submit(p, mn) for _, p, mn in workload]
    out = eng.drain()
    for rid, (_, p, mn) in zip(rids, workload):
        ref = eng.generate({"tokens": jnp.asarray(p)[None, :]}, mn)[0]
        np.testing.assert_array_equal(out[rid], ref[: len(out[rid])])
    return True


def run(csv_rows: list) -> dict:
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    workload = make_workload(cfg)

    su, sl, ss, sdt = run_static(_fresh_engine(m, params), workload)
    cu, cl, cs, cdt = run_continuous(_fresh_engine(m, params), workload)
    identical = verify_token_identity(m, params, workload)
    kv = run_kv_quant(m, params, csv_rows)
    prefix = run_prefix_sharing(m, params, csv_rows)

    s_tps, c_tps = su / sdt, cu / cdt
    rows = [
        ("serving/static_sustained_tps", f"{s_tps:.1f}",
         f"{su} tokens, {ss} decode steps"),
        ("serving/continuous_sustained_tps", f"{c_tps:.1f}",
         f"{cu} tokens, {cs} decode steps"),
        ("serving/continuous_speedup", f"{c_tps / s_tps:.2f}x",
         "sustained tok/s vs static batch"),
        ("serving/static_p50_latency_s", f"{np.percentile(sl, 50):.3f}", ""),
        ("serving/static_p95_latency_s", f"{np.percentile(sl, 95):.3f}", ""),
        ("serving/continuous_p50_latency_s",
         f"{np.percentile(cl, 50):.3f}", ""),
        ("serving/continuous_p95_latency_s",
         f"{np.percentile(cl, 95):.3f}", ""),
        ("serving/greedy_token_identity", str(identical),
         "continuous ≡ sequential generate()"),
    ]
    csv_rows.extend(rows)
    return {"static_tps": s_tps, "continuous_tps": c_tps,
            "speedup": c_tps / s_tps,
            "static_p95": float(np.percentile(sl, 95)),
            "continuous_p95": float(np.percentile(cl, 95)),
            "token_identical": identical, **kv, **prefix}


if __name__ == "__main__":
    rows: list = []
    out = run(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
    assert out["token_identical"]
    assert out["prefix_token_identical"]
    assert out["kv_bytes_reduction"] >= 0.40
