"""Serving benchmark: chunked token-budget serving vs. one-shot prefill
vs. the static batch.

The paper's 5.1 tok/s (§III) is a single-stream number; a serving system
cares about *sustained* throughput and time-to-first-token under
concurrent traffic. This bench replays Poisson-arrival workloads (mixed
prompt lengths, mixed token budgets) through three execution models:

  * **static batching** — requests are grouped in arrival order into
    fixed batches of ``num_slots``; each batch left-pads prompts to a
    common length and decodes until the *longest* budget in the batch is
    met (the classic convoy effect: short requests ride along as padding).
  * **one-shot continuous batching** — per-request admission runs a full
    dense prefill (jit per prompt length) fused with page commit and
    first-token sampling, then fixed-shape decode. The PR-2 baseline
    (``chunked_prefill=False``).
  * **chunked (token-budget) serving** — every step is ONE fixed-shape
    ``num_slots × prefill_chunk`` dispatch packing prefill chunks and
    decode tokens from mixed requests; exactly one compiled step
    function; aliased shared-prefix pages are read, never recomputed.

Reported: sustained tok/s (useful tokens / wall), per-request latency
p50/p95 (finish − arrival), **TTFT p50/p95** (first stream token −
arrival), decode-step counts, and **prefill-FLOPs-saved** accounting
(prompt tokens never run through the model thanks to prefix aliasing).
Also verifies that greedy chunked streams are token-identical to
per-request `generate()` — throughput must not come at the cost of
changed outputs.

Scenario sections:

  * **convoy** — a mixed long-prompt/short-prompt Poisson burst: under
    one-shot prefill a long prompt monopolizes the engine while admitted
    (short requests' decode stalls behind the dense prefill dispatch);
    chunked serving interleaves, fixing the convoy effect.
  * **KV quantization** — KV bytes/token with bf16 vs. int8 page pools,
    and the max concurrent slots a fixed page-pool byte budget holds.
  * **prefix sharing** — requests over one shared system prefix, served
    chunked vs. one-shot: with chunked prefill the aliased pages save
    *prefill FLOPs* (followers skip the whole prefix), not just memory —
    TTFT collapses accordingly.
  * **speculative decoding** — a repetitive-text burst (the prompt-lookup
    drafter's home turf) through `spec_decode="ngram"`: acceptance rate,
    mean tokens emitted per verify run (> 1 means one weight pass now
    amortizes over several tokens — the lever against the paper's
    memory-bandwidth-bound 5.1 tok/s decode), unified-dispatch count vs.
    the plain engine, and greedy token identity.
  * **decode-row packing** — every row of the unified dispatch declares
    its true run length and the packer pads only to the smallest width
    bucket covering the step; reported as the padding-waste % of
    dispatched positions, next to what the old fixed-chunk-width policy
    would have paid on the same steps.
  * **tiered SLO (preemption + KV spill)** — two overload shapes against
    the same engine, TTFT measured in *dispatch steps* (deterministic
    under greedy, so the smoke gate asserts improvements instead of
    eyeballing wall clock; wall-clock p95 reported alongside):
    *slot contention* — ``num_slots=2`` fully held by low-priority batch
    decodes when interactive requests arrive; without preemption they
    convoy behind a whole batch budget, with it the scheduler spills a
    victim's KV pages to the host tier and restores it later, holding
    interactive TTFT flat. *long-context reservation* — a long request's
    worst-case reservation blocks every short under conservative
    admission (the scaled-down 32k-convoy problem); optimistic admission
    admits them immediately and relieves pool pressure by spilling. The
    preempted streams are asserted token-identical to uninterrupted
    per-request `generate()` (gated identity section).
  * **mesh-sharded serving** — the full feature stack (chunked + int8 +
    prefix sharing + ngram spec) through ``GenerationEngine(mesh=...)``
    for every ``model``-axis size the host's devices allow: greedy
    streams must stay token-identical to the unsharded engine, and
    per-device peak page-pool bytes must shrink ~linearly with the axis
    (pools stripe over KV heads; page tables and the pager replicate).
    With one local device only the degenerate size-1 mesh runs — force
    more with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
  * **disaggregated prefill/decode** — ``DisaggController`` hands
    committed KV pages from a prefill engine to a decode engine with
    zero recompute: greedy streams stay token-identical to the unified
    engine (gated section, incl. a prefill-mesh ≠ decode-mesh leg when
    devices allow), decode-side TTFT is reported as pure transfer cost
    (wire KiB + adopt ms per handoff), and a mixed burst scores the
    convoy effect on the decode-side clock next to the roofline
    report's predicted disaggregation crossover.
  * **multi-replica fleet (router)** — N engines behind the
    prefix-affinity `Router`: a clustered-prefix Poisson burst served
    with affinity placement vs. seeded-random placement (affinity must
    skip strictly more prefill tokens; sustained tok/s is asserted at
    full scale and reported at smoke scale), sustained throughput vs.
    replica count {1, 2, 4}, a 1-replica fleet asserted token-identical
    to the bare engine (gated section ``router_vs_single``), and an
    elastic `drain_replica` under load that must lose and duplicate
    nothing (every stream checked against bare-engine references).

All metrics come from the engine's public `stats()` snapshot — the bench
never reaches into scheduler or pager internals. Every **asserted
identity section** registers itself in ``identity_sections``; the run
exits non-zero if any registered-expected section is missing or False,
so the smoke gate cannot silently pass while covering nothing.

Runs end-to-end on CPU at smoke scale (pure JAX path; no TPU kernels).
``--smoke`` runs a reduced version as the tier-1 end-to-end gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time

import jax
import numpy as np

import repro.configs as C
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.roofline.costmodel import disagg_report
from repro.serving import DisaggController, GenerationEngine, Router

# identity sections the gate requires: each section sets its key to the
# asserted comparison's outcome only after ACTUALLY running it — a
# section that is skipped (or crashes) leaves its key missing, and
# `main` exits non-zero either way
REQUIRED_IDENTITY = ("chunked_vs_oneshot_vs_generate", "spec_vs_plain",
                     "sharded_vs_unsharded", "awq_kernel_vs_ref",
                     "preempt_vs_uninterrupted", "tree_vs_plain",
                     "parallel_vs_single", "disagg_vs_unified",
                     "router_vs_single")

NUM_REQUESTS = 16
NUM_SLOTS = 4
PAGE_SIZE = 8
MAX_SEQ = 160
PREFILL_CHUNK = 16
ARRIVAL_RATE = 200.0       # req/s — burst load: offered load > capacity,
                           # so throughput measures the engine, not arrivals
PROMPT_LENS = (6, 10, 14, 18)
# long and short budgets interleaved, as a Poisson trace would deliver
# them — each static batch convoy-waits on one long request
TOKEN_BUDGETS = (72, 6, 8, 6, 64, 12, 8, 6, 48, 8, 6, 12, 36, 6, 8, 12)


def make_workload(cfg, seed=0, num_requests=NUM_REQUESTS,
                  prompt_lens=PROMPT_LENS, budgets=TOKEN_BUDGETS,
                  rate=ARRIVAL_RATE):
    """(arrival_s, prompt, max_new) triples, Poisson arrivals, mixed sizes."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, num_requests))
    reqs = []
    for i in range(num_requests):
        n = prompt_lens[i % len(prompt_lens)]
        prompt = rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        reqs.append((float(arrivals[i]), prompt, int(budgets[i % len(budgets)])))
    return reqs


def _fresh_engine(m, params, **kw):
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("num_slots", NUM_SLOTS)
    kw.setdefault("page_size", PAGE_SIZE)
    kw.setdefault("prefill_chunk", PREFILL_CHUNK)
    return GenerationEngine(m, params, **kw)


# ---------------------------------------------------------------------------
# Static-batch baseline
# ---------------------------------------------------------------------------

def _pad_batch(prompts):
    """Left-pad to a common length (keeps the last prompt token last)."""
    s = max(len(p) for p in prompts)
    out = np.zeros((len(prompts), s), np.int32)
    for i, p in enumerate(prompts):
        out[i, s - len(p):] = p
    return out


def run_static(eng, workload):
    """Arrival-order batches of NUM_SLOTS; returns (tokens, lat, steps, dt)."""
    batches = [workload[i:i + NUM_SLOTS]
               for i in range(0, len(workload), NUM_SLOTS)]
    # warmup: compile prefill/decode for every padded batch shape
    for batch in batches:
        eng.generate({"tokens": _pad_batch([p for _, p, _ in batch])}, 2)
    t0 = time.perf_counter()
    latencies, useful, steps = [], 0, 0
    for batch in batches:
        run_until = max(mn for _, _, mn in batch)
        last_arrival = max(a for a, _, _ in batch)
        # convoy admission: the batch cannot launch before its last arrival
        while time.perf_counter() - t0 < last_arrival:
            time.sleep(0.0005)
        eng.generate({"tokens": _pad_batch([p for _, p, _ in batch])},
                     run_until)
        steps += run_until
        done = time.perf_counter() - t0
        for arrival, _, mn in batch:
            latencies.append(done - arrival)
            useful += mn
    return useful, latencies, steps, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Continuous batching (one-shot or chunked, per engine flags)
# ---------------------------------------------------------------------------

def run_continuous(eng, workload, prefix_id=None):
    """Replay a workload; returns (useful, latencies, ttfts, steps, dt).

    ``latencies`` are finish − arrival, ``ttfts`` first-token − arrival,
    both in request order.
    """
    # warmup. Chunked path: `warmup()` precompiles the full bounded step
    # family (context buckets × block widths). One-shot path: compile
    # every prompt length the workload will present; with a prefix_id,
    # also run the first two real prompts back to back — they share
    # exactly the workload's prefix, so the aliased-commit variant
    # (static start_page = shared pages) compiles before the timed run.
    eng.warmup()
    if not eng._scheduler.chunked:
        seen = set()
        for _, prompt, _ in workload:
            if len(prompt) not in seen:
                seen.add(len(prompt))
                eng.submit(prompt, 2, prefix_id=prefix_id)
        if prefix_id is not None and len(workload) > 1:
            # the leader registers its prefix synchronously at admission,
            # so a follower queued behind it matches the real page count
            eng.submit(workload[1][1], 2, prefix_id=prefix_id)
    eng.drain()
    eng.reset_stats()                   # timed run reports clean stats
    pending = sorted(enumerate(workload), key=lambda r: r[1][0])
    finish: dict[int, float] = {}
    first: dict[int, float] = {}
    last_tok: dict[int, float] = {}
    itl_max: dict[int, float] = {}     # worst inter-token gap (decode stall)
    arrival_of: dict[int, float] = {}
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i][1][0] <= now:
            _, (arrival, prompt, mn) = pending[i]
            rid = eng.submit(prompt, mn, prefix_id=prefix_id)
            arrival_of[rid] = arrival
            i += 1
        events = eng.step()
        now = time.perf_counter() - t0
        for rid, _tok in events:
            if rid not in arrival_of:
                continue
            if rid not in first:
                first[rid] = now
            else:
                itl_max[rid] = max(itl_max.get(rid, 0.0),
                                   now - last_tok[rid])
            last_tok[rid] = now
        for rid in eng.collect():
            finish[rid] = now
        if len(finish) == len(workload):
            break
        if i < len(pending) and eng.idle:
            time.sleep(0.0005)
    dt = time.perf_counter() - t0
    useful = sum(mn for _, _, mn in workload)
    return {"useful": useful,
            "latencies": [finish[r] - arrival_of[r] for r in sorted(finish)],
            "ttfts": [first[r] - arrival_of[r] for r in sorted(first)],
            "itl_max": [itl_max.get(r, 0.0) for r in sorted(finish)],
            "steps": eng.stats().dispatches, "dt": dt}


# ---------------------------------------------------------------------------
# Convoy scenario: mixed long-prompt/short-prompt Poisson burst
# ---------------------------------------------------------------------------

CONVOY_LONG = 1024
CONVOY_SHORT = 6
CONVOY_MAX_SEQ = 1088


def make_convoy_workload(cfg, seed=2, num_requests=12, long_every=3,
                         rate=300.0):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, num_requests))
    reqs = []
    for i in range(num_requests):
        if i % long_every == 0:
            n, mn = CONVOY_LONG, 6
        else:
            n, mn = CONVOY_SHORT, 24
        prompt = rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        reqs.append((float(arrivals[i]), prompt, mn))
    return reqs


def run_convoy(m, params, csv_rows, num_requests=12):
    """Mixed long/short Poisson burst. Under one-shot prefill every
    long-prompt admission is a monolithic dense-prefill dispatch the
    whole engine waits on: short requests queued behind it pay its full
    prefill in TTFT, and in-flight decodes stall (completion latency).
    Chunked serving interleaves, so short-request latency decouples from
    long prompts."""
    wl = make_convoy_workload(m.cfg, num_requests=num_requests)
    res = {}
    for tag, kw in (("chunked", {"prefill_chunk": 64}),
                    ("oneshot", {"chunked_prefill": False})):
        eng = _fresh_engine(m, params, max_seq=CONVOY_MAX_SEQ, **kw)
        r = run_continuous(eng, wl)
        is_short = [len(p) == CONVOY_SHORT for _, p, _ in wl]
        short_ttft = [t for t, s in zip(r["ttfts"], is_short) if s]
        short_stall = [t for t, s in zip(r["itl_max"], is_short) if s]
        res[tag] = {"tps": r["useful"] / r["dt"],
                    "ttft_p95": float(np.percentile(r["ttfts"], 95)),
                    "short_ttft_p95": float(np.percentile(short_ttft, 95)),
                    "short_stall_max": float(np.max(short_stall)),
                    "p95": float(np.percentile(r["latencies"], 95))}
    csv_rows.extend([
        ("serving/convoy_tps_chunked", f"{res['chunked']['tps']:.1f}",
         f"{num_requests} reqs, {CONVOY_LONG}/{CONVOY_SHORT}-token prompts"),
        ("serving/convoy_tps_oneshot", f"{res['oneshot']['tps']:.1f}", ""),
        ("serving/convoy_short_ttft_p95_chunked_s",
         f"{res['chunked']['short_ttft_p95']:.3f}",
         "short requests queued behind long prefills"),
        ("serving/convoy_short_ttft_p95_oneshot_s",
         f"{res['oneshot']['short_ttft_p95']:.3f}", ""),
        ("serving/convoy_decode_stall_chunked_s",
         f"{res['chunked']['short_stall_max']:.3f}",
         "worst inter-token gap of a short request (the convoy effect)"),
        ("serving/convoy_decode_stall_oneshot_s",
         f"{res['oneshot']['short_stall_max']:.3f}",
         "decode waits out the whole monolithic long prefill"),
    ])
    return {"convoy": res}


# ---------------------------------------------------------------------------
# KV quantization: bytes/token + slots at a fixed page-pool budget
# ---------------------------------------------------------------------------

# the fixed-budget scenario: serve 512-token-context requests out of a
# 32 MiB page pool (the kind of budget an on-device accelerator has left
# after the INT4 weights)
BUDGET_BYTES = 32 * 1024 * 1024
BUDGET_CONTEXT = 512


def run_kv_quant(m, params, csv_rows):
    bpt = {}
    for quant in ("none", "int8"):
        eng = _fresh_engine(m, params, kv_quant=quant)
        bpt[quant] = eng.paged_kv_bytes_per_token()
    reduction = 1.0 - bpt["int8"] / bpt["none"]
    pages_per_req = -(-BUDGET_CONTEXT // PAGE_SIZE)
    slots = {q: int(BUDGET_BYTES // (bpt[q] * PAGE_SIZE)) // pages_per_req
             for q in bpt}
    csv_rows.extend([
        ("serving/kv_bytes_per_token_bf16", f"{bpt['none']:.0f}",
         "page-pool bytes per cached token, all layers"),
        ("serving/kv_bytes_per_token_int8", f"{bpt['int8']:.0f}",
         "int8 codes + f32 scale strips"),
        ("serving/kv_bytes_reduction", f"{reduction:.1%}",
         "int8 vs bf16 pages (target ≥ 40%)"),
        ("serving/slots_at_32MiB_bf16", str(slots["none"]),
         f"{BUDGET_CONTEXT}-token contexts in a 32 MiB pool"),
        ("serving/slots_at_32MiB_int8", str(slots["int8"]),
         f"{slots['int8'] / max(slots['none'], 1):.1f}x the bf16 slots"),
    ])
    return {"kv_bytes_per_token": bpt, "kv_bytes_reduction": reduction,
            "budget_slots": slots}


# ---------------------------------------------------------------------------
# Prefix sharing: a burst over one shared system prefix, chunked vs one-shot
# ---------------------------------------------------------------------------

PREFIX_LEN = 512
PREFIX_REQUESTS = 8
PREFIX_TAIL = 16
PREFIX_NEW_TOKENS = 32


def _prefix_workload(cfg, seed=4, prefix_len=PREFIX_LEN,
                     num_requests=PREFIX_REQUESTS, tail=PREFIX_TAIL,
                     new_tokens=PREFIX_NEW_TOKENS, rate=400.0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, num_requests))
    return [(float(arrivals[i]),
             np.concatenate([prefix,
                             rng.integers(0, cfg.vocab_size, (tail,)
                                          ).astype(np.int32)]),
             new_tokens)
            for i in range(num_requests)]


def run_prefix_sharing(m, params, csv_rows, prefix_len=PREFIX_LEN,
                       num_requests=PREFIX_REQUESTS,
                       new_tokens=PREFIX_NEW_TOKENS):
    wl = _prefix_workload(m.cfg, prefix_len=prefix_len,
                          num_requests=num_requests, new_tokens=new_tokens)
    max_seq = prefix_len + PREFIX_TAIL + new_tokens + PAGE_SIZE
    max_seq += -max_seq % PAGE_SIZE
    total_prompt = sum(len(p) for _, p, _ in wl)

    def serve(prefix_id, **kw):
        eng = _fresh_engine(m, params, max_seq=max_seq,
                            num_slots=num_requests, **kw)
        r = run_continuous(eng, wl, prefix_id=prefix_id)
        st = eng.stats()
        return {"tps": r["useful"] / r["dt"],
                "ttft_p95": float(np.percentile(r["ttfts"], 95)),
                "prefill_tokens": st.prefill_tokens,
                "skipped": st.prefill_tokens_skipped,
                "aliased_pages": st.prefix_shared_pages}

    shared_c = serve("sys")                         # chunked + prefix-aware
    shared_o = serve("sys", chunked_prefill=False)  # one-shot: memory only
    plain_c = serve(None)                           # chunked, no sharing
    flops_saved = shared_c["skipped"] / max(total_prompt, 1)
    csv_rows.extend([
        ("serving/prefix_shared_tps_chunked", f"{shared_c['tps']:.1f}",
         f"{num_requests} reqs × {prefix_len}-token shared prefix"),
        ("serving/prefix_shared_tps_oneshot", f"{shared_o['tps']:.1f}",
         "sharing saves memory but not FLOPs here"),
        ("serving/prefix_unshared_tps_chunked", f"{plain_c['tps']:.1f}", ""),
        ("serving/prefix_prefill_tokens_skipped", str(shared_c["skipped"]),
         f"{shared_c['aliased_pages']} aliased pages never recomputed"),
        ("serving/prefix_prefill_flops_saved", f"{flops_saved:.1%}",
         "prompt tokens skipped / total prompt tokens"),
        ("serving/prefix_ttft_p95_chunked_s", f"{shared_c['ttft_p95']:.3f}",
         "followers skip the whole prefix"),
        ("serving/prefix_ttft_p95_oneshot_s", f"{shared_o['ttft_p95']:.3f}",
         "followers re-run the full dense prefill"),
    ])
    return {"prefix_chunked": shared_c, "prefix_oneshot": shared_o,
            "prefix_unshared": plain_c, "prefix_flops_saved": flops_saved}


# ---------------------------------------------------------------------------
# Speculative decoding: repetitive-text burst, n-gram self-drafting
# ---------------------------------------------------------------------------

SPEC_K = 4
SPEC_NEW_TOKENS = 32


def make_repetitive_workload(cfg, seed=6, num_requests=8, pat_len=4,
                             reps=8, new_tokens=SPEC_NEW_TOKENS, rate=400.0):
    """Templated/repetitive prompts: each is a short pattern tiled, the
    regime prompt-lookup drafting exists for (code, lists, boilerplate)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, num_requests))
    reqs = []
    for i in range(num_requests):
        pat = rng.integers(0, cfg.vocab_size, (pat_len,)).astype(np.int32)
        reqs.append((float(arrivals[i]), np.tile(pat, reps),
                     int(new_tokens)))
    return reqs


def run_spec(m, params, csv_rows, identity, num_requests=8,
             new_tokens=SPEC_NEW_TOKENS, k=SPEC_K,
             tag_prefix="serving/spec"):
    """Repetitive burst through the n-gram speculative engine vs. the
    plain chunked engine: same streams (greedy identity is asserted),
    fewer weight passes."""
    wl = make_repetitive_workload(m.cfg, num_requests=num_requests,
                                  new_tokens=new_tokens)
    max_seq = max(len(p) for _, p, _ in wl) + new_tokens
    max_seq += -max_seq % PAGE_SIZE
    res = {}
    streams = {}
    for tag, kw in (("spec", {"spec_decode": "ngram", "spec_k": k}),
                    ("plain", {})):
        eng = _fresh_engine(m, params, max_seq=max_seq, **kw)
        r = run_continuous(eng, wl)
        st = eng.stats()
        res[tag] = {"tps": r["useful"] / r["dt"], "steps": r["steps"],
                    "acceptance": st.acceptance_rate,
                    "tokens_per_step": st.spec_tokens_per_row,
                    "drafted": st.draft_tokens,
                    "accepted": st.accepted_tokens,
                    "rollbacks": st.rollbacks}
        # identity replay: drain the same prompts through a fresh engine
        eng2 = _fresh_engine(m, params, max_seq=max_seq, **kw)
        rids = [eng2.submit(p, mn) for _, p, mn in wl]
        out = eng2.drain()
        streams[tag] = [list(out[r_]) for r_ in rids]
    identical = streams["spec"] == streams["plain"]
    res["identical"] = identical
    identity["spec_vs_plain"] = identical
    csv_rows.extend([
        (f"{tag_prefix}_acceptance_rate",
         f"{res['spec']['acceptance']:.1%}",
         f"{res['spec']['accepted']}/{res['spec']['drafted']} drafts "
         f"accepted (ngram, k={k})"),
        (f"{tag_prefix}_tokens_per_step",
         f"{res['spec']['tokens_per_step']:.2f}",
         "tokens emitted per verify run (1.0 = drafting never helped)"),
        (f"{tag_prefix}_dispatches", str(res["spec"]["steps"]),
         f"vs {res['plain']['steps']} without drafting — each dispatch "
         f"is one weight pass"),
        (f"{tag_prefix}_tps", f"{res['spec']['tps']:.1f}",
         f"plain chunked: {res['plain']['tps']:.1f}"),
        (f"{tag_prefix}_rollbacks", str(res["spec"]["rollbacks"]),
         "verify runs that truncated the KV watermark"),
        (f"{tag_prefix}_token_identity", str(identical),
         "greedy spec streams ≡ plain chunked streams"),
    ])
    return res


TREE_FANOUT = 2
PARALLEL_N = 3


def run_tree_spec(m, params, csv_rows, identity, num_requests=8,
                  new_tokens=SPEC_NEW_TOKENS, k=SPEC_K,
                  tag_prefix="serving/tree"):
    """Tree speculation vs. linear speculation vs. plain decode.

    Two bursts:

    * the repetitive burst through the n-gram drafters — the tree
      drafter proposes the primary chain plus depth-1 alternate first
      tokens from older occurrence sites. Greedy tree streams are
      asserted token-identical to the plain chunked engine (the gated
      ``tree_vs_plain`` identity section).
    * a *branchy* burst through a two-hypothesis hedged drafter that
      backs the wrong branch on two verify passes out of three — the
      regime hedging exists for. The linear drafter must commit to one
      branch and loses its whole chain on a wrong guess; the tree
      spends one node on the rival branch and salvages an accepted
      token from the same weight pass, so it finishes the same streams
      in strictly fewer dispatches (bench-asserted in ``__main__``).
    """
    import jax.numpy as jnp
    wl = make_repetitive_workload(m.cfg, num_requests=num_requests,
                                  new_tokens=new_tokens)
    max_seq = max(len(p) for _, p, _ in wl) + new_tokens
    max_seq += -max_seq % PAGE_SIZE
    res: dict = {}
    streams: dict = {}
    for tag, kw in (
            ("tree", {"spec_decode": "ngram", "spec_k": k,
                      "spec_tree": True, "spec_tree_fanout": TREE_FANOUT}),
            ("linear", {"spec_decode": "ngram", "spec_k": k}),
            ("plain", {})):
        eng = _fresh_engine(m, params, max_seq=max_seq, **kw)
        rids = [eng.submit(p, mn) for _, p, mn in wl]
        out = eng.drain()
        st = eng.stats()
        streams[tag] = [list(out[r]) for r in rids]
        res[tag] = {"steps": st.dispatches,
                    "acceptance": st.acceptance_rate,
                    "tokens_per_step": st.spec_tokens_per_row,
                    "drafted": st.draft_tokens,
                    "accepted": st.accepted_tokens,
                    "rollbacks": st.rollbacks,
                    "fanout_now": st.spec_fanout_now}
    identical = streams["tree"] == streams["plain"]
    res["identical"] = identical
    identity["tree_vs_plain"] = identical

    # branchy burst: the drafter knows the continuation but hedges an
    # uncertain first token (a branch point). Reference streams come from
    # generate(), so both engines' drafters see the same two hypotheses.
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, m.cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(min(num_requests, NUM_SLOTS))]
    ref_eng = _fresh_engine(m, params, max_seq=max_seq)
    refs = [np.asarray(ref_eng.generate({"tokens": jnp.asarray(p)[None, :]},
                                        new_tokens)[0])
            for p in prompts]

    def _hedged(tree, oracle):
        calls: dict = {}

        def draft(reqs):
            out = {}
            for req in reqs:
                slot, rid, ctx, kk = req[0], req[1], req[2], req[4]
                ref, plen = oracle[rid]
                done = len(ctx) - plen
                true = [int(t) for t in ref[done:done + kk]]
                if not true:
                    continue
                i = calls.get(rid, 0)
                calls[rid] = i + 1
                rival = (true[0] + 1) % m.cfg.vocab_size
                wrong = i % 3 != 2          # backs the wrong branch 2/3
                first = rival if wrong else true[0]
                if tree:
                    nodes = [(first, -1)]
                    nodes += [(t, j) for j, t in enumerate(true[1:kk - 1])]
                    if kk > 1:              # hedge: the rival first token
                        nodes.append((true[0] if wrong else rival, -1))
                    out[slot] = nodes
                else:
                    out[slot] = [first] + true[1:kk]
            return out
        return draft

    branchy: dict = {}
    bstreams: dict = {}
    for tag, tree in (("tree", True), ("linear", False)):
        oracle: dict = {}
        kw = {"spec_decode": "draft_model", "spec_k": k,
              "draft_fn": _hedged(tree, oracle)}
        if tree:
            kw |= {"spec_tree": True, "spec_tree_fanout": TREE_FANOUT}
        eng = _fresh_engine(m, params, max_seq=max_seq, **kw)
        rids = []
        for p, ref in zip(prompts, refs):
            rid = eng.submit(p, new_tokens)
            oracle[rid] = (ref, len(p))
            rids.append(rid)
        out = eng.drain()
        st = eng.stats()
        bstreams[tag] = [list(out[r]) for r in rids]
        branchy[tag] = {"steps": st.dispatches,
                        "acceptance": st.acceptance_rate,
                        "tokens_per_step": st.spec_tokens_per_row}
    assert bstreams["tree"] == bstreams["linear"]
    for s, ref in zip(bstreams["tree"], refs):
        np.testing.assert_array_equal(s, ref[: len(s)])
    res["branchy"] = branchy
    csv_rows.extend([
        (f"{tag_prefix}_acceptance_rate",
         f"{res['tree']['acceptance']:.1%}",
         f"{res['tree']['accepted']}/{res['tree']['drafted']} tree nodes "
         f"accepted (ngram chain+alternates, k={k})"),
        (f"{tag_prefix}_tokens_per_pass",
         f"{res['tree']['tokens_per_step']:.2f}",
         f"vs {res['linear']['tokens_per_step']:.2f} linear — tokens per "
         f"verify weight pass, repetitive burst"),
        (f"{tag_prefix}_dispatches", str(res["tree"]["steps"]),
         f"vs {res['linear']['steps']} linear / "
         f"{res['plain']['steps']} plain"),
        (f"{tag_prefix}_fanout_now", str(res["tree"]["fanout_now"]),
         "adaptive root fanout after the burst (1 = chain only)"),
        (f"{tag_prefix}_branchy_dispatches", str(branchy["tree"]["steps"]),
         f"vs {branchy['linear']['steps']} linear — hedged drafter wrong "
         f"on 2/3 of passes; the depth-1 hedge must win"),
        (f"{tag_prefix}_token_identity", str(identical),
         "greedy tree-spec streams ≡ plain chunked streams"),
    ])
    return res


def run_parallel(m, params, csv_rows, identity, n=PARALLEL_N,
                 prompt_len=32, new_tokens=16,
                 tag_prefix="serving/parallel"):
    """``submit(n=…)`` parallel sampling: ``n`` continuations of one
    prompt alias its physical prompt pages (refcounted, copy-on-write
    partial tail) instead of prefilling and storing ``n`` copies.
    Greedy siblings are asserted identical to ``n`` independent
    submissions (the gated ``parallel_vs_single`` identity section);
    the physical-page and prefill-FLOP savings are reported."""
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, m.cfg.vocab_size,
                          (prompt_len,)).astype(np.int32)
    eng_sep = _fresh_engine(m, params)
    rids = [eng_sep.submit(prompt, new_tokens) for _ in range(n)]
    out = eng_sep.drain()
    sep = [list(out[r]) for r in rids]
    st_sep = eng_sep.stats()
    eng_par = _fresh_engine(m, params)
    rids = eng_par.submit(prompt, new_tokens, n=n)
    out = eng_par.drain()
    par = [list(out[r]) for r in rids]
    st_par = eng_par.stats()
    identical = par == sep
    identity["parallel_vs_single"] = identical
    shared = st_par.prefix_shared_pages
    page_bytes = eng_par.paged_kv_page_bytes()
    csv_rows.extend([
        (f"{tag_prefix}_shared_pages", str(shared),
         f"physical prompt pages aliased across {n} siblings "
         f"(vs {st_sep.prefix_shared_pages} with {n} separate submits)"),
        (f"{tag_prefix}_kv_bytes_saved", str(shared * page_bytes),
         f"{page_bytes} B/page × {shared} pages never duplicated"),
        (f"{tag_prefix}_prefill_tokens_skipped",
         str(st_par.prefill_tokens_skipped),
         f"vs {st_sep.prefill_tokens_skipped} unshared — aliased prompt "
         f"tokens never re-run through the weights"),
        (f"{tag_prefix}_token_identity", str(identical),
         f"greedy submit(n={n}) streams ≡ {n} independent submissions"),
    ])
    return {"identical": identical, "shared_pages": shared,
            "sep_shared": st_sep.prefix_shared_pages,
            "skipped": st_par.prefill_tokens_skipped,
            "kv_bytes_saved": shared * page_bytes}


def verify_token_identity(m, params, workload, identity):
    """Greedy chunked streams ≡ one-shot streams ≡ per-request generate()."""
    import jax.numpy as jnp
    eng = _fresh_engine(m, params)
    eng_one = _fresh_engine(m, params, chunked_prefill=False)
    rids = [eng.submit(p, mn) for _, p, mn in workload]
    rids_one = [eng_one.submit(p, mn) for _, p, mn in workload]
    out, out_one = eng.drain(), eng_one.drain()
    for rid, rid_one, (_, p, mn) in zip(rids, rids_one, workload):
        np.testing.assert_array_equal(out[rid], out_one[rid_one])
        ref = eng.generate({"tokens": jnp.asarray(p)[None, :]}, mn)[0]
        np.testing.assert_array_equal(out[rid], ref[: len(out[rid])])
    identity["chunked_vs_oneshot_vs_generate"] = True
    return True


def _padding_rows(st, csv_rows, tag="serving/padding"):
    """Decode-row packing accounting from a burst's `EngineStats`: rows
    declare their true run length, so padding is paid only up to the
    step's width bucket — reported next to what the old policy (every
    row padded to the prefill chunk width whenever anything prefills)
    would have paid on the same steps."""
    waste, waste_fixed = st.padding_waste, st.padding_waste_fixed
    csv_rows.extend([
        (f"{tag}_waste", f"{waste:.1%}",
         "share of dispatched positions holding padding "
         "(run-length packer)"),
        (f"{tag}_waste_fixed_width", f"{waste_fixed:.1%}",
         "same steps under the old pad-to-chunk-width policy"),
    ])
    return {"waste": waste, "waste_fixed": waste_fixed}


# ---------------------------------------------------------------------------
# Mesh-sharded serving: identity + per-device pool bytes vs the model axis
# ---------------------------------------------------------------------------

SHARD_PREFIX_LEN = 16
SHARD_NEW_TOKENS = 10


def run_sharded(csv_rows, identity):
    """The full serving feature stack under every ``model``-axis size the
    local devices allow (1 is the degenerate mesh — always runs, so this
    section can never be silently skipped): greedy streams must match
    the unsharded engine token-for-token while per-device page-pool
    bytes shrink with the axis. Uses an Hkv = 4 variant of the smoke
    config — pools shard over KV heads, so Hkv must divide the axis
    (that requirement is enforced at engine construction)."""
    cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                              num_heads=8, num_kv_heads=4, head_dim=16)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size,
                          (SHARD_PREFIX_LEN,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (t,)
                                            ).astype(np.int32)])
               for t in (5, 12, 9, 3)]
    sizes = [s for s in (1, 2, 4)
             if s <= jax.device_count() and cfg.num_kv_heads % s == 0]

    def serve(mesh):
        eng = GenerationEngine(m, params, max_seq=64, num_slots=4,
                               page_size=8, prefill_chunk=4,
                               kv_quant="int8", spec_decode="ngram",
                               spec_k=4, mesh=mesh)
        rids = [eng.submit(p, SHARD_NEW_TOKENS, prefix_id="sys")
                for p in prompts]
        out = eng.drain()
        return [list(out[r]) for r in rids], eng.stats()

    ref, st0 = serve(None)
    bytes_per_dev = {}
    identical = True
    for size in sizes:
        got, st = serve(serving_mesh(size))
        identical &= got == ref
        bytes_per_dev[size] = st.kv_pool_bytes_per_device
        csv_rows.append(
            (f"serving/sharded_kv_pool_bytes_per_device_model{size}",
             str(st.kv_pool_bytes_per_device),
             f"of {st.kv_pool_bytes} global pool bytes "
             f"({st.kv_pool_bytes / max(st.kv_pool_bytes_per_device, 1):.1f}"
             f"x reduction)"))
    shrink = bytes_per_dev[sizes[0]] / max(bytes_per_dev[sizes[-1]], 1)
    identity["sharded_vs_unsharded"] = identical
    csv_rows.extend([
        ("serving/sharded_axis_sizes", "/".join(map(str, sizes)),
         f"{jax.device_count()} local devices (force more with "
         f"XLA_FLAGS=--xla_force_host_platform_device_count=4)"),
        ("serving/sharded_token_identity", str(identical),
         "greedy sharded streams ≡ unsharded (chunked+int8+prefix+spec)"),
        ("serving/sharded_per_device_shrink",
         f"{shrink:.1f}x",
         f"pool bytes/device, model={sizes[0]} vs model={sizes[-1]}"),
    ])
    return {"identical": identical, "sizes": sizes,
            "bytes_per_device": bytes_per_dev}


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode: zero-recompute KV page handoff
# ---------------------------------------------------------------------------

DISAGG_KW = dict(max_seq=96, num_slots=4, page_size=8, prefill_chunk=8,
                 kv_quant="int8", spec_decode="ngram", spec_k=4)
DISAGG_LONG = 80          # convoy prompt: handed off, never decodes on the
DISAGG_SHORT = 6          # prefill side; shorts route direct to decode
DISAGG_NEW = 8


def _disagg_warm(server, long_prompt, short_prompt):
    """Compile every shape the timed burst will hit (prefill lengths,
    decode widths, and — for the controller — the handoff gather/scatter
    movers and the adopted-slot decode), then zero the stats."""
    server.submit(short_prompt, 2)
    server.submit(long_prompt, 2)
    server.drain()
    server.reset_stats()


def _disagg_burst(server, shorts, new, longs, long_new, *,
                  decode_clock, delays=(3, 10)):
    """Replay a mixed burst and score it on the DECODE-side clock.

    On one host the two engines take turns, so wall time can't show the
    disaggregation win — what a separate decode accelerator would feel
    is the time spent inside *decode-side dispatches*. For a unified
    engine that clock IS its step clock (the long request's prefill runs
    in its dispatches); for the controller it is the decode-engine step
    time its stats already accumulate — prefill-engine dispatches never
    touch it. Each long prompt arrives a few steps in, once the shorts
    are mid-decode, and ``stall`` is the worst decode-clock gap between
    a short's consecutive tokens — the convoy effect as the decode
    accelerator experiences it, sampled once per long admission.
    """
    role = {}
    for p in shorts:
        role[server.submit(p, new)] = "short"
    total = len(shorts) + len(longs)
    arrive = dict(zip(delays, longs))
    wall_acc = 0.0
    last: dict = {}
    stall, toks, steps = 0.0, 0, 0
    done: set = set()
    clk = 0.0
    clk0 = server.stats().decode_step_time_s if decode_clock else 0.0
    while len(done) < total:
        if steps in arrive:
            role[server.submit(arrive.pop(steps), long_new)] = "long"
        steps += 1
        t0 = time.perf_counter()
        events = server.step()
        wall_acc += time.perf_counter() - t0
        clk = (server.stats().decode_step_time_s - clk0) if decode_clock \
            else wall_acc
        for rid, _tok in events:
            if role.get(rid) != "short":
                continue
            if rid in last:
                stall = max(stall, clk - last[rid])
                toks += 1
            last[rid] = clk
        done |= set(server.collect())
    return {"stall": stall, "decode_s": clk, "decode_toks": toks}


def run_disagg(csv_rows, identity, smoke=False):
    """`DisaggController` vs the unified engine, three claims:

      * **identity** (gated section) — greedy streams through the
        prefill→handoff→decode path match the unified engine token for
        token, with the full decode feature stack on (chunked + int8 KV
        + prefix sharing + ngram spec); with ≥ 2 local devices the same
        burst also runs with the decode engine on a 2-way ``model`` mesh
        while prefill stays unsharded — prefill mesh ≠ decode mesh, the
        replicated wire image doing the resharding.
      * **TTFT as transfer cost** — the decode side never re-runs
        prefill, so its time-to-first-token is the handoff itself: wire
        KiB and adopt milliseconds per handoff (int8 pools ship codes +
        scale strips, ~2× fewer bytes than bf16).
      * **convoy relief** — under a mixed burst (one long prompt + 3
        shorts) the decode-side stall and tok/s are measured on the
        decode clock, quiet vs convoy, unified vs disagg, next to the
        roofline report's predicted crossover.

    Uses the same Hkv = 4 smoke-config variant as `run_sharded` so the
    mesh leg can shard KV heads.
    """
    cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                              num_heads=8, num_kv_heads=4, head_dim=16)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(12)
    prefix = rng.integers(0, cfg.vocab_size,
                          (SHARD_PREFIX_LEN,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (t,)
                                            ).astype(np.int32)])
               for t in (5, 12, 9, 3)]

    eng = GenerationEngine(m, params, **DISAGG_KW)
    rids = [eng.submit(p, DISAGG_NEW, prefix_id="sys") for p in prompts]
    out = eng.drain()
    ref = [[int(t) for t in out[r]] for r in rids]

    legs = [("same", None, None)]
    if jax.device_count() >= 2:
        legs.append(("decode_mesh2", None, serving_mesh(2)))
    identical = True
    handoffs = wire_bytes = 0
    aliased = pages = 0.0
    for tag, pmesh, dmesh in legs:
        ctrl = DisaggController(m, params, handoff_min_tokens=1,
                                prefill_mesh=pmesh, decode_mesh=dmesh,
                                **DISAGG_KW)
        crids = [ctrl.submit(p, DISAGG_NEW, prefix_id="sys")
                 for p in prompts]
        got = ctrl.drain()
        identical &= [[int(t) for t in got[r]] for r in crids] == ref
        st = ctrl.stats()
        handoffs += st.handoffs
        wire_bytes += st.wire_bytes
        aliased += st.aliased_pages
        pages += st.handoff_pages
    identity["disagg_vs_unified"] = identical

    # mixed burst on the decode clock: unified vs disagg, quiet vs convoy
    shorts = [rng.integers(0, cfg.vocab_size,
                           (DISAGG_SHORT,)).astype(np.int32)
              for _ in range(3)]
    longs = [rng.integers(0, cfg.vocab_size,
                          (DISAGG_LONG,)).astype(np.int32)
             for _ in range(2)]
    # unified baseline = one-shot prefill, as in `run_convoy`: the long
    # admission is one monolithic dispatch the decode clock waits out.
    # (The chunked unified engine bounds that stall to a chunk — but at
    # smoke scale every dispatch costs ~the same weight-streaming time,
    # so chunk-vs-decode contrast is invisible on CPU; the structural
    # claim measured here is prefill LEAVING the decode clock entirely.)
    # spec off on both sides: the one-shot path can't speculate, and
    # uniform decode gaps make the stall comparison apples-to-apples
    conv_kw = {k: v for k, v in DISAGG_KW.items()
               if not k.startswith("spec_")}
    uni = GenerationEngine(m, params,
                           **dict(conv_kw, chunked_prefill=False))
    uni.warmup()
    _disagg_warm(uni, longs[0], shorts[0])
    u_conv = _disagg_burst(uni, shorts, 24, longs, 6, decode_clock=False)
    ctrl = DisaggController(m, params, handoff_min_tokens=32, **conv_kw)
    ctrl.warmup()
    _disagg_warm(ctrl, longs[0], shorts[0])
    d_quiet = _disagg_burst(ctrl, shorts, 24, [], 6, decode_clock=True)
    ctrl.reset_stats()
    d_conv = _disagg_burst(ctrl, shorts, 24, longs, 6, decode_clock=True)
    cst = ctrl.stats()
    rep = disagg_report(cfg, decode_batch=DISAGG_KW["num_slots"],
                        context=DISAGG_KW["max_seq"], quant=True)
    tps = {k: r["decode_toks"] / max(r["decode_s"], 1e-9)
           for k, r in (("quiet", d_quiet), ("convoy", d_conv))}
    u_tps = u_conv["decode_toks"] / max(u_conv["decode_s"], 1e-9)

    csv_rows.extend([
        ("serving/disagg_token_identity", str(identical),
         "prefill→handoff→decode ≡ unified "
         f"({'+'.join(t for t, _, _ in legs)})"),
        ("serving/disagg_wire_kib_per_handoff",
         f"{wire_bytes / max(handoffs, 1) / 1024:.1f}",
         "decode-side TTFT is this transfer (int8 codes + scales)"),
        ("serving/disagg_adopt_ms_per_handoff",
         f"{cst.adopt_time_s / max(cst.handoffs, 1) * 1e3:.2f}",
         "wire + scatter into the decode pool, steady state (movers "
         "compiled)"),
        ("serving/disagg_aliased_page_frac",
         f"{aliased / max(pages, 1):.2f}",
         "handoff pages deduped against the decode pool's prefix index"),
        ("serving/disagg_decode_stall_unified_s",
         f"{u_conv['stall']:.3f}",
         "worst short-request token gap, decode clock, convoy burst"),
        ("serving/disagg_decode_stall_disagg_s",
         f"{d_conv['stall']:.3f}",
         "long prefill lives on the other engine"),
        ("serving/disagg_decode_tps_quiet", f"{tps['quiet']:.1f}",
         "short-request decode-side tok/s, no long prefill in flight"),
        ("serving/disagg_decode_tps_convoy", f"{tps['convoy']:.1f}",
         f"same burst + {DISAGG_LONG}-token prefill convoy "
         f"(unified: {u_tps:.1f})"),
        ("serving/disagg_predicted_crossover_tokens",
         str(rep["crossover_prompt_tokens"]),
         f"roofline: prefill {rep['prefill_bound']}-bound at "
         f"{rep['prefill_intensity']:.0f} F/B, decode "
         f"{rep['decode_bound']}-bound at "
         f"{rep['decode_intensity']:.0f} F/B"),
    ])
    return {"identical": identical, "handoffs": handoffs,
            "wire_bytes": wire_bytes,
            "convoy_handoffs": cst.handoffs, "direct": cst.direct,
            "stall": {"unified": u_conv["stall"],
                      "disagg": d_conv["stall"]},
            "decode_tps": {"quiet": tps["quiet"], "convoy": tps["convoy"],
                           "unified_convoy": u_tps},
            "crossover_pred": rep["crossover_prompt_tokens"]}


# ---------------------------------------------------------------------------
# Compression × speed: the AWQ W4 weight stream through the serving grid
# ---------------------------------------------------------------------------

AWQ_FEATURES = {
    "plain": {},
    "int8": {"kv_quant": "int8"},
    "prefix": {},                       # prefix_id at submit time
    "spec": {"spec_decode": "ngram", "spec_k": SPEC_K},
}
AWQ_SMOKE_FEATURES = ("plain", "spec")


def run_awq(m, params, csv_rows, identity, smoke=False):
    """Float vs AWQ-W4 params through the serving feature grid.

    Three parts, all through the PUBLIC engine API:

      * **identity battery** — the quantized engine streams greedy tokens
        under the Pallas kernel (interpret mode) and under the pure-jnp
        ``ref`` oracle through the FULL feature stack (chunked + int8 KV +
        prefix sharing + ngram spec); the comparison registers as a gated
        identity section.
      * **weight-stream accounting** — ``stats().weight_bytes_per_token``
        for float vs packed params: the bytes one decode step streams per
        emitted token, the quantity the paper's INT4 compression targets
        (reported next to the KV bytes/token column `run_kv_quant` owns).
      * **ms-per-token grid** — float vs AWQ × feature cells, separate
        prefill and decode probes (untimed compile pass first, engine
        reused so only the probes are timed). Off-TPU the AWQ cells run
        the jnp dequant oracle — the grid is then a correctness-shaped
        cost model; the kernel regime needs a TPU backend.
    """
    import jax.numpy as jnp

    import repro.core.qlinear as Q
    from repro.core import quantize_params
    cfg = m.cfg
    qp, report = quantize_params(params)
    assert report.quantized, "config has no quantizable linears"

    # --- identity battery: Pallas kernel vs jnp oracle, full stack -------
    rng = np.random.default_rng(19)
    id_prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    id_prompts = [np.concatenate([id_prefix,
                                  rng.integers(0, cfg.vocab_size, (t,)
                                               ).astype(np.int32)])
                  for t in (5, 12, 9, 3)]

    def _streams(impl):
        Q.set_execution_config(impl=impl, compute_dtype=jnp.float32)
        eng = _fresh_engine(m, qp, max_seq=64, num_slots=4, page_size=8,
                            prefill_chunk=4, kv_quant="int8",
                            spec_decode="ngram", spec_k=SPEC_K)
        rids = [eng.submit(p, 10, prefix_id="sys") for p in id_prompts]
        out = eng.drain()
        return [list(out[r]) for r in rids]

    prev = Q.get_execution_config()
    try:
        identical = _streams("ref") == _streams("kernel_interpret")
    finally:
        Q._EXEC = prev
    identity["awq_kernel_vs_ref"] = identical
    csv_rows.append(("serving/awq_token_identity", str(identical),
                     "AWQ kernel ≡ jnp ref through chunked+int8+prefix+spec"))

    # --- weight stream accounting ----------------------------------------
    wb = {}
    for tag, pp in (("float", params), ("awq", qp)):
        st = _fresh_engine(m, pp).stats()
        wb[tag] = st.weight_bytes
        csv_rows.append(
            (f"serving/weight_bytes_per_token_{tag}",
             f"{st.weight_bytes_per_token:.0f}",
             "weight bytes streamed per decoded token "
             "(whole model per step until spec amortizes it)"))
    csv_rows.append(
        ("serving/awq_weight_bytes_reduction",
         f"{1 - wb['awq'] / wb['float']:.1%}",
         f"{wb['float']} -> {wb['awq']} model bytes"))

    # --- compression × speed grid -----------------------------------------
    feats = AWQ_SMOKE_FEATURES if smoke else tuple(AWQ_FEATURES)
    prefill_len = 24 if smoke else 64
    decode_new = 8 if smoke else 24
    n_req = 2 if smoke else 4
    grid = {}
    for ptag, pp in (("float", params), ("awq", qp)):
        for feat in feats:
            rng = np.random.default_rng(23)
            if feat == "spec":
                pat = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
                short = [np.tile(pat, 2) for _ in range(n_req)]
            else:
                short = [rng.integers(0, cfg.vocab_size, (8,)
                                      ).astype(np.int32)
                         for _ in range(n_req)]
            pref = rng.integers(0, cfg.vocab_size, (prefill_len - 8,)
                                ).astype(np.int32)
            long_ = [np.concatenate([pref,
                                     rng.integers(0, cfg.vocab_size, (8,)
                                                  ).astype(np.int32)])
                     for _ in range(n_req)]
            pid = "sys" if feat == "prefix" else None
            eng = _fresh_engine(m, pp, **AWQ_FEATURES[feat])

            def _drain(prompts, new, prefix_id=None):
                for p in prompts:
                    eng.submit(p, new, prefix_id=prefix_id)
                t0 = time.perf_counter()
                eng.drain()
                return time.perf_counter() - t0

            _drain(long_, 1, pid)               # untimed: compiles, and for
            _drain(short, decode_new)           # "prefix" registers the pages
            pre_ms = _drain(long_, 1, pid) * 1e3 / (n_req * prefill_len)
            dec_ms = _drain(short, decode_new) * 1e3 / (n_req * decode_new)
            grid[f"{ptag}/{feat}"] = {"prefill_ms_per_tok": pre_ms,
                                      "decode_ms_per_tok": dec_ms}
            csv_rows.extend([
                (f"serving/awq_grid_{ptag}_{feat}_prefill_ms_per_tok",
                 f"{pre_ms:.2f}",
                 f"{n_req} reqs x {prefill_len}-token prompts"),
                (f"serving/awq_grid_{ptag}_{feat}_decode_ms_per_tok",
                 f"{dec_ms:.2f}",
                 f"{n_req} reqs x {decode_new} new tokens"),
            ])
    return {"identical": identical, "weight_bytes": wb, "grid": grid}


# ---------------------------------------------------------------------------
# Tiered SLO: priority preemption + KV page spill under overload
# ---------------------------------------------------------------------------

SLO_HOLD_STEPS = 4          # dispatches the low tier runs alone before the
                            # interactive tier arrives (mid-decode overload)
SLO_STEP_CAP = 5000         # drain-loop fuse: a wedged scheduler raises in
                            # `run()`, this bounds a hypothetical step leak


def _serve_tiered(eng, lo_reqs, hi_reqs, hold_steps=SLO_HOLD_STEPS):
    """Submit the low tier, let it hold the engine for ``hold_steps``
    dispatches, then submit the interactive tier and step to drain.

    Interactive TTFT is counted in *dispatch steps since submission*:
    greedy decode makes step counts a pure function of the schedule, so
    the gate can assert "preemption held TTFT down" deterministically —
    the wall-clock numbers are reported alongside for scale.
    Returns (streams, ttft_steps, ttft_wall, stats).
    """
    lo = [eng.submit(p, mn, priority=0) for p, mn in lo_reqs]
    for _ in range(hold_steps):
        eng.step()
    hi = [eng.submit(p, mn, priority=1) for p, mn in hi_reqs]
    hi_pending = set(hi)
    first_step, first_wall = {}, {}
    streams: dict[int, list] = {}
    step = 0
    t0 = time.perf_counter()
    while not eng.idle:
        events = eng.step()
        step += 1
        assert step <= SLO_STEP_CAP, "tiered-SLO drain did not converge"
        now = time.perf_counter() - t0
        for rid, _tok in events:
            if rid in hi_pending:
                hi_pending.discard(rid)
                first_step[rid] = step
                first_wall[rid] = now
        for rid, toks in eng.collect().items():
            streams[rid] = [int(t) for t in toks]
    return ({r: streams[r] for r in lo + hi},
            [first_step[r] for r in hi],
            [first_wall[r] for r in hi], eng.stats())


def _matches_generate(eng, streams, reqs_by_rid):
    """Every served stream ≡ an uninterrupted per-request `generate()`."""
    import jax.numpy as jnp
    for rid, (p, mn) in reqs_by_rid.items():
        ref = np.asarray(
            eng.generate({"tokens": jnp.asarray(p)[None, :]}, mn)[0])
        if streams[rid] != [int(t) for t in ref[: len(streams[rid])]]:
            return False
    return True


def run_slo(m, params, csv_rows, identity, smoke=False):
    """Tiered-SLO overload: priority preemption + KV page spill.

    Two overload shapes, each served with and without the new machinery,
    TTFT compared in deterministic dispatch steps:

      * **slot contention** — every slot of a 2-slot engine is held by
        low-priority batch decodes when two interactive requests arrive.
        Baseline: they convoy behind a full batch budget. Preemption:
        the scheduler spills a victim's committed KV pages to the host
        tier, serves the interactive tier, then restores the victim at
        its commit watermark (zero prefill recompute).
      * **long-context reservation** — one long-budget request's
        worst-case page reservation starves every short request under
        conservative admission (the 32k-convoy problem at smoke scale).
        Optimistic admission books only what is committed, admits the
        shorts immediately, and relieves later pool pressure by
        spilling the long request.

    All preempted streams are asserted token-identical to uninterrupted
    per-request ``generate()`` references — the "spill/restore changed
    no bytes" identity section the gate requires.
    """
    cfg = m.cfg
    rng = np.random.default_rng(31)

    def _reqs(n, plen, mn):
        return [(rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
                 mn) for _ in range(n)]

    res: dict = {}
    token_identity = True

    # --- scenario 1: slot contention ------------------------------------
    lo_budget = 32 if smoke else 64
    lo_reqs = _reqs(2, 12, lo_budget)
    hi_reqs = _reqs(2, 6, 4)
    contention = {}
    for tag, kw in (("preempt", {"preemption": True}), ("base", {})):
        eng = _fresh_engine(m, params, num_slots=2, **kw)
        eng.warmup()
        streams, tsteps, twall, st = _serve_tiered(eng, lo_reqs, hi_reqs)
        if tag == "preempt":
            rids = list(streams)
            reqs = dict(zip(rids, lo_reqs + hi_reqs))
            token_identity &= _matches_generate(eng, streams, reqs)
        contention[tag] = {
            "ttft_steps_p95": float(np.percentile(tsteps, 95)),
            "ttft_wall_p95": float(np.percentile(twall, 95)),
            "preemptions": st.preemptions, "restores": st.restores,
            "spilled_pages": st.spilled_pages,
            "restore_ms_mean": st.restore_ms_mean,
        }
    res["contention"] = contention

    # --- scenario 2: long-context reservation convoy --------------------
    # the long request's worst-case reservation ≈ the whole pool; sized so
    # conservative admission blocks every short until the long finishes
    if smoke:
        long_plen, long_mn, max_seq = 12, 61, MAX_SEQ
        n_short, short_mn = 3, 16
    else:
        long_plen, long_mn, max_seq = 64, 256, 384
        # exactly the free slots (more would slot-preempt the long and
        # park it before the pool ever dries), with budgets long enough
        # to still be decoding when the long's growing footprint crosses
        # the pool (~step 66 of their 90): pressure must relieve by
        # spilling the long, not by it finishing first
        n_short, short_mn = NUM_SLOTS - 1, 90
    long_pages = -(-(long_plen + long_mn - 1) // PAGE_SIZE)
    num_pages = long_pages + 2           # +1 scratch, +1 free: shorts need
    long_req = _reqs(1, long_plen, long_mn)     # 2+ pages -> blocked
    short_reqs = _reqs(n_short, 6, short_mn)
    longctx = {}
    for tag, kw in (("optimistic", {"preemption": True,
                                    "admission": "optimistic"}),
                    ("reserved", {})):
        eng = _fresh_engine(m, params, max_seq=max_seq, num_pages=num_pages,
                            **kw)
        eng.warmup()
        streams, tsteps, twall, st = _serve_tiered(eng, long_req, short_reqs)
        if tag == "optimistic":
            rids = list(streams)
            reqs = dict(zip(rids, long_req + short_reqs))
            token_identity &= _matches_generate(eng, streams, reqs)
        longctx[tag] = {
            "ttft_steps_p95": float(np.percentile(tsteps, 95)),
            "ttft_wall_p95": float(np.percentile(twall, 95)),
            "pressure_spills": st.pressure_spills,
            "preemptions": st.preemptions, "restores": st.restores,
        }
    res["longctx"] = longctx
    res["token_identity"] = token_identity
    identity["preempt_vs_uninterrupted"] = token_identity

    pre, base = contention["preempt"], contention["base"]
    opt, rsv = longctx["optimistic"], longctx["reserved"]
    csv_rows.extend([
        ("serving/slo_interactive_ttft_steps_p95_preempt",
         f"{pre['ttft_steps_p95']:.0f}",
         "dispatch steps from arrival to first token, 2 slots fully held "
         "by low-priority decodes"),
        ("serving/slo_interactive_ttft_steps_p95_base",
         f"{base['ttft_steps_p95']:.0f}",
         "no preemption: convoys behind the whole batch budget"),
        ("serving/slo_interactive_ttft_wall_p95_preempt_s",
         f"{pre['ttft_wall_p95']:.3f}", ""),
        ("serving/slo_interactive_ttft_wall_p95_base_s",
         f"{base['ttft_wall_p95']:.3f}", ""),
        ("serving/slo_preemptions", str(pre["preemptions"]),
         f"{pre['spilled_pages']} page strips spilled to the host tier"),
        ("serving/slo_restores", str(pre["restores"]),
         f"{pre['restore_ms_mean']:.2f} ms mean restore latency, resumed "
         f"at the commit watermark (zero recompute)"),
        ("serving/slo_longctx_ttft_steps_p95_optimistic",
         f"{opt['ttft_steps_p95']:.0f}",
         f"{long_plen}+{long_mn}-token request in a {num_pages}-page pool"),
        ("serving/slo_longctx_ttft_steps_p95_reserved",
         f"{rsv['ttft_steps_p95']:.0f}",
         "worst-case reservation starves the shorts until the long ends"),
        ("serving/slo_longctx_pressure_spills",
         str(opt["pressure_spills"]),
         "optimistic over-admission relieved by spilling the long request"),
        ("serving/slo_token_identity", str(token_identity),
         "preempted/spilled streams ≡ uninterrupted generate()"),
    ])
    return res


# ---------------------------------------------------------------------------
# Multi-replica fleet: prefix-affinity router
# ---------------------------------------------------------------------------

def make_cluster_workload(cfg, n_clusters=2, num_requests=8, prefix_len=32,
                          new_tokens=8, rate=ARRIVAL_RATE, seed=11):
    """Clustered-prefix Poisson burst: request ``i`` belongs to cluster
    ``i % n_clusters`` and shares that cluster's page-aligned system
    prefix. Returns (prefixes, [(arrival, prompt, max_new, prefix_id)])."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, (prefix_len,)
                             ).astype(np.int32) for _ in range(n_clusters)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate, num_requests))
    reqs = []
    for i in range(num_requests):
        c = i % n_clusters
        tail = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
        reqs.append((float(arrivals[i]),
                     np.concatenate([prefixes[c], tail]),
                     new_tokens, f"cluster{c}"))
    return prefixes, reqs


def _warm_fleet(fleet, prefixes):
    """Pin every cluster prefix (sticky), run one request per cluster
    through the fleet so the pages are resident, and zero the stats so
    the timed burst reports only itself."""
    for c, pfx in enumerate(prefixes):
        fleet.pin_prefix(f"cluster{c}")
        fleet.submit(np.concatenate(
            [pfx, np.full((4,), c + 1, np.int32)]), 2,
            prefix_id=f"cluster{c}")
    fleet.drain()
    fleet.reset_stats()


def _run_fleet(router, workload):
    """Replay a clustered workload through a Router; same contract as
    `run_continuous` but fleet-wide (skipped = sum over replicas)."""
    pending = sorted(enumerate(workload), key=lambda r: r[1][0])
    arrival_of, first, finish = {}, {}, {}
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i][1][0] <= now:
            _, (arrival, prompt, mn, pid) = pending[i]
            rid = router.submit(prompt, mn, prefix_id=pid)
            arrival_of[rid] = arrival
            i += 1
        events = router.step()
        now = time.perf_counter() - t0
        for rid, _tok in events:
            if rid in arrival_of and rid not in first:
                first[rid] = now
        for rid in router.collect():
            finish[rid] = now
        if len(finish) == len(workload):
            break
        if i < len(pending) and router.idle:
            time.sleep(0.0005)
    dt = time.perf_counter() - t0
    useful = sum(mn for _, _, mn, _ in workload)
    skipped = sum(getattr(s, "prefill_tokens_skipped", 0)
                  for s in router.stats())
    return {"useful": useful, "dt": dt, "tps": useful / dt,
            "ttft_p95": float(np.percentile(
                [first[r] - arrival_of[r] for r in first], 95)),
            "skipped": int(skipped)}


def run_router(m, params, csv_rows, identity, smoke=False):
    """Multi-replica serving fleet through the prefix-affinity `Router`.

    Four measurements:

      * **router_vs_single** (gated identity) — the same burst through a
        bare engine and a 1-replica fleet must produce byte-identical
        greedy streams: the router adds placement, never changes tokens.
      * **affinity vs. random** — two warmed 2-replica fleets serve the
        clustered burst; affinity placement routes each cluster to the
        replica already holding its prefix pages and must skip strictly
        more prefill tokens than seeded-random placement (tok/s asserted
        at full scale, where the skipped work dominates wall clock).
      * **throughput vs. replica count** — the same warmed burst through
        fleets of 1/2(/4 at full scale); informational on one host
        (replicas share the device), the scaling story is the row.
      * **drain under load** — submit 2x the burst to the 2-replica
        fleet, step a few times, `drain_replica(0)` mid-flight, then
        drain the fleet: every stream must come back exactly once and
        byte-equal to its bare-engine reference (zero loss, zero
        duplication), with rerouted-request count and drain-phase TTFT
        reported.
    """
    cfg = m.cfg
    n_req = 8 if smoke else NUM_REQUESTS
    mn = 8 if smoke else 16
    # full scale doubles the shared prefix (8 pages): the skipped
    # prefill has to dominate wall-clock noise for the tok/s assert
    prefixes, workload = make_cluster_workload(
        cfg, num_requests=n_req, new_tokens=mn,
        prefix_len=32 if smoke else 64)
    res: dict = {"topology": {}}

    # --- 1-replica fleet ≡ bare engine (gated identity) ---------------
    eng_ref = _fresh_engine(m, params)
    eng_ref.warmup()
    rids = [eng_ref.submit(p, mn_, prefix_id=pid)
            for _, p, mn_, pid in workload]
    refs = eng_ref.drain()
    ref_streams = [list(refs[r]) for r in rids]
    fleet1 = Router([_fresh_engine(m, params)])
    fleet1.warmup()
    grids = [fleet1.submit(p, mn_, prefix_id=pid)
             for _, p, mn_, pid in workload]
    fout = fleet1.drain()
    identical = [list(fout[g]) for g in grids] == ref_streams
    identity["router_vs_single"] = identical
    res["identical"] = identical

    # --- affinity vs random placement (both fleets warmed + pinned) ---
    fleets = {}
    for policy in ("affinity", "random"):
        fleet = Router([_fresh_engine(m, params) for _ in range(2)],
                       placement=policy, seed=7)
        fleet.warmup()
        _warm_fleet(fleet, prefixes)
        r = _run_fleet(fleet, workload)
        r["affinity_hits"] = fleet.router_stats.affinity_hits
        res[policy] = r
        fleets[policy] = fleet

    # --- throughput vs replica count ----------------------------------
    # the 2-replica number is the affinity fleet's run above; 1 (and 4,
    # at full scale) get their own warmed fleets so every size pays the
    # same pre-warm
    scale = {2: res["affinity"]["tps"]}
    sizes = (1,) if smoke else (1, 4)
    for n in sizes:
        fleet = Router([_fresh_engine(m, params) for _ in range(n)])
        fleet.warmup()
        _warm_fleet(fleet, prefixes)
        scale[n] = _run_fleet(fleet, workload)["tps"]
    res["scale_tps"] = {str(k): v for k, v in sorted(scale.items())}
    res["topology"] = {
        "fleet_sizes": sorted(scale), "mesh_axis": 1,
        "devices": jax.device_count(),
    }

    # --- elastic drain under load: zero loss, zero duplication --------
    fleet = fleets["affinity"]
    both = workload + [(a, p, mn_, pid) for a, p, mn_, pid in workload]
    drids = [fleet.submit(p, mn_, prefix_id=pid) for _, p, mn_, pid in both]
    t0 = time.perf_counter()
    first: dict[int, float] = {}
    for _ in range(3):                  # work is genuinely in flight
        for rid, _tok in fleet.step():
            first.setdefault(rid, time.perf_counter() - t0)
    for rid, _tok in fleet.drain_replica(0):
        first.setdefault(rid, time.perf_counter() - t0)
    dout = fleet.drain()
    streams = [list(dout[r]) for r in drids if r in dout]
    want = ref_streams + ref_streams    # greedy ⇒ placement-independent
    res["drain"] = {
        "lost": len(drids) - len(streams),
        "duplicated": len(dout) - len(set(dout)),
        "identical": streams == want,
        "reroutes": fleet.router_stats.reroutes,
        "drain_ttft_p95": float(np.percentile(
            [first[r] for r in first], 95)) if first else 0.0,
    }

    aff, rnd = res["affinity"], res["random"]
    csv_rows.extend([
        ("serving/router_affinity_tps", f"{aff['tps']:.1f}",
         f"2 replicas, {n_req}-request clustered burst, "
         f"{aff['affinity_hits']} affinity hits"),
        ("serving/router_random_tps", f"{rnd['tps']:.1f}",
         "same burst, seeded-random placement"),
        ("serving/router_affinity_prefill_skipped", str(aff["skipped"]),
         "prompt tokens never recomputed (placed onto warm pages)"),
        ("serving/router_random_prefill_skipped", str(rnd["skipped"]),
         "random placement misses the warm replica about half the time"),
        ("serving/router_scale_tps",
         " ".join(f"{k}x:{v:.1f}" for k, v in sorted(res["scale_tps"]
                                                     .items())),
         "sustained tok/s vs replica count (one host: informational)"),
        ("serving/router_identity", str(identical),
         "1-replica fleet ≡ bare engine (greedy streams)"),
        ("serving/router_drain_reroutes", str(res["drain"]["reroutes"]),
         "queued requests moved off the draining replica, rids kept"),
        ("serving/router_drain_ttft_p95_s",
         f"{res['drain']['drain_ttft_p95']:.3f}",
         "TTFT across the drain-under-load burst"),
        ("serving/router_drain_zero_loss",
         str(res["drain"]["lost"] == 0 and res["drain"]["identical"]),
         "every stream delivered exactly once, byte-equal to references"),
    ])
    return res


def run(csv_rows: list, smoke: bool = False) -> dict:
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    identity: dict = {}   # section name → asserted comparison outcome

    if smoke:
        # tier-1 end-to-end gate: small burst through the chunked engine,
        # identity vs one-shot + generate(), prefix-FLOP accounting, one
        # speculative-decode burst, one sharded burst
        workload = make_workload(cfg, num_requests=6,
                                 budgets=(24, 6, 8, 6, 12, 8))
        identical = verify_token_identity(m, params, workload[:3], identity)
        eng_cont = _fresh_engine(m, params)
        r = run_continuous(eng_cont, workload)
        pack = _padding_rows(eng_cont.stats(), csv_rows,
                             tag="serving/smoke_padding")
        kv = run_kv_quant(m, params, csv_rows)
        prefix = run_prefix_sharing(m, params, csv_rows, prefix_len=32,
                                    num_requests=3, new_tokens=8)
        spec = run_spec(m, params, csv_rows, identity, num_requests=4,
                        new_tokens=12, tag_prefix="serving/smoke_spec")
        tree = run_tree_spec(m, params, csv_rows, identity, num_requests=4,
                             new_tokens=12, tag_prefix="serving/smoke_tree")
        par = run_parallel(m, params, csv_rows, identity, new_tokens=8,
                           tag_prefix="serving/smoke_parallel")
        sharded = run_sharded(csv_rows, identity)
        disagg = run_disagg(csv_rows, identity, smoke=True)
        awq = run_awq(m, params, csv_rows, identity, smoke=True)
        slo = run_slo(m, params, csv_rows, identity, smoke=True)
        router = run_router(m, params, csv_rows, identity, smoke=True)
        csv_rows.extend([
            ("serving/smoke_sustained_tps", f"{r['useful'] / r['dt']:.1f}",
             f"{r['useful']} tokens, {r['steps']} unified dispatches"),
            ("serving/smoke_ttft_p95_s",
             f"{np.percentile(r['ttfts'], 95):.3f}", ""),
            ("serving/smoke_token_identity", str(identical),
             "chunked ≡ one-shot ≡ generate()"),
        ])
        return {"token_identical": identical, "spec": spec, "tree": tree,
                "parallel": par, "padding": pack, "sharded": sharded,
                "disagg": disagg, "awq": awq, "slo": slo, "router": router,
                "identity_sections": identity, **kv, **prefix}

    workload = make_workload(cfg)
    su, sl, ss, sdt = run_static(_fresh_engine(m, params), workload)
    eng_cont = _fresh_engine(m, params)
    r = run_continuous(eng_cont, workload)
    cu, cl, ct, cs, cdt = (r["useful"], r["latencies"], r["ttfts"],
                           r["steps"], r["dt"])
    pack = _padding_rows(eng_cont.stats(), csv_rows)
    identical = verify_token_identity(m, params, workload, identity)
    convoy = run_convoy(m, params, csv_rows)
    kv = run_kv_quant(m, params, csv_rows)
    prefix = run_prefix_sharing(m, params, csv_rows)
    spec = run_spec(m, params, csv_rows, identity)
    tree = run_tree_spec(m, params, csv_rows, identity)
    par = run_parallel(m, params, csv_rows, identity)
    sharded = run_sharded(csv_rows, identity)
    disagg = run_disagg(csv_rows, identity)
    awq = run_awq(m, params, csv_rows, identity)
    slo = run_slo(m, params, csv_rows, identity)
    router = run_router(m, params, csv_rows, identity)

    s_tps, c_tps = su / sdt, cu / cdt
    rows = [
        ("serving/static_sustained_tps", f"{s_tps:.1f}",
         f"{su} tokens, {ss} decode steps"),
        ("serving/continuous_sustained_tps", f"{c_tps:.1f}",
         f"{cu} tokens, {cs} unified dispatches"),
        ("serving/continuous_speedup", f"{c_tps / s_tps:.2f}x",
         "sustained tok/s vs static batch"),
        ("serving/static_p50_latency_s", f"{np.percentile(sl, 50):.3f}", ""),
        ("serving/static_p95_latency_s", f"{np.percentile(sl, 95):.3f}", ""),
        ("serving/continuous_p50_latency_s",
         f"{np.percentile(cl, 50):.3f}", ""),
        ("serving/continuous_p95_latency_s",
         f"{np.percentile(cl, 95):.3f}", ""),
        ("serving/continuous_ttft_p50_s", f"{np.percentile(ct, 50):.3f}", ""),
        ("serving/continuous_ttft_p95_s", f"{np.percentile(ct, 95):.3f}", ""),
        ("serving/greedy_token_identity", str(identical),
         "chunked ≡ one-shot ≡ sequential generate()"),
    ]
    csv_rows.extend(rows)
    return {"static_tps": s_tps, "continuous_tps": c_tps,
            "speedup": c_tps / s_tps,
            "static_p95": float(np.percentile(sl, 95)),
            "continuous_p95": float(np.percentile(cl, 95)),
            "ttft_p95": float(np.percentile(ct, 95)),
            "token_identical": identical, "spec": spec, "tree": tree,
            "parallel": par, "padding": pack,
            "sharded": sharded, "disagg": disagg, "awq": awq, "slo": slo,
            "router": router,
            "identity_sections": identity, **convoy, **kv, **prefix}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run for the tier-1 gate")
    ap.add_argument("--history-file", default=None,
                    help="tracked run-history JSON (default: repo-root "
                         "BENCH_serving.json); every run appends a record")
    args = ap.parse_args()
    rows: list = []
    out = run(rows, smoke=args.smoke)
    for r in rows:
        print(",".join(str(x) for x in r))
    # tracked history: append a schema'd record BEFORE any gate can exit,
    # so failed runs leave evidence too (run_tier1 gates on this file)
    hist_path = pathlib.Path(args.history_file) if args.history_file else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"
    # provenance stamp: which code produced this record (the trajectory
    # gate compares adjacent records — a regression should name a commit)
    try:
        git_commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=pathlib.Path(__file__).resolve().parent,
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        git_commit = "unknown"
    record = {
        "schema": 1,
        "timestamp": time.time(),
        "smoke": bool(args.smoke),
        "git_commit": git_commit,
        "jax_version": jax.__version__,
        "jax_devices": jax.device_count(),
        "metrics": {name: value for name, value, _ in rows},
        "identity_sections": out.get("identity_sections", {}),
        "awq": {"weight_bytes": out["awq"]["weight_bytes"],
                "grid": out["awq"]["grid"]},
        "replica_topology": out["router"]["topology"],
    }
    try:
        history = json.loads(hist_path.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        history = []
    if not isinstance(history, list):
        history = []
    history.append(record)
    hist_path.write_text(json.dumps(history, indent=1) + "\n")
    print(f"HISTORY appended to {hist_path} ({len(history)} records)")
    # the skip guard: every asserted identity section must have RUN and
    # passed — a section that was silently skipped leaves its key missing,
    # which fails the gate just like a mismatch would
    sections = out.get("identity_sections", {})
    bad = [k for k in REQUIRED_IDENTITY if sections.get(k) is not True]
    if bad:
        print(f"IDENTITY-SECTIONS missing or failed: {bad} "
              f"(ran: {sections})", file=sys.stderr)
        sys.exit(1)
    print(f"IDENTITY-SECTIONS ok: {sorted(sections)}")
    assert out["token_identical"]
    assert out["kv_bytes_reduction"] >= 0.40
    # sharded pools must actually stripe: with >1 device the per-device
    # bytes at the largest axis shrink by the axis size (exactly linear —
    # Hkv divides), and streams matched (asserted via identity sections)
    sh = out["sharded"]
    if len(sh["sizes"]) > 1:
        lo, hi = sh["sizes"][0], sh["sizes"][-1]
        # global footprint is axis-invariant …
        assert sh["bytes_per_device"][hi] * hi \
            == sh["bytes_per_device"][lo] * lo
        # … so per-device bytes shrink linearly with the axis size
        assert sh["bytes_per_device"][hi] \
            == sh["bytes_per_device"][lo] * lo // hi
    # prefix-aware chunked prefill must actually skip the aliased pages
    assert out["prefix_chunked"]["skipped"] > 0
    assert out["prefix_chunked"]["prefill_tokens"] \
        < out["prefix_unshared"]["prefill_tokens"]
    # speculative decoding: greedy streams never change, the drafter
    # actually fires, and accounting stays sane
    assert out["spec"]["identical"]
    assert out["spec"]["spec"]["drafted"] > 0
    assert 0 <= out["spec"]["spec"]["accepted"] \
        <= out["spec"]["spec"]["drafted"]
    # tree speculation: the ngram tree drafter actually fired, and on the
    # branchy burst the depth-1 hedge beats linear speculation outright —
    # the same streams in strictly fewer weight passes
    tr = out["tree"]
    assert tr["identical"]
    assert tr["tree"]["drafted"] > 0
    assert 0 <= tr["tree"]["accepted"] <= tr["tree"]["drafted"]
    assert tr["branchy"]["tree"]["steps"] < tr["branchy"]["linear"]["steps"]
    assert tr["branchy"]["tree"]["tokens_per_step"] \
        > tr["branchy"]["linear"]["tokens_per_step"]
    # parallel sampling: siblings alias prompt pages and skip aliased
    # prefill; n separate submissions alias nothing
    par = out["parallel"]
    assert par["identical"]
    assert par["shared_pages"] > 0 and par["sep_shared"] == 0
    assert par["skipped"] > 0
    # run-length packing can only remove padding vs the fixed-width policy
    assert out["padding"]["waste"] <= out["padding"]["waste_fixed"] + 1e-9
    # the packed weight stream must actually be smaller than the float one
    assert out["awq"]["weight_bytes"]["awq"] \
        < out["awq"]["weight_bytes"]["float"]
    # tiered SLO (deterministic step-count TTFT, so smoke can assert it):
    # preemption actually fired, restores balanced, and the interactive
    # tier's p95 TTFT beat the no-preemption convoy — same for optimistic
    # admission vs the worst-case-reservation baseline
    slo = out["slo"]
    assert slo["contention"]["preempt"]["preemptions"] >= 1
    assert slo["contention"]["preempt"]["restores"] \
        == slo["contention"]["preempt"]["preemptions"]
    assert slo["contention"]["preempt"]["spilled_pages"] > 0
    assert slo["contention"]["preempt"]["ttft_steps_p95"] \
        < slo["contention"]["base"]["ttft_steps_p95"]
    assert slo["longctx"]["optimistic"]["ttft_steps_p95"] \
        < slo["longctx"]["reserved"]["ttft_steps_p95"]
    assert slo["longctx"]["optimistic"]["pressure_spills"] >= 1
    assert slo["token_identity"]
    # disaggregation: the handoff path actually carried pages (identity
    # is gated via REQUIRED_IDENTITY), routing split the convoy burst,
    # and the decode side saw bytes on the wire
    dg = out["disagg"]
    assert dg["handoffs"] >= 1 and dg["wire_bytes"] > 0
    assert dg["convoy_handoffs"] >= 1 and dg["direct"] >= 1
    # fleet routing: affinity placement must land clustered requests on
    # their warm replica — strictly more prefill tokens skipped than the
    # seeded-random fleet on the same burst — and the mid-flight
    # drain_replica must deliver every stream exactly once, byte-equal
    # to bare-engine references (zero loss, zero duplication)
    rt = out["router"]
    assert rt["affinity"]["skipped"] > rt["random"]["skipped"]
    assert rt["drain"]["lost"] == 0 and rt["drain"]["duplicated"] == 0
    assert rt["drain"]["identical"]
    if not args.smoke:
        # the headline claims: sharing saves FLOPs (not just memory),
        # TTFT p95 beats the one-shot baseline on the shared-prefix
        # burst, chunking bounds the convoy-effect decode stall, and on
        # the repetitive burst one weight pass emits > 1 token on average
        assert out["prefix_flops_saved"] > 0.5
        assert out["prefix_chunked"]["ttft_p95"] \
            < out["prefix_oneshot"]["ttft_p95"]
        assert out["convoy"]["chunked"]["short_stall_max"] \
            < out["convoy"]["oneshot"]["short_stall_max"]
        assert out["spec"]["spec"]["tokens_per_step"] > 1.0
        assert out["spec"]["spec"]["steps"] < out["spec"]["plain"]["steps"]
        # disaggregation's headline: with the long prefill exiled to the
        # other engine, the decode side's worst short-request stall drops
        # (measured on the decode clock — wall time can't see it on one
        # host). Smoke reports the same rows without asserting, like the
        # convoy section.
        assert out["disagg"]["stall"]["disagg"] \
            < out["disagg"]["stall"]["unified"]
        # routing's headline: the skipped prefill work shows up as
        # sustained throughput at full scale (smoke bursts are too
        # short for the wall clock to resolve it reliably on CPU)
        assert rt["affinity"]["tps"] > rt["random"]["tps"]
