"""Kernel-level benchmark: the fused dequant-MAC unit (paper §III-B).

No TPU in this container, so the numbers that matter are STRUCTURAL (the
same quantities Table II's synthesis reports): bytes streamed per weight,
VMEM working set per grid step, MXU tile alignment — plus interpret-mode
correctness timing as a smoke signal.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.packing import pack_linear
from repro.core.quantize import QuantConfig, quantize_groupwise
from repro.kernels.ops import awq_matmul, choose_blocks
from repro.kernels.ref import awq_matmul_ref

# paper-relevant shapes: qwen25-05b decode GEMV + prefill GEMM per linear
SHAPES = [
    ("decode_qkv", 1, 896, 1152),
    ("decode_ffn_gate", 1, 896, 4864),
    ("decode_ffn_down", 1, 4864, 896),
    ("prefill_ffn_gate", 256, 896, 4864),
]


def run(csv_rows: list) -> dict:
    out = {}
    gs = 64
    for name, m, k, n in SHAPES:
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
        cfg = QuantConfig(group_size=gs)
        p = pack_linear(*quantize_groupwise(w, cfg), None, None, cfg)
        bm, bn, bk = choose_blocks(m, k, n, gs)
        # streamed bytes per weight (the paper's bandwidth argument)
        wbytes = p.qweight.size * 4 + p.scales.size * 4 + p.zeros.size
        bits_per_w = wbytes * 8 / (k * n)
        vmem = bm * bk * 4 + bk // 8 * bn * 4 + 2 * (bk // gs) * bn * 4 \
            + bm * bn * 4
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
        t0 = time.perf_counter()
        y = awq_matmul(x, p, compute_dtype=jnp.float32, interpret=True)
        jax.block_until_ready(y)
        t_int = (time.perf_counter() - t0) * 1e6
        ref = awq_matmul_ref(x, p.qweight, p.scales, p.zeros, gs)
        err = float(jnp.abs(y - ref).max())
        csv_rows.append((f"kernel/{name}", f"{t_int:.0f}",
                         f"blocks=({bm},{bn},{bk}) vmem={vmem/2**20:.2f}MB "
                         f"bits/w={bits_per_w:.2f} err={err:.1e}"))
        out[name] = {"vmem_mb": vmem / 2 ** 20, "bits_per_w": bits_per_w,
                     "err": err}
        assert err < 1e-4
        assert vmem < 16 * 2 ** 20
        assert bn % 8 == 0 and bk % gs == 0
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
