"""Paper Table III (model size): 988 MB → 443.81 MB (55.1% reduction).

Byte-exact accounting of the full qwen2.5-0.5b config through the paper's
AWQ_MACRO serialization (GS=64): every quantizable linear at 4.5 bits/weight
(GS·8 INT4 qweights + 8 FP16 scales + 128-bit zeros strip per macro),
everything else fp16. Nothing is materialized — shapes come from
`jax.eval_shape` over the real `model.init`.

Also reports GS=128 and the per-component split, plus the same accounting
for every assigned architecture (compression is arch-agnostic — DESIGN §4).
"""
from __future__ import annotations

import jax

import repro.configs as C
from repro.core.pipeline import model_size_bytes
from repro.models import build_model

PAPER_BASELINE_MB = 988.0
PAPER_AWQ_MB = 443.81


def sizes_for(arch: str) -> dict:
    cfg = C.get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    base = model_size_bytes(shapes, quantized=False)
    q64 = model_size_bytes(shapes, quantized=True)
    from repro.core.quantize import QuantConfig
    q128 = model_size_bytes(shapes, quantized=True,
                            cfg=QuantConfig(group_size=128))
    return {"baseline_mb": base / 1e6, "awq_gs64_mb": q64 / 1e6,
            "awq_gs128_mb": q128 / 1e6,
            "reduction_pct": 100 * (1 - q64 / base)}


def run(csv_rows: list) -> dict:
    out = {}
    r = sizes_for("qwen25-05b")
    out["qwen25-05b"] = r
    csv_rows.append(("compression/qwen25-05b_baseline_mb",
                     f"{r['baseline_mb']:.2f}",
                     f"paper={PAPER_BASELINE_MB}"))
    csv_rows.append(("compression/qwen25-05b_awq_gs64_mb",
                     f"{r['awq_gs64_mb']:.2f}", f"paper={PAPER_AWQ_MB}"))
    csv_rows.append(("compression/qwen25-05b_reduction_pct",
                     f"{r['reduction_pct']:.2f}", "paper=55.1"))
    csv_rows.append(("compression/qwen25-05b_awq_gs128_mb",
                     f"{r['awq_gs128_mb']:.2f}",
                     "GS=128 (AWQ default; paper chose 64)"))
    for arch in C.ASSIGNED_ARCHS:
        r = sizes_for(arch)
        out[arch] = r
        csv_rows.append((f"compression/{arch}_reduction_pct",
                         f"{r['reduction_pct']:.2f}",
                         f"{r['baseline_mb']:.0f}->{r['awq_gs64_mb']:.0f}MB"))
    return out


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
