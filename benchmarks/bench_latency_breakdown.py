"""Paper Table I: per-op latency breakdown of one decode step.

The paper profiles Qwen2.5-0.5B decode on the KV260's ARM PS and finds
91.6% of time in MAC operations (matmuls) — the observation that justifies
offloading matmuls to the accelerator. We reproduce the experiment on this
host CPU with the real qwen25-05b dims (single layer, averaged): each
component jit'd and timed separately, then scaled by num_layers.

Output: name,us_per_call,percent — compare the MAC share against 91.6%.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import layers


def _time(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(csv_rows: list) -> dict:
    cfg = C.get_config("qwen25-05b")
    d, q_dim, kv_dim, f = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
    b, s_ctx = 1, 1024  # single-request decode against a 1k context
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, d), jnp.float32)
    wq = jax.random.normal(key, (d, q_dim), jnp.float32) * 0.02
    wk = jax.random.normal(key, (d, kv_dim), jnp.float32) * 0.02
    wo = jax.random.normal(key, (q_dim, d), jnp.float32) * 0.02
    wg = jax.random.normal(key, (d, f), jnp.float32) * 0.02
    wd = jax.random.normal(key, (f, d), jnp.float32) * 0.02
    bias_q = jnp.zeros((q_dim,))
    kcache = jax.random.normal(key, (b, s_ctx, cfg.num_kv_heads,
                                     cfg.head_dim), jnp.float32)
    gamma = jnp.ones((d,))
    h_attn = jax.random.normal(key, (b, q_dim), jnp.float32)
    h_ff = jax.random.normal(key, (b, f), jnp.float32)
    qh = jax.random.normal(key, (b, cfg.num_heads, cfg.head_dim))
    cos, sin = layers.rope_cos_sin(jnp.zeros((b,), jnp.int32), cfg.head_dim,
                                   cfg.rope_theta)

    comps = {
        # linear ops (MACs)
        "qkv_projection_mac": jax.jit(
            lambda x: (x @ wq, x @ wk, x @ wk)),
        "qkv_bias_add": jax.jit(lambda x: (x @ wq) + bias_q),
        "attention_scores_values": jax.jit(
            lambda q, k: jnp.einsum(
                "bkgs,bskd->bkgd",
                jax.nn.softmax(jnp.einsum("bkgd,bskd->bkgs",
                                          q.reshape(b, 2, 7, 64), k), -1),
                k)),
        "output_proj_residual": jax.jit(lambda h, x: x + h @ wo),
        "ffn_gate_up_mac": jax.jit(
            lambda x: jax.nn.silu(x @ wg) * (x @ wg)),
        "ffn_down_residual": jax.jit(lambda h, x: x + h @ wd),
        # non-linear ops (paper: stay on the CPU/VPU)
        "rope": jax.jit(lambda q: layers.apply_rope(q, cos, sin, 64)),
        "rmsnorm": jax.jit(
            lambda x: layers.rmsnorm({"gamma": gamma}, x)),
        "silu_elemwise_mul": jax.jit(lambda g, u: jax.nn.silu(g) * u),
    }
    args = {
        "qkv_projection_mac": (x,), "qkv_bias_add": (x,),
        "attention_scores_values": (qh, kcache),
        "output_proj_residual": (h_attn, x),
        "ffn_gate_up_mac": (x,), "ffn_down_residual": (h_ff, x),
        "rope": (qh,), "rmsnorm": (x,), "silu_elemwise_mul": (h_ff, h_ff),
    }
    mac_ops = {"qkv_projection_mac", "attention_scores_values",
               "output_proj_residual", "ffn_gate_up_mac",
               "ffn_down_residual"}

    times = {k: _time(fn, *args[k]) for k, fn in comps.items()}
    total = sum(times.values())
    mac_pct = 100 * sum(times[k] for k in mac_ops) / total
    for k, v in times.items():
        tag = "MAC" if k in mac_ops else "nonlinear"
        csv_rows.append((f"latency_breakdown/{k}", f"{v:.1f}",
                         f"{100*v/total:.1f}%({tag})"))
    csv_rows.append(("latency_breakdown/mac_share", f"{total:.1f}",
                     f"{mac_pct:.1f}% (paper Table I: 91.6%)"))
    return {"mac_pct": mac_pct, "total_us_per_layer": total}


if __name__ == "__main__":
    rows = []
    print(run(rows))
    for r in rows:
        print(",".join(r))
