"""Multi-replica serving fleet demo: the prefix-affinity router.

One `GenerationEngine` is a single serving "pod"; the fleet layer puts N
of them behind `serving.Router`, which owns the engine API
(`submit/step/collect/drain`) and decides *which* replica serves each
request by scoring

  * **prefix affinity** — exact reusable-page counts from each replica's
    content-addressed prefix index (`engine.prefix_reuse_pages`): a
    request whose system prompt is already resident somewhere skips that
    prefill work if placed there,
  * **load** — queue depth + active slots (penalty) and free-page
    headroom (bonus) from the extended `EngineStats`,
  * **SLO class** — interactive (``priority>0``) traffic is pushed away
    from replicas holding batch backlogs.

Sessions stick: the same ``session_id`` lands on the same replica until
that replica drains. Elastic scaling loses nothing: `drain_replica(i)`
reroutes queued work, finishes in-flight work, and every global request
id keeps streaming; `add_replica` grows the fleet live.

The demo builds a 2-replica fleet, serves two prompt clusters, shows the
placement ledger, then drains replica 0 under load and verifies the
rerouted streams are token-identical to a bare single engine (greedy
streams are a pure function of the prompt, so placement can't change
them).

Run:  PYTHONPATH=src python examples/serve_fleet.py
"""
import jax
import numpy as np

import repro.configs as configs
from repro.launch.specs import FleetSpec, ReplicaSpec
from repro.models import build_model
from repro.serving import GenerationEngine

MAX_SEQ = 96
ENGINE_KW = dict(max_seq=MAX_SEQ, num_slots=4, page_size=8,
                 prefill_chunk=8)


def main():
    cfg = configs.get_smoke_config("qwen25-05b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # -- declare the fleet (the k8s-style deployment description) ------
    spec = FleetSpec(replicas=2,
                     replica=ReplicaSpec(engine_kwargs=ENGINE_KW),
                     placement="affinity", affinity_threshold=1)
    router = spec.build(model, params)

    # two prompt clusters, each sharing a page-aligned system prefix
    rng = np.random.default_rng(0)
    prefixes = [rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)
                for _ in range(2)]
    prompts, pids = [], []
    for i in range(8):
        c = i % 2
        tail = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
        prompts.append(np.concatenate([prefixes[c], tail]))
        pids.append(f"sys{c}")

    # pin first (sticky: pages registered later join the pin), then warm
    # each cluster through the fleet so its pages survive the drain
    for c in (0, 1):
        router.pin_prefix(f"sys{c}")
    warm = [router.submit(prompts[c], 4, prefix_id=pids[c],
                          session_id=f"warm{c}") for c in (0, 1)]
    router.drain()

    # -- clustered burst: affinity should split clusters by replica ----
    rids = [router.submit(p, 8, prefix_id=pid, session_id=f"user{i % 4}")
            for i, (p, pid) in enumerate(zip(prompts, pids))]
    out = router.drain()
    rs = router.router_stats
    skipped = sum(s.prefill_tokens_skipped for s in router.stats())
    print(f"fleet of {router.num_replicas} on {jax.device_count()} "
          f"device(s): {rs.placements} placements, "
          f"{rs.affinity_hits} affinity hits, "
          f"{rs.session_hits} session hits, "
          f"{skipped} prefill tokens skipped")

    # -- drain replica 0 under load: zero token loss -------------------
    # submit each prompt twice (16 > 2x4 slots, so some requests queue);
    # drain_replica reroutes the queued ones to replica 1 mid-flight
    both = list(zip(prompts, pids)) * 2
    rids2 = [router.submit(p, 8, prefix_id=pid) for p, pid in both]
    for _ in range(3):           # a few steps so work is genuinely live
        router.step()
    router.drain_replica(0)
    out2 = router.drain()
    print(f"drained replica 0 under load: "
          f"{rs.reroutes} queued request(s) rerouted, "
          f"{sum(len(out2[r]) for r in rids2)} tokens delivered")

    # -- verify against a bare engine (placement-independence) ---------
    eng = GenerationEngine(model, params, **ENGINE_KW)
    ref = {}
    for p, pid in zip(prompts, pids):
        r = eng.submit(p, 8, prefix_id=pid)
        ref[r] = p
    refs = eng.drain()
    want = [list(refs[r]) for r in sorted(refs)]
    got = [list(out[r]) for r in rids]
    got2 = [list(out2[r]) for r in rids2]
    assert got == want and got2 == want + want, "fleet streams diverged"
    print("fleet streams (before AND during drain) are token-identical "
          "to a bare engine")


if __name__ == "__main__":
    main()
