"""Mesh-sharded serving demo: tensor-parallel page pools under the
continuous-batching engine.

The paper splits one model's inference across heterogeneous compute
(CPU + FPGA) while keeping a single logical execution stream; this demo
scales the same idea across a device mesh. ``GenerationEngine(mesh=...)``
serves a TP-sharded model with TP-sharded paged KV:

  * **weights** shard by the production rules in
    `repro.distributed.sharding.param_pspec` (column-parallel QKV,
    row-parallel O/down, vocab-parallel head),
  * **page pools** stripe over KV heads on the ``model`` axis
    (`paged_cache_pspec`) — each device holds ``Hkv / |model|`` heads of
    every physical page, so per-device KV memory shrinks linearly with
    the axis,
  * **everything host-visible replicates**: the pager's free list,
    refcounts, prefix index and page tables never change — page IDs are
    device-agnostic, so admission, eviction, prefix sharing and
    speculative rollback run untouched,
  * greedy sharded streams are **token-identical** to the single-device
    engine — the demo checks this at the end.

Run (any machine; forces 4 virtual CPU devices):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/serve_sharded.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import dataclasses                                            # noqa: E402

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

import repro.configs as configs                               # noqa: E402
from repro.distributed import serving_mesh                    # noqa: E402
from repro.models import build_model                          # noqa: E402
from repro.serving import GenerationEngine                    # noqa: E402


def serve(model, params, prompts, mesh, label):
    eng = GenerationEngine(model, params, max_seq=96, num_slots=4,
                           page_size=8, prefill_chunk=8, kv_quant="int8",
                           spec_decode="ngram", spec_k=4, mesh=mesh)
    rids = [eng.submit(p, 12, prefix_id="sys") for p in prompts]
    out = eng.drain()
    st = eng.stats()
    print(f"\n--- {label} ---")
    print(f"model axis {st.model_axis}: "
          f"{st.kv_pool_bytes_per_device:,} pool bytes/device "
          f"(global {st.kv_pool_bytes:,}); "
          f"{st.dispatches} dispatches, "
          f"{st.prefix_shared_pages} pages aliased, "
          f"acceptance {st.acceptance_rate:.0%}")
    return [list(out[r]) for r in rids]


def main():
    # KV heads must divide the model axis (Hkv = 4 → 1-, 2- and 4-way
    # meshes all work; the engine rejects indivisible combinations with
    # a construction-time error)
    cfg = dataclasses.replace(configs.get_smoke_config("qwen25-05b"),
                              num_heads=8, num_kv_heads=4, head_dim=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    # shared system prefix (aliased across all three requests) + a
    # repetitive tail (so the n-gram self-drafter has something to match)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate(
        [prefix, np.tile(rng.integers(0, cfg.vocab_size, (3,)
                                      ).astype(np.int32), reps)])
        for reps in (4, 6, 5)]

    print(f"{jax.device_count()} local devices")
    ref = serve(model, params, prompts, None, "unsharded (mesh=None)")
    for size in (1, 2, 4):
        if size > jax.device_count():
            break
        got = serve(model, params, prompts, serving_mesh(size),
                    f"mesh ('model',) of size {size}")
        assert got == ref, f"mesh size {size} diverged"
    print("\ngreedy streams are token-identical across every mesh size")


if __name__ == "__main__":
    main()
