"""Quickstart: the paper's pipeline end-to-end in ~60 lines.

  1. build the paper's model (qwen2.5-0.5b family, smoke-sized for CPU),
  2. train it briefly on the synthetic stream,
  3. calibrate + AWQ-quantize (INT4, GS=64, activation-aware scales),
  4. serve batched generation from the packed weights,
  5. report the compression rate (paper Table III).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import (AWQConfig, CalibrationCapture, QuantConfig,
                        quantize_params)
from repro.core.pipeline import model_size_bytes
from repro.data import make_dataset
from repro.models import build_model
from repro.serving import GenerationEngine
from repro.training import AdamWConfig, TrainConfig, make_train_step
from repro.training.train_step import init_train_state


def main():
    cfg = configs.get_smoke_config("qwen25-05b")
    model = build_model(cfg)

    # --- 2. train briefly ---------------------------------------------------
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=80,
                              weight_decay=0.0))))
    ds = make_dataset(cfg, 16, 64)
    for i in range(80):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in ds.batch_at(i).items()})
        if i % 20 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.3f}")
    params = state["params"]

    # --- 3. AWQ PTQ (the paper's §III-A flow) -------------------------------
    calib = {k: jnp.asarray(v) for k, v in ds.batch_at(999).items()}
    with CalibrationCapture() as cap:
        model.loss(params, calib)
    qparams, report = quantize_params(
        params, cap.stats, AWQConfig(quant=QuantConfig(group_size=64)))
    base = model_size_bytes(params, quantized=False)
    packed = model_size_bytes(qparams, quantized=True)
    print(f"\nAWQ: {len(report.quantized)} linears → INT4 GS=64 "
          f"({len(report.calibrated)} activation-calibrated)")
    print(f"serialized size {base/1e6:.2f} MB → {packed/1e6:.2f} MB "
          f"({100*(1-packed/base):.1f}% smaller; paper: 55.1%)")

    # --- 4. serve from packed weights ---------------------------------------
    engine = GenerationEngine(model, qparams, max_seq=128)
    prompt = {"tokens": jnp.asarray(ds.batch_at(5)["tokens"][:, :16])}
    out = engine.generate(prompt, 24)
    print(f"\ngenerated {out.shape[1]} tokens/request "
          f"(batch {out.shape[0]}): {out[0][:12].tolist()}")


if __name__ == "__main__":
    main()
