"""Speculative decoding demo: n-gram self-drafting through the unified
chunk dispatch, with live acceptance/rollback accounting.

Decode is memory-bandwidth bound: every step streams ALL weights to emit
one token (the paper's 5.1 tok/s ceiling). Speculative decoding amortizes
one weight pass over several tokens:

  * a tiny qwen2.5-style model serves a burst of **repetitive prompts**
    (tiled patterns — stand-ins for code, lists, templated chat) with
    ``spec_decode="ngram"``: each decoding slot's own context proposes
    its continuation by prompt lookup — no second model,
  * the slot's per-step row becomes a token run ``[last, d_1 … d_k]``;
    the SAME unified dispatch that packs prefill chunks verifies all
    drafts in one weight pass and samples a corrected/bonus token,
  * accepted drafts stream out together; a rejected suffix rolls the
    paged KV back (`KVPager.truncate` — pages return to the free list,
    free-exactly-once preserved),
  * greedy outputs are **token-identical** to ordinary decode — the demo
    checks this against a drafting-free engine at the end.

Also shown: ``spec_decode="draft_model"`` (a second, smaller model
drafts greedily with its own dense cache) — here the "draft" is the
target itself, so acceptance is ~100% and every step emits k+1 tokens.

Run:  PYTHONPATH=src python examples/serve_speculative.py
"""
import jax
import numpy as np

import repro.configs as configs
from repro.models import build_model
from repro.serving import GenerationEngine


def serve(eng, prompts, max_new, label):
    rids = [eng.submit(p, max_new) for p in prompts]
    print(f"\n--- {label} ---")
    step = 0
    while not eng.idle:
        events = eng.step()
        step += 1
        if events:
            line = " ".join(f"r{rid}:{tok}" for rid, tok in events)
            print(f"step {step:2d}  [{len(events)} tokens]  {line}")
    st = eng.scheduler_stats
    print(f"{st.decode_steps} weight passes for "
          f"{st.slot_tokens} decode tokens")
    if st.spec_rows:
        print(f"drafted {st.draft_tokens}, accepted {st.accepted_tokens} "
              f"({st.acceptance_rate:.0%}); "
              f"{st.spec_tokens_per_row:.2f} tokens per verify run; "
              f"{st.rollbacks} rollbacks returned "
              f"{st.rollback_pages} KV pages")
    print(f"padding: {st.padding_waste:.0%} of dispatched positions "
          f"(run-length packer)")
    out = eng.collect()
    return [list(out[r]) for r in rids]


def main():
    cfg = configs.get_smoke_config("qwen25-05b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    # repetitive prompts: short patterns tiled — prompt lookup's home turf
    prompts = [np.tile(rng.integers(0, cfg.vocab_size, (4,)
                                    ).astype(np.int32), 6)
               for _ in range(3)]
    common = dict(max_seq=96, num_slots=4, page_size=8, prefill_chunk=8)

    ngram = serve(
        GenerationEngine(model, params, spec_decode="ngram", spec_k=4,
                         **common),
        prompts, 16, 'spec_decode="ngram" (prompt-lookup self-drafting)')

    drafted = serve(
        GenerationEngine(model, params, spec_decode="draft_model", spec_k=4,
                         draft_model=model, draft_params=params, **common),
        prompts, 16, 'spec_decode="draft_model" (draft = target: ~100% '
        'acceptance)')

    plain = serve(GenerationEngine(model, params, **common),
                  prompts, 16, "no speculation (baseline)")

    assert ngram == drafted == plain
    print("\ngreedy streams are token-identical across all three engines")


if __name__ == "__main__":
    main()
