"""Serve batched requests from EVERY assigned architecture (smoke-sized),
float and AWQ-quantized — proves the paper's technique is arch-agnostic
and plugged in as a first-class feature (deliverable (f) + §Arch-
applicability).

Run:  PYTHONPATH=src python examples/serve_all_archs.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import quantize_params
from repro.data import make_dataset
from repro.models import build_model
from repro.serving import GenerationEngine


def main():
    print(f"{'arch':24s} {'params':>8s} {'quantized':>10s} "
          f"{'float tok/s':>12s} {'awq tok/s':>10s}")
    for arch in configs.list_archs():
        cfg = configs.get_smoke_config(arch)
        if cfg.is_encoder:
            print(f"{arch:24s} encoder-only: no decode (skip noted in "
                  "DESIGN.md §4)")
            continue
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qparams, report = quantize_params(params)
        ds = make_dataset(cfg, 2, 16)
        prompt = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"])}
        if cfg.frontend == "vision":
            import numpy as np
            prompt["images"] = jnp.asarray(np.random.default_rng(0).normal(
                size=(2, cfg.num_patches, cfg.frontend_dim)), jnp.float32)
        tput = {}
        for tag, p in (("float", params), ("awq", qparams)):
            eng = GenerationEngine(model, p, max_seq=64)
            eng.generate(prompt, 2)  # compile
            t0 = time.perf_counter()
            out = eng.generate(prompt, 16)
            tput[tag] = out.size / (time.perf_counter() - t0)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"{arch:24s} {n/1e6:7.1f}M {len(report.quantized):10d} "
              f"{tput['float']:12.1f} {tput['awq']:10.1f}")


if __name__ == "__main__":
    main()
