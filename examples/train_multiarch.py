"""End-to-end driver: train a ~100M-param smollm-360m variant with the
production train loop (checkpointing, failure recovery, straggler
watchdog) — assignment deliverable (b).

CPU-friendly defaults (60 steps × batch 2 × seq 128 ≈ minutes on one
core); on real hardware: --steps 300 --batch 64 --seq 1024 --full-depth.

The config is the real smollm-360m trunk at reduced depth so a CPU finishes
a few hundred steps; pass --full-depth on real hardware. Every substrate on
the path (data → train_step → AdamW → async checkpoints) is the same code
the 512-chip dry-run lowers.

Run:  PYTHONPATH=src python examples/train_multiarch.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

import repro.configs as configs
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full-depth", action="store_true")
    args = ap.parse_args()

    if args.full_depth:
        cfg = configs.get_config(args.arch)
    else:
        # ~100M-param variant: real width, reduced depth (32 → 6 layers):
        # 6·(4·960² + 3·960·2560)/1e6 ≈ 66M trunk + 47M embed ≈ 113M params
        cfg = dataclasses.replace(configs.get_config(args.arch),
                                  num_layers=6, max_seq_len=512)
    n = cfg.n_params()
    print(f"[example] {cfg.name}: {n/1e6:.0f}M params, "
          f"{cfg.num_layers} layers")
    configs._REGISTRY["_example"] = (lambda: cfg, lambda: cfg)
    with tempfile.TemporaryDirectory() as d:
        out = train_main([
            "--arch", "_example", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq), "--lr", "1e-3",
            "--warmup", "10", "--ckpt-dir", d, "--ckpt-every", "100",
            "--log-every", "25",
        ])
    assert out["last_loss"] < out["first_loss"], "training must make progress"
    print(f"[example] loss {out['first_loss']:.3f} → {out['last_loss']:.3f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
