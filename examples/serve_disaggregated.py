"""Disaggregated prefill/decode serving demo: zero-recompute KV handoff.

The paper's hybrid execution splits one model's inference across
heterogeneous compute while keeping a single logical stream; this demo
applies the same split along the *phase* axis. Prefill is compute-bound
and decode is bandwidth-bound, so `DisaggController` runs them as two
engines:

  * the **prefill engine** runs chunked (optionally prefix-shared)
    prefill to the commit watermark, samples the first token, then
    exports the committed KV pages as a `KVHandoff` — a host-side,
    mesh-agnostic wire image (int8 pools ship codes + scale strips,
    ~2× fewer bytes than bf16);
  * the **decode engine** adopts the pages into its own pool — aliasing
    any prefix pages it already holds — and resumes at the watermark:
    it never re-runs prefill, so its time-to-first-token is purely the
    transfer. Decode keeps the full feature stack (int8 KV, prefix
    pinning, n-gram speculation), and may run a *different* mesh than
    the prefill side: the wire image is replicated, so the scatter
    re-stripes pages for whatever layout the decode pool uses;
  * the controller routes short prompts straight to the decode engine
    (a split only pays past the roofline crossover) and overlaps the
    handoff device→host gather with decode dispatches.

The demo serves the same burst through a unified `GenerationEngine` and
through the controller with prefill on a 4-way mesh and decode on a
2-way mesh, asserts the greedy streams are token-identical, and prints
the handoff ledger plus the roofline split report the placement policy
derives from.

Run (any machine; forces 4 virtual CPU devices):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/serve_disaggregated.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import dataclasses                                            # noqa: E402

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

import repro.configs as configs                               # noqa: E402
from repro.distributed import serving_mesh                    # noqa: E402
from repro.models import build_model                          # noqa: E402
from repro.roofline.costmodel import disagg_report            # noqa: E402
from repro.serving import (DisaggController,                  # noqa: E402
                           GenerationEngine)

KW = dict(max_seq=96, num_slots=4, page_size=8, prefill_chunk=8,
          kv_quant="int8", spec_decode="ngram", spec_k=4)


def main():
    # Hkv = 4 so the decode pool can stripe over KV heads on a 2-way
    # mesh while prefill runs 4-way — the two sides never need to agree
    cfg = dataclasses.replace(configs.get_smoke_config("qwen25-05b"),
                              num_heads=8, num_kv_heads=4, head_dim=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)])
        for t in (5, 12, 9)]
    print(f"{jax.device_count()} local devices")

    # unified reference: one engine does both phases
    eng = GenerationEngine(model, params, **KW)
    rids = [eng.submit(p, 12, prefix_id="sys") for p in prompts]
    out = eng.drain()
    ref = [list(out[r]) for r in rids]
    print(f"unified: {eng.stats().dispatches} dispatches")

    # disaggregated: prefill 4-way, decode 2-way, pages resharded by the
    # adopt scatter — handoff_min_tokens=1 forces every request through
    # the handoff path so the demo exercises it
    ctrl = DisaggController(model, params, handoff_min_tokens=1,
                            prefill_mesh=serving_mesh(4),
                            decode_mesh=serving_mesh(2), **KW)
    crids = [ctrl.submit(p, 12, prefix_id="sys") for p in prompts]
    got = ctrl.drain()
    assert [list(got[r]) for r in crids] == ref, "streams diverged"
    st = ctrl.stats()
    print(f"disagg:  {st.handoffs} handoffs, "
          f"{st.handoff_pages:.0f} pages shipped "
          f"({st.aliased_pages:.0f} aliased via the decode-side prefix "
          f"index), {st.wire_bytes:,} wire bytes, "
          f"{st.adopt_time_s * 1e3:.1f} ms total adopt")
    print("greedy streams are token-identical: "
          "prefill(4-way) → handoff → decode(2-way) ≡ unified")

    # the placement policy's inputs: where each phase lands on the
    # roofline and the prompt length past which the split pays
    rep = disagg_report(cfg, decode_batch=KW["num_slots"],
                        context=KW["max_seq"], quant=True)
    print(f"\nroofline split report (machine balance "
          f"{rep['machine_balance']:.0f} FLOPs/byte):")
    print(f"  prefill {rep['prefill_intensity']:6.1f} F/B "
          f"({rep['prefill_bound']}-bound)")
    print(f"  decode  {rep['decode_intensity']:6.1f} F/B "
          f"({rep['decode_bound']}-bound)")
    print(f"  disaggregate={rep['disaggregate']}, crossover at "
          f"{rep['crossover_prompt_tokens']} prompt tokens")


if __name__ == "__main__":
    main()
