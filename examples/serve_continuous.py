"""Continuous-batching serving demo: chunked prefill + streaming.

A tiny qwen2.5-style model serves a mixed burst — one LONG prompt next
to several short chats — through the token-budget chunked serving path:

  * every step is ONE fixed-shape dispatch packing prefill chunks and
    decode tokens from mixed requests: the long prompt's chunks
    interleave with everyone else's decode tokens instead of stalling
    them (the convoy-effect fix), and its first token is sampled by the
    dispatch that commits its last chunk,
  * `step()` returns `(request_id, token)` stream events as they are
    produced — this is the hook a real frontend would forward to clients,
  * finished requests are evicted mid-flight and their KV pages + batch
    slot immediately reused by queued work,
  * the engine holds KV in **int8 pages** (``kv_quant="int8"``: quantized
    per chunk on commit, dequantized inside the paged attention read),
    and requests sharing a system prompt pass ``prefix_id`` so their
    common full pages are aliased — under chunked prefill those tokens
    are **never recomputed** (prefix sharing saves prefill FLOPs, not
    just memory). `pin_prefix` keeps the hot prefix resident for the
    next burst — see docs/SERVING.md.

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""
import jax
import numpy as np

import repro.configs as configs
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig


def main():
    cfg = configs.get_smoke_config("qwen25-05b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = GenerationEngine(model, params, max_seq=128,
                           num_slots=4, page_size=8,
                           prefill_chunk=8,       # token budget 4×8 per step
                           kv_quant="int8")       # int8 KV pages + scales

    rng = np.random.default_rng(0)
    # a shared 16-token "system prompt": requests passing the same
    # prefix_id alias its full KV pages AND skip recomputing them
    system = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    eng.pin_prefix("system")   # keep it resident across bursts
    specs = [  # (tail_len, max_new_tokens, temperature, share_prefix)
        (5, 12, 0.0, True), (11, 4, 0.0, False),
        (64, 6, 0.0, False),                       # the LONG prompt
        (8, 20, 0.8, True), (7, 9, 0.0, True), (13, 16, 1.2, False),
        (4, 5, 0.0, True), (9, 8, 0.0, False),
    ]
    rid_meta = {}
    for n, max_new, temp, share in specs:
        tail = rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        prompt = np.concatenate([system, tail]) if share else tail
        rid = eng.submit(prompt, max_new,
                         sampler=SamplerConfig(temperature=temp),
                         prefix_id="system" if share else None)
        rid_meta[rid] = (len(prompt), max_new, temp)
        print(f"submitted rid={rid}  prompt={len(prompt)} tok  "
              f"budget={max_new}  T={temp}"
              f"{'  [shared prefix]' if share else ''}")

    print("\n--- streaming (chunks interleave with decode) ---")
    streams: dict[int, list[int]] = {rid: [] for rid in rid_meta}
    step = 0
    while not eng.idle:
        events = eng.step()
        step += 1
        for rid, tok in events:
            streams[rid].append(tok)
        sched = eng._scheduler
        prefilling = sum(st.prefilling for st in sched.slots.values())
        line = " ".join(f"r{rid}:{tok}" for rid, tok in events)
        print(f"step {step:2d}  [{eng.num_active} active, "
              f"{prefilling} prefilling]  {line}")

    print("\n--- finished ---")
    for rid, toks in eng.collect().items():
        n, max_new, temp = rid_meta[rid]
        print(f"rid={rid}  T={temp}  {len(toks)}/{max_new} tokens: "
              f"{[int(t) for t in toks]}")

    st = eng.scheduler_stats
    util = st.slot_tokens / max(st.slot_steps, 1)
    print(f"\n{st.decode_steps} unified dispatches for {st.finished} "
          f"requests; slot utilization {util:.0%}")
    print(f"prefill: {st.prefill_tokens} prompt tokens in "
          f"{st.prefill_chunks} chunks, {st.prefill_tokens_skipped} "
          f"aliased tokens never recomputed")
    eng.unpin_prefix("system")


if __name__ == "__main__":
    main()
