"""Parallel sampling demo: ``submit(n=...)`` — n continuations of one
prompt, prompt KV paid once.

Best-of-n / self-consistency decoding needs n continuations of the SAME
prompt. Submitting the prompt n times prefills it n times and stores n
copies of its KV; ``submit(prompt, max_new, n=n)`` instead

  * prefills the prompt once (the first sibling), registering its full
    pages under an auto-generated prefix id,
  * **aliases** those physical pages read-only into every other sibling
    (refcounted — the `prefix_id` machinery) and skips their aliased
    prefill chunks entirely: prompt FLOPs are paid once,
  * copies only a partial tail page per sibling (copy-on-write, decode
    must append to it); divergent continuations land in per-sibling
    pages as usual,
  * with a sampled `SamplerConfig`, gives each sibling an independent
    PRNG stream — greedy siblings are deliberately identical, which the
    demo uses to check the aliased path against n separate submissions.

Run:  PYTHONPATH=src python examples/serve_parallel_sampling.py
"""
import jax
import numpy as np

import repro.configs as configs
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig


def fresh(model, params):
    return GenerationEngine(model, params, max_seq=96, num_slots=4,
                            page_size=8, prefill_chunk=8)


def main():
    cfg = configs.get_smoke_config("qwen25-05b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)
    n, max_new = 3, 12

    # --- n separate submissions: the prompt is prefilled n times --------
    eng = fresh(model, params)
    rids = [eng.submit(prompt, max_new) for _ in range(n)]
    out = eng.drain()
    sep = [list(out[r]) for r in rids]
    st = eng.stats()
    print(f"--- {n} separate submits ---")
    print(f"prefill tokens run: {st.prefill_tokens}, "
          f"skipped: {st.prefill_tokens_skipped}, "
          f"shared pages: {st.prefix_shared_pages}")

    # --- one submit(n=...): prompt pages written once, aliased ----------
    eng = fresh(model, params)
    rids = eng.submit(prompt, max_new, n=n)
    out = eng.drain()
    par = [list(out[r]) for r in rids]
    st = eng.stats()
    saved = st.prefix_shared_pages * eng.paged_kv_page_bytes()
    print(f"\n--- submit(n={n}) ---")
    print(f"prefill tokens run: {st.prefill_tokens}, "
          f"skipped: {st.prefill_tokens_skipped}, "
          f"shared pages: {st.prefix_shared_pages} "
          f"({saved} KV bytes never duplicated)")

    assert par == sep, "greedy siblings must match n independent runs"
    print(f"\ngreedy submit(n={n}) streams ≡ {n} independent submissions")

    # --- sampled siblings: same pages, independent continuations --------
    eng = fresh(model, params)
    rids = eng.submit(prompt, max_new, n=n,
                      sampler=SamplerConfig(temperature=1.0, top_k=8))
    out = eng.drain()
    print("\n--- sampled siblings (temperature 1.0, top_k 8) ---")
    for r in rids:
        print(f"r{r}: {[int(t) for t in out[r]]}")


if __name__ == "__main__":
    main()
