"""Speculative decoding through the unified chunk dispatch: greedy
token identity vs sequential generate() across draft lengths and
acceptance outcomes, KV rollback across page boundaries, drafter modes
(n-gram prompt lookup, draft model, custom draft_fn), scheduler
state-machine semantics against a fake executor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig
from repro.serving.kv_pager import KVPager, PagerConfig
from repro.serving.scheduler import Request, Scheduler, ngram_propose


@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _engine(m, params, **kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("num_slots", 4)
    kw.setdefault("page_size", 8)
    return GenerationEngine(m, params, **kw)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


def _refs(eng, prompts, max_new):
    return [np.asarray(eng.generate({"tokens": jnp.asarray(p)[None, :]},
                                    max_new)[0]) for p in prompts]


def _pager_invariants(pager):
    """Free-exactly-once bookkeeping: every non-scratch page is either on
    the free list or owned (refcount ≥ 1), never both, never neither."""
    free = set(pager.free_pages)
    assert len(free) == len(pager.free_pages)          # no duplicates
    for pg in range(1, pager.cfg.num_pages):
        if pg in free:
            assert pager.page_ref[pg] == 0, pg
        else:
            assert pager.page_ref[pg] >= 1, pg
    assert pager.pages_in_use == pager.cfg.num_pages - 1 - len(free)


# ---------------------------------------------------------------------------
# n-gram prompt-lookup drafter (host-side, no model)
# ---------------------------------------------------------------------------

def test_ngram_propose_matches_most_recent_occurrence():
    ctx = np.array([5, 6, 7, 9, 5, 6, 8, 3, 5, 6], np.int32)
    # suffix [5, 6] last occurred at index 4 → continuation [8, 3, 5, 6]
    assert ngram_propose(ctx, 4, max_n=3) == [8, 3, 5, 6]
    assert ngram_propose(ctx, 2, max_n=3) == [8, 3]
    # longer n-grams win: suffix [3, 5, 6] has no earlier occurrence, but
    # with max_n=1 the last [6] at index 5 proposes [8, ...]
    assert ngram_propose(ctx, 1, max_n=1) == [8]


def test_ngram_propose_no_match_and_tiny_context():
    assert ngram_propose(np.array([1, 2, 3, 4], np.int32), 4) == []
    assert ngram_propose(np.array([7], np.int32), 4) == []
    assert ngram_propose(np.array([7, 7], np.int32), 2) == [7]


# ---------------------------------------------------------------------------
# Scheduler state machine against a fake executor (no model)
# ---------------------------------------------------------------------------

class _FakeSpecExec:
    """Scripted verify executor: accepts a fixed number of drafts per call
    and emits deterministic tokens (fix = 100 + base token + accepted)."""

    def __init__(self, accept):
        self.accept = accept           # drafts to accept per verify row
        self.calls = []                # (c, n_draft tuple)

    def run_batch(self, tokens, pos, row_slots, sample_idx, temps, topks,
                  n_draft=None):
        if n_draft is None:
            out = np.array([100 + tokens[r, sample_idx[r]]
                            for r in range(tokens.shape[0])], np.int32)
            return out
        self.calls.append((tokens.shape[1], tuple(int(x) for x in n_draft)))
        n_acc = np.minimum(n_draft, self.accept).astype(np.int32)
        fix = np.array([100 + tokens[r, sample_idx[r]] + n_acc[r]
                        for r in range(tokens.shape[0])], np.int32)
        return fix, n_acc


def _spec_sched(draft, accept, num_slots=2, pages_per_slot=4, page_size=4,
                chunk=4, k=3, adaptive=False):
    ex = _FakeSpecExec(accept)
    pager = KVPager(PagerConfig(num_pages=num_slots * pages_per_slot + 1,
                                page_size=page_size, num_slots=num_slots,
                                pages_per_slot=pages_per_slot))
    sched = Scheduler(pager, run_batch=ex.run_batch, chunk_size=chunk,
                      spec_decode="draft_fn", spec_k=k,
                      adaptive_spec_k=adaptive, draft_fn=draft)
    return sched, ex


def test_fake_spec_acceptance_emits_run_and_rolls_back():
    drafts = {"calls": 0}

    def draft(reqs):
        drafts["calls"] += 1
        return {slot: [7, 8, 9][:k] for slot, _rid, _ctx, _q, k in reqs}

    sched, ex = _spec_sched(draft, accept=1)   # always accept 1 of 3
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=7))
    sched.step()                               # prefill → first token
    ev = sched.step()                          # verify run: accept 1 + fix
    assert len(ev) == 2 and ev[0][1] == 7      # accepted draft, then fix
    assert sched.stats.spec_rows == 1
    assert sched.stats.draft_tokens == 3 and sched.stats.accepted_tokens == 1
    assert sched.stats.rollbacks == 1          # 2 rejected → truncate
    # KV watermark matches the sampled stream: prompt 4 + first + run 2
    assert int(sched.pager.slot_len[0]) == 4 + 2
    out = sched.run()
    assert len(out[0]) == 7
    assert sched.pager.pages_in_use == 0
    _pager_invariants(sched.pager)


def test_fake_spec_draft_cap_near_budget_end():
    """k_eff shrinks to the remaining budget minus one, so a verify run
    never writes KV past the admitted reservation and the stream never
    overshoots max_new."""
    seen = []

    def draft(reqs):
        seen.extend(k for *_rest, k in reqs)
        return {slot: list(range(10, 10 + k))
                for slot, _rid, _ctx, _q, k in reqs}

    sched, ex = _spec_sched(draft, accept=3, k=3)
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=5))
    out = sched.run()
    assert len(out[0]) == 5                  # exactly the budget
    # first verify: 4 to go → k_eff 3; after emitting 4 → 1 to go → no draft
    assert seen == [3]
    assert sched.pager.pages_in_use == 0
    _pager_invariants(sched.pager)


def test_adaptive_spec_k_shrinks_to_one_then_grows_back():
    """Forced full rejection drives the acceptance EMA to 0 and walks
    spec_k down the bucket family to 1; forced full acceptance drives it
    back up to spec_k_max — one bucket per step, never outside the
    family."""
    def draft(reqs):
        return {slot: [7] * k for slot, _rid, _ctx, _q, k in reqs}

    sched, ex = _spec_sched(draft, accept=0, k=4, pages_per_slot=16,
                            page_size=4, adaptive=True)
    assert sched._k_buckets == [1, 2, 4]
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=40))
    sched.step()                              # prefill → first token
    seen_k = []
    for _ in range(4):                        # full-reject phase
        seen_k.append(sched.spec_k_cur)
        sched.step()
    assert sched.spec_k_cur == 1              # 4 → 2 → 1, then floor
    assert seen_k[0] == 4 and all(k in (1, 2, 4) for k in seen_k)
    ex.accept = 99                            # full-accept phase
    grown = []
    while 0 not in sched.finished and not sched.idle:
        sched.step()
        grown.append(sched.spec_k_cur)
    assert max(grown) == 4                    # 1 → 2 → 4 on acceptance
    out = {**sched.finished, **sched.run()}
    assert len(out[0]) == 40                  # exactly the budget
    _pager_invariants(sched.pager)


def test_adaptive_spec_k_engine_identity(model_and_params):
    """Adaptive k under a real engine: a drafter that is wrong until k
    bottoms out at 1 and oracle-right afterwards leaves the greedy
    stream token-identical to sequential decode, while k round-trips
    4 → 1 → 4."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (6,), seed=12)
    eng0 = _engine(m, params)
    refs = _refs(eng0, prompts, 24)
    oracle = {}
    state = {"eng": None}

    def draft(reqs):
        out = {}
        sched = state["eng"]._scheduler
        for slot, rid, ctx, _q, k in reqs:
            ref, plen = oracle[rid]
            done = len(ctx) - plen
            nxt = [int(t) for t in ref[done:done + k]]
            if sched.spec_k_cur > 1 and min(state["ks"]) > 1:
                nxt = [(t + 1) % cfg.vocab_size for t in nxt]  # all wrong
            out[slot] = nxt
        return out

    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  spec_adaptive=True, draft_fn=draft)
    state["eng"] = eng
    state["ks"] = [4]
    rid = eng.submit(prompts[0], 24)
    oracle[rid] = (refs[0], len(prompts[0]))
    while not eng.idle:
        eng.step()
        state["ks"].append(eng._scheduler.spec_k_cur)
    out = eng.collect()
    assert min(state["ks"]) == 1              # rejection drove k to 1
    assert state["ks"][-1] == 4 or max(
        state["ks"][state["ks"].index(1):]) == 4   # …and acceptance back up
    np.testing.assert_array_equal(out[rid], refs[0])
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)


def test_fake_spec_full_acceptance_width_and_eos_mid_run():
    def draft(reqs):
        return {slot: [50, 51, 52][:k] for slot, _rid, _ctx, _q, k in reqs}

    sched, ex = _spec_sched(draft, accept=3, k=3, pages_per_slot=6)
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=12, eos_id=51))
    sched.step()
    ev = sched.step()                          # verify: [50, 51, …] → EOS
    assert [t for _r, t in ev] == [50, 51]     # stops mid-acceptance
    assert sched.stats.finished == 1
    assert ex.calls[-1][0] == 4                # verify run width k+1 = 4
    assert sched.pager.pages_in_use == 0
    _pager_invariants(sched.pager)


# ---------------------------------------------------------------------------
# End-to-end greedy identity: spec-decode streams ≡ sequential generate()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_greedy_ngram_identity_across_k(model_and_params, k):
    cfg, m, params = model_and_params
    # repetitive prompts (prompt lookup fires) + random ones (it mostly
    # falls back to plain decode) in one batch
    rng = np.random.default_rng(2)
    pats = [rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
            for _ in range(2)]
    prompts = [np.tile(p, 5) for p in pats] + _prompts(cfg, (9, 13), seed=3)

    eng = _engine(m, params, spec_decode="ngram", spec_k=k)
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.drain()
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)
    refs = _refs(eng, prompts, 10)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref[: len(out[rid])])
        assert len(out[rid]) == 10
    st = eng.scheduler_stats
    assert st.draft_tokens > 0                # the drafter actually fired
    assert 0 <= st.accepted_tokens <= st.draft_tokens


def test_forced_full_acceptance_oracle_draft(model_and_params):
    """A draft_fn that proposes the true greedy continuation: everything
    is accepted, each verify run emits k+1 tokens, streams stay
    identical, and no rollback ever happens."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 9, 12), seed=4)
    eng0 = _engine(m, params)
    refs = _refs(eng0, prompts, 9)
    oracle = {}            # rid → full greedy stream

    def draft(reqs):
        out = {}
        for slot, rid, ctx, _q, k in reqs:
            ref, plen = oracle[rid]
            done = len(ctx) - plen             # tokens already emitted
            out[slot] = [int(t) for t in ref[done:done + k]]
        return out

    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  draft_fn=draft)
    rids = [eng.submit(p, 9) for p in prompts]
    for rid, p, ref in zip(rids, prompts, refs):
        oracle[rid] = (ref, len(p))
    out = eng.drain()
    st = eng.scheduler_stats
    assert st.accepted_tokens == st.draft_tokens > 0
    assert st.rollbacks == 0
    assert st.spec_tokens_per_row > 2.0
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    assert eng._scheduler.pager.pages_in_use == 0


def test_forced_rejection_identity_and_rollback(model_and_params):
    """A drafter that always proposes wrong tokens: every draft is
    rejected, every verify run rolls back, and the stream is still
    token-identical to sequential greedy (the corrected token IS the
    argmax)."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (6, 11), seed=5)
    eng0 = _engine(m, params)
    refs = _refs(eng0, prompts, 8)
    oracle = {}

    def draft(reqs):
        out = {}
        for slot, rid, ctx, _q, k in reqs:
            ref, plen = oracle[rid]
            done = len(ctx) - plen
            nxt = [int(t) for t in ref[done:done + k]]
            out[slot] = [(t + 1) % cfg.vocab_size for t in nxt]  # all wrong
        return out

    eng = _engine(m, params, spec_decode="draft_model", spec_k=3,
                  draft_fn=draft)
    rids = [eng.submit(p, 8) for p in prompts]
    for rid, p, ref in zip(rids, prompts, refs):
        oracle[rid] = (ref, len(p))
    out = eng.drain()
    st = eng.scheduler_stats
    assert st.accepted_tokens == 0 and st.draft_tokens > 0
    assert st.rollbacks == st.spec_rows > 0
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)


def test_rollback_across_page_boundary(model_and_params):
    """Rejected verify runs that straddle a page boundary release the
    freshly drawn page back to the free list (and back into the slot's
    reservation) — and the stream stays identical."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (6,), seed=6)      # page 4: decode crosses pages
    eng0 = _engine(m, params, page_size=4)
    refs = _refs(eng0, prompts, 10)
    oracle = {}

    def draft(reqs):
        out = {}
        for slot, rid, ctx, _q, k in reqs:
            ref, plen = oracle[rid]
            done = len(ctx) - plen
            nxt = [int(t) for t in ref[done:done + k]]
            out[slot] = [(t + 1) % cfg.vocab_size for t in nxt]
        return out

    eng = _engine(m, params, page_size=4, spec_decode="draft_model",
                  spec_k=6, draft_fn=draft)
    rid = eng.submit(prompts[0], 10)
    oracle[rid] = (refs[0], len(prompts[0]))
    out = eng.drain()
    st = eng.scheduler_stats
    assert st.rollback_pages > 0               # pages actually came back
    np.testing.assert_array_equal(out[rid], refs[0])
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)


def test_randomized_accept_reject_pager_invariants(model_and_params):
    """Random mix of right and wrong drafts across many requests: streams
    stay identical and the pager's free-exactly-once bookkeeping holds
    after every step."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 8, 11, 7, 13, 4), seed=7)
    eng0 = _engine(m, params)
    refs = _refs(eng0, prompts, 9)
    oracle = {}
    rng = np.random.default_rng(8)

    def draft(reqs):
        out = {}
        for slot, rid, ctx, _q, k in reqs:
            ref, plen = oracle[rid]
            done = len(ctx) - plen
            nxt = [int(t) for t in ref[done:done + k]]
            out[slot] = [t if rng.random() < 0.6 else
                         (t + 1) % cfg.vocab_size for t in nxt]
        return out

    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  draft_fn=draft)
    rids = [eng.submit(p, 9) for p in prompts]
    for rid, p, ref in zip(rids, prompts, refs):
        oracle[rid] = (ref, len(p))
    out = {}
    while not eng.idle:
        eng.step()
        _pager_invariants(eng._scheduler.pager)
        out.update(eng.collect())
    st = eng.scheduler_stats
    assert 0 < st.accepted_tokens < st.draft_tokens
    assert st.rollbacks > 0
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    assert eng._scheduler.pager.pages_in_use == 0


def test_draft_model_mode_self_draft_full_acceptance(model_and_params):
    """Draft model = the target itself: greedy drafts match the target's
    argmax chain, so (near-)everything is accepted and steps collapse —
    with streams still identical to sequential decode."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 12, 9), seed=9)
    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  draft_model=m, draft_params=params)
    rids = [eng.submit(p, 12) for p in prompts]
    out = eng.drain()
    st = eng.scheduler_stats
    assert st.accepted_tokens == st.draft_tokens > 0
    assert st.spec_tokens_per_row > 3.0
    refs = _refs(eng, prompts, 12)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    assert eng._scheduler.pager.pages_in_use == 0


def test_spec_with_prefix_sharing(model_and_params):
    """Speculative decode composes with prefix sharing: aliased prompt
    pages are still skipped, never rolled back, and streams match the
    unshared spec engine."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(10)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (t,)
                                            ).astype(np.int32)])
               for t in (4, 7, 3)]

    def serve(prefix_id):
        eng = _engine(m, params, spec_decode="ngram", spec_k=4)
        rids = [eng.submit(p, 8, prefix_id=prefix_id) for p in prompts]
        out = eng.drain()
        assert eng._scheduler.pager.pages_in_use == 0
        _pager_invariants(eng._scheduler.pager)
        return [list(out[r]) for r in rids], eng._scheduler.stats

    shared, st_s = serve("sys")
    unshared, st_u = serve(None)
    assert shared == unshared
    assert st_s.prefix_shared_pages > 0
    assert st_s.prefill_tokens_skipped > st_u.prefill_tokens_skipped == 0


def test_spec_sampled_mixed_rows(model_and_params):
    """Sampled rows ride the speculative dispatch: greedy rows in the
    same batch stay token-identical to sequential greedy, hot rows finish
    with full-length deterministic (seeded) streams."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (6, 9, 7), seed=11)

    def serve():
        eng = _engine(m, params, spec_decode="ngram", spec_k=3, seed=5)
        r_g = eng.submit(np.tile(prompts[0][:3], 4), 10,
                         sampler=SamplerConfig(0.0))
        r_h = eng.submit(prompts[1], 10,
                         sampler=SamplerConfig(temperature=1.5, top_k=8))
        r_w = eng.submit(prompts[2], 10,
                         sampler=SamplerConfig(temperature=0.7))
        out = eng.drain()
        assert eng._scheduler.pager.pages_in_use == 0
        return {"g": list(out[r_g]), "h": list(out[r_h]),
                "w": list(out[r_w])}, eng

    a, eng = serve()
    b, _ = serve()
    assert a == b                               # deterministic per seed
    ref = eng.generate({"tokens": jnp.asarray(
        np.tile(prompts[0][:3], 4))[None, :]}, 10)[0]
    np.testing.assert_array_equal(a["g"], ref)  # greedy row unaffected
    assert len(a["h"]) == 10 and len(a["w"]) == 10


def test_eos_mid_acceptance_stops_stream(model_and_params):
    """EOS inside an accepted draft run ends the request exactly there —
    the trailing accepted/bonus tokens are dropped."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (7,), seed=12)
    eng0 = _engine(m, params)
    ref = _refs(eng0, prompts, 8)[0]
    eos = int(ref[3])
    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  draft_model=m, draft_params=params)
    rid = eng.submit(prompts[0], 8, eos_id=eos)
    out = eng.drain()
    stream = out[rid]
    np.testing.assert_array_equal(stream, ref[: len(stream)])
    assert int(stream[-1]) == eos
    assert list(stream).index(eos) == len(stream) - 1
    assert eng._scheduler.pager.pages_in_use == 0


def test_spec_requires_chunked_path(model_and_params):
    cfg, m, params = model_and_params
    eng = _engine(m, params, spec_decode="ngram", chunked_prefill=False)
    with pytest.raises(ValueError, match="chunked"):
        eng.submit(np.arange(4, dtype=np.int32), 4)
    with pytest.raises(ValueError, match="spec_decode"):
        _engine(m, params, spec_decode="medusa")
    with pytest.raises(ValueError, match="draft_model"):
        _engine(m, params, spec_decode="draft_model")
