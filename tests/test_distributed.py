"""Sharded execution on a multi-device (placeholder) mesh.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=8 so the
main pytest process keeps its single real device (dry-run instruction #0).
Covers: param pspec rules, sharded train step ≡ single-device step, elastic
checkpoint restore onto a different mesh shape, SP-decode cache sharding.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import repro.configs as C
from repro.models import build_model
from repro.distributed import sharding as shd
from repro.training import TrainConfig, AdamWConfig, make_train_step
from repro.training.train_step import init_train_state
from repro.data import make_dataset
from repro.checkpoint import save, restore
import tempfile

cfg = C.get_smoke_config("qwen25-05b")
m = build_model(cfg)
out = {}

dev = np.asarray(jax.devices()).reshape(2, 4)
mesh = Mesh(dev, ("data", "model"))

# --- param pspec rules on the real param tree ---
params = m.init(jax.random.PRNGKey(0))
specs = shd.pspec_tree(params, mesh, shd.param_pspec, cfg)
from repro.utils.tree import flatten_with_paths
for (path, leaf), (_, spec) in zip(flatten_with_paths(params),
                                   flatten_with_paths(specs)):
    for dim, ax in zip(leaf.shape, list(spec) + [None]*(leaf.ndim-len(spec))):
        if ax is not None:
            sz = mesh.shape[ax] if isinstance(ax, str) else np.prod([mesh.shape[a] for a in ax])
            assert dim % sz == 0, (path, leaf.shape, spec)
out["pspec_rules"] = "ok"

# --- sharded train step equals single-device ---
tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                       decay_steps=10, weight_decay=0.0),
                 grad_comm_dtype="float32")
ds = make_dataset(cfg, 8, 32)
batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

state0 = init_train_state(m, jax.random.PRNGKey(0))
step_plain = jax.jit(make_train_step(m, tc))
_, m_plain = step_plain(state0, batch)

with shd.use_mesh(mesh):
    state = init_train_state(m, jax.random.PRNGKey(0))
    pshard = shd.make_sharding(state["params"], mesh, shd.param_pspec, cfg)
    state["params"] = jax.tree.map(jax.device_put, state["params"], pshard)
    bshard = NamedSharding(mesh, P("data", None))
    batch_s = {k: jax.device_put(v, bshard) for k, v in batch.items()}
    step_sharded = jax.jit(make_train_step(m, tc))
    state_s, m_shard = step_sharded(state, batch_s)
assert abs(float(m_plain["loss"]) - float(m_shard["loss"])) < 1e-3, \
    (float(m_plain["loss"]), float(m_shard["loss"]))
out["sharded_step_matches"] = "ok"

# --- elastic restore onto a different mesh ---
with tempfile.TemporaryDirectory() as d:
    save(d, 1, state_s)
    mesh2 = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    tpl = jax.eval_shape(lambda: init_train_state(m, jax.random.PRNGKey(0)))
    shard2 = shd.make_sharding(tpl["params"], mesh2, shd.param_pspec, cfg)
    st2, _ = restore(d, tpl, shardings={"params": shard2,
                                        "opt": {"m": shard2, "v": shard2},
                                        "step": NamedSharding(mesh2, P())})
    with shd.use_mesh(mesh2):
        _, m2 = jax.jit(make_train_step(m, tc))(st2, batch)
    assert np.isfinite(float(m2["loss"]))
out["elastic_restore"] = "ok"

# --- SP-decode: cache sequence-sharded over model axis ---
cache = m.init_cache(8, 64)
cshard = shd.make_sharding(cache, mesh, shd.cache_pspec, cfg)
from repro.utils.tree import flatten_with_paths as fwp
kspec = [s.spec for (p, s) in fwp(cshard) if p.endswith("/k")][0]
assert kspec[2] == "model" or kspec[1] == "model", kspec  # seq dim sharded
with shd.use_mesh(mesh):
    cache = jax.tree.map(jax.device_put, cache, cshard)
    params_s = jax.tree.map(jax.device_put, params,
                            shd.make_sharding(params, mesh, shd.param_pspec, cfg))
    tok = jnp.zeros((8,), jnp.int32)
    pos = jnp.zeros((8,), jnp.int32)
    logits, cache2 = jax.jit(m.decode_step)(params_s, cache, tok, pos)
logits_plain, _ = jax.jit(m.decode_step)(params, m.init_cache(8, 64), tok, pos)
assert float(jnp.abs(logits - logits_plain).max()) < 2e-2
out["sp_decode"] = "ok"

print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_multidevice_sharding_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULT:"):])
    assert res == {"pspec_rules": "ok", "sharded_step_matches": "ok",
                   "elastic_restore": "ok", "sp_decode": "ok"}
