"""Data pipeline: determinism, resume, sharding, signal."""
import numpy as np

import repro.configs as C
from repro.data import make_dataset


def test_batch_pure_function_of_seed_step():
    cfg = C.get_smoke_config("qwen25-05b")
    ds1 = make_dataset(cfg, 4, 64, seed=7)
    ds2 = make_dataset(cfg, 4, 64, seed=7)
    for step in (0, 5, 1000):
        b1, b2 = ds1.batch_at(step), ds2.batch_at(step)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
    assert not np.array_equal(ds1.batch_at(0)["tokens"],
                              ds1.batch_at(1)["tokens"])


def test_labels_are_next_tokens():
    cfg = C.get_smoke_config("qwen25-05b")
    b = make_dataset(cfg, 2, 32).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_stream_is_compressible():
    """Next-token entropy must be below uniform (training signal exists)."""
    cfg = C.get_smoke_config("qwen25-05b")
    b = make_dataset(cfg, 16, 256).batch_at(0)
    toks, labels = b["tokens"], b["labels"]
    pred = (toks.astype(np.int64) * 31 + 7) % min(cfg.vocab_size, 4096)
    acc = (pred == labels).mean()
    assert acc > 0.5  # deterministic transition hit ~90% of the time


def test_host_slice_partitions():
    cfg = C.get_smoke_config("qwen25-05b")
    ds = make_dataset(cfg, 8, 16)
    b = ds.batch_at(0)
    parts = [ds.host_slice(b, h, 4) for h in range(4)]
    recon = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(recon, b["tokens"])


def test_modalities():
    cfg = C.get_smoke_config("hubert-xlarge")
    b = make_dataset(cfg, 2, 32).batch_at(0)
    assert b["features"].shape == (2, 32, cfg.frontend_dim)
    cfg = C.get_smoke_config("phi-3-vision-4.2b")
    b = make_dataset(cfg, 2, 32).batch_at(0)
    assert b["images"].shape == (2, cfg.num_patches, cfg.frontend_dim)
