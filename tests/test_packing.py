"""Packing: int32 nibble layout + byte-exact AWQ_MACRO serialization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests fall back to parametrized samples
    HAVE_HYPOTHESIS = False

from repro.core.packing import (awq_macro_bytes, awq_macro_nbytes,
                                pack_int4, packed_linear_nbytes,
                                parse_awq_macro_bytes, unpack_int4)


def test_pack_unpack_exact():
    q = jax.random.randint(jax.random.PRNGKey(0), (128, 24), 0, 16)
    assert bool(jnp.all(unpack_int4(pack_int4(q)) == q))


def test_nibble_order_matches_paper_unpack_unit():
    # nibble j of word w holds row w*8+j (shift/mask order, Fig. 4b)
    q = jnp.arange(16).reshape(16, 1) % 16
    packed = np.asarray(pack_int4(q))
    assert packed.shape == (2, 1)
    w0 = int(np.uint32(packed[0, 0]))
    for j in range(8):
        assert (w0 >> (4 * j)) & 0xF == j


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 5), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
    def test_property_pack_roundtrip(k8, n, seed):
        q = jax.random.randint(jax.random.PRNGKey(seed), (8 * k8, n), 0, 16)
        assert bool(jnp.all(unpack_int4(pack_int4(q)) == q))
else:
    @pytest.mark.parametrize("k8,n,seed", [
        (1, 1, 0), (2, 3, 7), (3, 4, 1234), (5, 2, 2 ** 31 - 1),
        (4, 1, 42),
    ])
    def test_property_pack_roundtrip(k8, n, seed):
        q = jax.random.randint(jax.random.PRNGKey(seed), (8 * k8, n), 0, 16)
        assert bool(jnp.all(unpack_int4(pack_int4(q)) == q))


def test_awq_macro_bytes_rate():
    # paper layout: GS=64 → 4.5 bits/weight exactly
    assert awq_macro_nbytes(64) == 64 * 4 + 16 + 16
    nbytes = packed_linear_nbytes(896, 4864, 64)
    bits_per_w = nbytes * 8 / (896 * 4864)
    assert abs(bits_per_w - 4.5) < 1e-9


def test_awq_macro_serialization_roundtrip():
    rng = np.random.default_rng(0)
    k, n, gs = 128, 16, 64
    q = rng.integers(0, 16, (k, n)).astype(np.uint8)
    s = rng.random((k // gs, n)).astype(np.float16)
    z = rng.integers(0, 16, (k // gs, n)).astype(np.uint8)
    buf = awq_macro_bytes(q, s, z, gs)
    assert len(buf) == packed_linear_nbytes(k, n, gs)
    q2, s2, z2 = parse_awq_macro_bytes(buf, k, n, gs)
    np.testing.assert_array_equal(q, q2)
    np.testing.assert_array_equal(z, z2)
    np.testing.assert_array_equal(s, s2)


def test_zeros_strip_padding():
    """The 96-bit padding of the zeros strip is all zero bytes (§III-A)."""
    k, n, gs = 64, 8, 64
    q = np.zeros((k, n), np.uint8)
    s = np.ones((1, n), np.float16)
    z = np.full((1, n), 15, np.uint8)
    buf = awq_macro_bytes(q, s, z, gs)
    macro = buf[:awq_macro_nbytes(gs)]
    zeros_strip = macro[gs * 4 + 16:]
    assert len(zeros_strip) == 16
    assert zeros_strip[:4] == b"\xff" * 4     # 8 × INT4 zeros = 15
    assert zeros_strip[4:] == b"\x00" * 12    # 96-bit padding
