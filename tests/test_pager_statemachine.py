"""Property-based lifecycle harness for the pager state machine.

`PagerModel` drives a real `KVPager` through randomized interleavings of
every lifecycle op — admit / commit_chunk / decode-extend / truncate /
spill / restore / drop / free / prefix alias / register / pin / unpin —
while maintaining a **symbolic device pool**: a `[num_pages, page_size]`
int array where every committed token writes a content value that is a
pure function of (request, position). After every op it asserts

  * `KVPager.verify_invariants()` — free-exactly-once, refcount ==
    owner count (slots + pins + spill-kept), reservation consistency,
    page-table mirrors, watermark/length coverage, slot partition;
  * byte identity — gathering each active slot's pages reproduces the
    request's expected token content exactly. Freed pages are clobbered
    with a sentinel immediately (simulating reuse by another request),
    so any read-after-free or lost spill byte shows up as a sentinel;
  * restore ≡ never-spilled — the expected content is defined without
    reference to spills, so a restored slot passing the byte check IS
    the "restore reproduces the uninterrupted bytes" invariant;
  * error-path hardening — ops on spilled/freed slots and dead spill
    records are probed after every spill/restore/free and must raise
    `PageAllocationError` without mutating anything.

Two drivers share the model:

  * a seeded random walk that ALWAYS runs (no third-party deps) — the
    tier-1 fallback when `hypothesis` is not installed;
  * a `hypothesis` `RuleBasedStateMachine` (CI installs hypothesis; see
    pyproject `[test]`) where hypothesis owns the op-seed sequence and
    shrinks failing interleavings. Profiles: ``tier1`` (derandomized,
    fast — the default), ``ci`` (derandomized, 500+ examples), ``dev``
    (randomized). Select with ``HYPOTHESIS_PROFILE``.
"""
import os
import random

import numpy as np
import pytest

from repro.serving.kv_pager import (KVPager, PageAllocationError,
                                    PagerConfig)

P = 4                  # tokens per page
NUM_PAGES = 14         # 13 usable — 4 slots × 5 pages demand 20: contention
NUM_SLOTS = 4
PAGES_PER_SLOT = 5     # 20-token slot capacity
SENTINEL = -1

# shared-prefix templates: identical (prefix_id, prompt) pairs alias;
# one length is page-aligned so fully-aliased prompts occur
_TEMPLATE_LENS = (8, 10, 5)


def _template_prompt(i: int) -> np.ndarray:
    t = np.arange(_TEMPLATE_LENS[i])
    return ((i * 1009 + t * 17) % 50021 + 1).astype(np.int64)


class PagerModel:
    """Real pager + symbolic device pool + expected-content oracle."""

    def __init__(self, *, optimistic: bool):
        self.pager = KVPager(PagerConfig(
            num_pages=NUM_PAGES, page_size=P, num_slots=NUM_SLOTS,
            pages_per_slot=PAGES_PER_SLOT, optimistic=optimistic))
        self.pool = np.full((NUM_PAGES, P), SENTINEL, np.int64)
        self.active: dict[int, dict] = {}     # slot → request state
        self.parked: list[dict] = []          # spilled: state+record+shadow
        self.next_rid = 0
        # coverage counters, so a driver can assert the walk did not
        # silently degenerate into admit/free-only traffic
        self.counts = {"admit": 0, "spill": 0, "restore": 0, "drop": 0,
                       "truncate": 0, "alias": 0}

    # ------------------------------------------------------------- oracle
    @staticmethod
    def _expected_stream(rid: int, prompt: np.ndarray,
                         max_new: int) -> np.ndarray:
        """Full expected KV content, position 0 .. prompt+max_new-2.

        Prompt positions hold the prompt token (identical across aliased
        requests by construction); decode positions hold a rid-unique
        chain value. Defined with NO reference to spills — a restored
        slot matching this is byte-identical to a never-spilled run.
        """
        cap = len(prompt) + max_new - 1
        t = np.arange(len(prompt), cap)
        gen = (rid * 7919 + t * 131) % 99991 + 1
        return np.concatenate([prompt, gen])

    def _write(self, slot: int, a: int, b: int) -> None:
        pages = self.pager.slot_pages[slot]
        exp = self.active[slot]["exp"]
        for t in range(a, b):
            pg = pages[t // P]
            assert pg != 0, "model would write the scratch page"
            self.pool[pg, t % P] = exp[t]

    def _clobber_free(self) -> None:
        """Freed pages are immediately reused by 'someone else'."""
        if self.pager.free_pages:
            self.pool[list(self.pager.free_pages)] = SENTINEL

    def check(self) -> None:
        self.pager.verify_invariants()
        assert (self.pool[0] == SENTINEL).all(), "scratch page written"
        for slot, stt in self.active.items():
            pages = self.pager.slot_pages[slot]
            got = self.pool[pages].reshape(-1)[: stt["written"]]
            want = stt["exp"][: stt["written"]]
            assert (got == want).all(), (
                f"slot {slot} rid {stt['rid']}: committed KV bytes diverge "
                f"at positions {np.nonzero(got != want)[0][:8]}")
        st = self.pager.stats()
        assert st.spill_records == len(self.parked)
        assert st.pages_spilled == sum(p["rec"].n_spilled
                                       for p in self.parked)

    # ---------------------------------------------------------------- ops
    def op_admit(self, rng) -> None:
        rid = self.next_rid
        self.next_rid += 1
        tmpl = rng.choice([None, None, 0, 1, 2])
        if tmpl is None:
            plen = rng.randint(1, 12)
            t = np.arange(plen)
            prompt = ((rid * 37 + t * 11) % 49999 + 1).astype(np.int64)
            prefix_id = None
        else:
            prompt = _template_prompt(tmpl)
            plen = len(prompt)
            prefix_id = f"tmpl{tmpl}"
        cap = PAGES_PER_SLOT * P
        max_new = rng.randint(1, min(8, cap - plen + 1))
        shared = (self.pager.match_prefix(prompt, prefix_id)
                  if prefix_id is not None else [])
        if not self.pager.can_admit(plen, max_new, n_shared=len(shared)):
            with pytest.raises(PageAllocationError):
                self.pager.alloc_slot(plen, max_new, shared_pages=shared)
            return
        slot, _ = self.pager.alloc_slot(plen, max_new, shared_pages=shared)
        self.counts["admit"] += 1
        self.counts["alias"] += bool(shared)
        self.active[slot] = {
            "rid": rid, "prompt": prompt, "plen": plen, "max_new": max_new,
            "prefix_id": prefix_id,
            "exp": self._expected_stream(rid, prompt, max_new),
            # aliased prefix pages are already-resident content
            "written": self.pager.slot_committed[slot]}

    def _slots_where(self, pred) -> list[int]:
        return sorted(s for s, stt in self.active.items() if pred(stt, s))

    def op_commit(self, rng) -> None:
        cands = self._slots_where(lambda stt, s: stt["written"] < stt["plen"])
        if not cands:
            return
        slot = rng.choice(cands)
        stt = self.active[slot]
        before = self.pager.slot_committed[slot]
        end = rng.randint(stt["written"] + 1, stt["plen"])
        self.pager.commit_chunk(slot, stt["written"], end)
        assert self.pager.slot_committed[slot] == end >= before  # monotone
        self._write(slot, stt["written"], end)
        stt["written"] = end

    def op_register(self, rng) -> None:
        cands = self._slots_where(
            lambda stt, s: stt["prefix_id"] is not None
            and stt["written"] >= stt["plen"])
        if not cands:
            return
        slot = rng.choice(cands)
        stt = self.active[slot]
        self.pager.register_prefix(slot, stt["prompt"], stt["prefix_id"])

    def op_decode(self, rng) -> None:
        cands = self._slots_where(
            lambda stt, s: stt["written"] >= stt["plen"]
            and stt["written"] < len(stt["exp"]))
        if not cands:
            return
        slot = rng.choice(cands)
        stt = self.active[slot]
        n = rng.randint(1, min(4, len(stt["exp"]) - stt["written"]))
        try:
            self.pager.extend(slot, stt["written"] + n)
        except PageAllocationError:
            # optimistic mode, dry pool: the raise may leave the slot
            # holding extra drawn pages but never a longer length — the
            # invariant check below validates exactly that
            assert self.pager.cfg.optimistic
            return
        self._write(slot, stt["written"], stt["written"] + n)
        stt["written"] += n

    def op_truncate(self, rng) -> None:
        cands = self._slots_where(
            lambda stt, s: stt["written"] >= stt["plen"])
        if not cands:
            return
        slot = rng.choice(cands)
        stt = self.active[slot]
        new_len = rng.randint(max(stt["plen"], 1), stt["written"])
        if rng.random() < 0.25:      # probe: growth is not a truncation
            with pytest.raises(PageAllocationError):
                self.pager.truncate(slot, stt["written"] + P + 1)
        if stt["plen"] >= 2 and rng.random() < 0.25:
            with pytest.raises(PageAllocationError):   # below the prompt
                self.pager.truncate(slot, stt["plen"] - 1)
        self.pager.truncate(slot, new_len)
        self.counts["truncate"] += 1
        stt["written"] = min(stt["written"], new_len)
        self._clobber_free()

    def op_free(self, rng) -> None:
        if not self.active:
            return
        slot = rng.choice(sorted(self.active))
        self.pager.free_slot(slot)
        del self.active[slot]
        with pytest.raises(PageAllocationError):       # double free
            self.pager.free_slot(slot)
        self._clobber_free()

    def op_spill(self, rng) -> None:
        if not self.active:
            return
        slot = rng.choice(sorted(self.active))
        ids = self.pager.peek_spill(slot)
        shadow = self.pool[ids].copy() if ids else \
            np.zeros((0, P), np.int64)
        rec = self.pager.spill(slot)
        self.counts["spill"] += 1
        # spill order ≡ peek order: the engine gathered bytes by peek ids
        assert rec.spilled_pages == ids
        self.parked.append({"state": self.active.pop(slot), "rec": rec,
                            "shadow": shadow})
        # a spilled slot is inactive: every mutator must raise untouched
        for probe in (lambda: self.pager.spill(slot),
                      lambda: self.pager.truncate(slot, 1),
                      lambda: self.pager.extend(slot, 1),
                      lambda: self.pager.commit_chunk(slot, 0, 1),
                      lambda: self.pager.free_slot(slot)):
            with pytest.raises(PageAllocationError):
                probe()
        self._clobber_free()

    def op_restore(self, rng) -> None:
        ok = [p for p in self.parked if self.pager.can_restore(p["rec"])]
        if not ok:
            if self.parked:     # blocked: restore must raise untouched
                with pytest.raises(PageAllocationError):
                    self.pager.restore(rng.choice(self.parked)["rec"])
            return
        p = rng.choice(ok)
        slot, fresh = self.pager.restore(p["rec"])
        self.counts["restore"] += 1
        assert len(fresh) == p["rec"].n_spilled
        self.pool[fresh] = p["shadow"]        # engine scatter-back
        self.active[slot] = p["state"]
        self.parked.remove(p)
        with pytest.raises(PageAllocationError):       # dead record
            self.pager.restore(p["rec"])
        with pytest.raises(PageAllocationError):
            self.pager.drop_spill(p["rec"])

    def op_drop(self, rng) -> None:
        if not self.parked:
            return
        p = rng.choice(self.parked)
        self.pager.drop_spill(p["rec"])
        self.counts["drop"] += 1
        self.parked.remove(p)
        with pytest.raises(PageAllocationError):
            self.pager.drop_spill(p["rec"])
        self._clobber_free()

    def op_pin(self, rng) -> None:
        self.pager.pin_prefix(f"tmpl{rng.randint(0, 2)}")

    def op_unpin(self, rng) -> None:
        self.pager.unpin_prefix(f"tmpl{rng.randint(0, 2)}")
        self._clobber_free()

    _OPS = (("op_admit", 5), ("op_commit", 5), ("op_decode", 6),
            ("op_truncate", 2), ("op_register", 2), ("op_spill", 3),
            ("op_restore", 3), ("op_drop", 1), ("op_free", 2),
            ("op_pin", 1), ("op_unpin", 1))

    def random_op(self, rng) -> None:
        names = [n for n, w in self._OPS for _ in range(w)]
        getattr(self, rng.choice(names))(rng)
        self.check()

    def finish(self, rng) -> None:
        """Drain to empty: everything spilled or active releases, pins
        lift, and the pool must return to fully free — no leaked page,
        slot, reservation, or spill record survives a full lifecycle."""
        while self.parked:
            self.op_drop(rng)
        while self.active:
            self.op_free(rng)
        for i in range(3):
            self.pager.unpin_prefix(f"tmpl{i}")
        self.check()
        assert self.pager.pages_in_use == 0
        assert self.pager.num_free_pages == NUM_PAGES - 1
        assert self.pager.num_free_slots == NUM_SLOTS
        assert self.pager._reserved == 0
        assert not self.pager.spill_records


# ---------------------------------------------------------------------------
# Driver 1: seeded random walk — always runs, no third-party deps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("optimistic", [False, True])
@pytest.mark.parametrize("seed", range(3))
def test_random_walk_lifecycle(optimistic, seed):
    rng = random.Random(seed * 7919 + int(optimistic))
    model = PagerModel(optimistic=optimistic)
    for _ in range(400):
        model.random_op(rng)
    model.finish(rng)


def test_walk_actually_exercises_spill_restore():
    """Guard against the walk silently degenerating: across the tier-1
    seeds, every headline transition fires — admissions, prefix aliases,
    truncations, spills AND restores (not just spill-then-drop)."""
    totals = {k: 0 for k in ("admit", "spill", "restore", "drop",
                             "truncate", "alias")}
    for seed in range(3):
        rng = random.Random(seed * 7919 + 1)
        model = PagerModel(optimistic=True)
        for _ in range(400):
            model.random_op(rng)
        model.finish(rng)
        for k, v in model.counts.items():
            totals[k] += v
    assert all(totals[k] > 0 for k in totals), totals


# ---------------------------------------------------------------------------
# Driver 2: hypothesis RuleBasedStateMachine (installed in CI; the seeded
# walk above is the always-on fallback when it is absent locally)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, settings
    from hypothesis import strategies as hst
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised only locally
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _COMMON = dict(deadline=None, stateful_step_count=50,
                   suppress_health_check=[HealthCheck.too_slow,
                                          HealthCheck.filter_too_much])
    # derandomized profiles so tier-1 and CI runs are reproducible; the
    # acceptance bar is the `ci` profile's 500 examples
    settings.register_profile("tier1", max_examples=40, derandomize=True,
                              **_COMMON)
    settings.register_profile("ci", max_examples=500, derandomize=True,
                              print_blob=True, **_COMMON)
    settings.register_profile("dev", max_examples=200, print_blob=True,
                              **_COMMON)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "tier1"))

    class PagerLifecycleMachine(RuleBasedStateMachine):
        """Hypothesis owns the per-op seed sequence (so a failing
        interleaving shrinks to a minimal op list); each drawn seed
        applies one weighted lifecycle op through `PagerModel`, which
        re-verifies every invariant itself."""

        def __init__(self):
            super().__init__()
            self.model = None

        @initialize(optimistic=hst.booleans())
        def setup(self, optimistic):
            self.model = PagerModel(optimistic=optimistic)

        @rule(seed=hst.integers(min_value=0, max_value=2**32 - 1))
        def op(self, seed):
            self.model.random_op(random.Random(seed))

        @invariant()
        def accounting_holds(self):
            if self.model is not None:
                self.model.check()

        def teardown(self):
            if self.model is not None:
                self.model.finish(random.Random(0))

    TestPagerLifecycleMachine = PagerLifecycleMachine.TestCase
