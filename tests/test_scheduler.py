"""Continuous-batching scheduler: backfill, eviction, e2e token identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig
from repro.serving.kv_pager import KVPager, PagerConfig
from repro.serving.scheduler import Request, Scheduler


# ---------------------------------------------------------------------------
# Pure-scheduler tests against a fake executor (no model, no device work)
# ---------------------------------------------------------------------------

class _FakeExec:
    """Deterministic executor: first token = 100 + rid, decode echoes it."""

    def __init__(self):
        self.prefills = []
        self.decode_calls = 0

    def prefill_commit(self, req, slot, pages, n_shared=0):
        self.prefills.append((len(req.tokens), slot, tuple(pages), n_shared))
        return 100 + req.rid

    def decode(self, page_tables, token, pos, temps, topks):
        self.decode_calls += 1
        return token          # echo: every request repeats its first token


def _sched(num_slots=2, pages_per_slot=4, page_size=4, num_pages=None):
    ex = _FakeExec()
    if num_pages is None:
        num_pages = num_slots * pages_per_slot + 1
    pager = KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                                num_slots=num_slots,
                                pages_per_slot=pages_per_slot))
    return Scheduler(pager, prefill_commit=ex.prefill_commit,
                     decode=ex.decode), ex


def test_slot_backfill_after_finish():
    sched, ex = _sched(num_slots=2)
    for rid in range(4):
        sched.submit(Request(rid=rid, tokens=np.zeros(4, np.int32),
                             max_new_tokens=2))
    ev = sched.step()
    # only 2 slots: requests 0,1 admitted (first tokens), decoded to
    # completion (2 tokens each), then 2,3 backfilled in the same step
    assert sched.stats.admitted == 4
    assert sched.stats.finished == 2
    assert sched.num_active == 2
    out = sched.run()
    assert sorted(out) == [0, 1, 2, 3]
    assert all(len(v) == 2 for v in out.values())
    # pager fully drained after completion
    assert sched.pager.pages_in_use == 0
    assert sched.pager.num_free_slots == 2


def test_eos_evicts_and_frees_pages():
    sched, ex = _sched(num_slots=1)
    # fake decode echoes the first token (101 for rid 1, admitted second)
    sched.submit(Request(rid=0, tokens=np.zeros(4, np.int32),
                         max_new_tokens=8, eos_id=100))
    sched.submit(Request(rid=1, tokens=np.zeros(4, np.int32),
                         max_new_tokens=3, eos_id=-1))
    ev = sched.step()
    # rid 0's first token IS its eos (fake prefill puts argmax at 100) →
    # finished at admission without occupying a decode step; rid 1 backfills
    assert (0, 100) in ev
    assert 0 in sched.finished and len(sched.finished[0]) == 1
    out = sched.run()
    assert list(out[1]) == [101, 101, 101]
    assert sched.pager.pages_in_use == 0


def test_queue_waits_for_capacity():
    # 1 slot, 4 usable pages; request reserving all pages blocks the queue
    sched, ex = _sched(num_slots=1, pages_per_slot=4, page_size=4,
                       num_pages=5)
    sched.submit(Request(rid=0, tokens=np.zeros(4, np.int32),
                         max_new_tokens=13))      # 16 tokens → all 4 pages
    sched.submit(Request(rid=1, tokens=np.zeros(4, np.int32),
                         max_new_tokens=1))
    sched.step()
    assert sched.num_active == 1 and len(sched.queue) == 1
    out = sched.run()
    assert sorted(out) == [0, 1]


def test_rejects_invalid_requests():
    sched, _ = _sched()
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, tokens=np.zeros(0, np.int32),
                             max_new_tokens=2))
    with pytest.raises(ValueError):
        sched.submit(Request(rid=1, tokens=np.zeros(2, np.int32),
                             max_new_tokens=0))
    # a request that could never fit a slot must be rejected up front,
    # not left to livelock the queue (slot capacity = 4 pages × 4 tokens)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=2, tokens=np.zeros(10, np.int32),
                             max_new_tokens=8))
    assert not sched.queue


# ---------------------------------------------------------------------------
# End-to-end: continuous batching ≡ per-request generate() under greedy
# ---------------------------------------------------------------------------

def _engine(**kw):
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, GenerationEngine(m, params, max_seq=64, num_slots=4,
                                 page_size=8, **kw)


def test_continuous_batching_matches_sequential_greedy():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 12, 9, 17, 7, 21, 3, 14)]
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.drain()
    assert sorted(out) == sorted(rids)
    for p, rid in zip(prompts, rids):
        ref = eng.generate({"tokens": jnp.asarray(p)[None, :]}, 10)[0]
        np.testing.assert_array_equal(out[rid], ref[: len(out[rid])])
        assert len(out[rid]) == 10           # no eos in this vocab range


def test_continuous_batching_eos_truncates():
    cfg, eng = _engine()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (6, 11, 4)]
    # pick each request's eos to be its 4th greedy token → length 4 streams
    refs = [np.asarray(eng.generate({"tokens": jnp.asarray(p)[None, :]}, 8)[0])
            for p in prompts]
    rids = [eng.submit(p, 8, eos_id=int(r[3])) for p, r in zip(prompts, refs)]
    out = eng.drain()
    for rid, r in zip(rids, refs):
        stream = out[rid]
        np.testing.assert_array_equal(stream, r[: len(stream)])
        assert int(stream[-1]) == int(r[3]) and len(stream) <= 8
        # eos may legitimately appear earlier if the same token repeats
        assert list(stream).index(int(r[3])) == len(stream) - 1


def test_per_request_sampling_params():
    cfg, eng = _engine()
    rng = np.random.default_rng(2)
    greedy_p = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    hot_p = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    r_greedy = eng.submit(greedy_p, 12, sampler=SamplerConfig(0.0))
    r_hot = eng.submit(hot_p, 12, sampler=SamplerConfig(temperature=5.0))
    out = eng.drain()
    ref = eng.generate({"tokens": jnp.asarray(greedy_p)[None, :]}, 12)[0]
    # greedy row unaffected by the hot row sharing the batch
    np.testing.assert_array_equal(out[r_greedy], ref)
    assert len(out[r_hot]) == 12


def test_more_requests_than_slots_all_complete():
    cfg, eng = _engine()
    rng = np.random.default_rng(3)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, (1 + (i % 5),)
                                    ).astype(np.int32), 2 + (i % 7))
            for i in range(11)]
    out = eng.drain()
    assert sorted(out) == sorted(rids)
    st = eng.scheduler_stats
    assert st.admitted == 11 and st.finished == 11
    assert eng._scheduler.pager.pages_in_use == 0
