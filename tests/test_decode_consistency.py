"""Decode path ≡ parallel forward: the strongest cache/RoPE/ring/SSD check.

Per-arch tolerance: bf16 activations; MLA's absorbed decode is a different
(mathematically equal) contraction order, so its bf16 rounding differs more
(verified exact in f32 — see EXPERIMENTS.md §Validation).

MoE archs get a **robust quantile** assertion instead of a strict max:
top-k routing is discrete, so a near-tied gate (probs within bf16 rounding
of each other) can legitimately flip between the decode contraction and
the parallel forward — that token then runs a different expert and its
logits diverge by O(1) while every agreeing position stays within the
numeric tolerance (diagnosed on deepseek-v2-lite: one flipped token at
max-err 1.64, ~0.05 elsewhere; identical with an f32 cache). We therefore
assert that ≥ 90% of (batch, position) cells agree within tolerance and
that the flipped remainder stays bounded, rather than letting a single
router tie mark the whole decode path red.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model

TOL = {"deepseek-v2-lite-16b": 1e-1, "phi-3-vision-4.2b": 5e-2}
B, S_PRE, S_DEC = 2, 40, 20  # decode crosses the smoke window (32)
ROUTING_FLIP_QUANTILE = 0.90  # fraction of cells that must agree (MoE only)
ROUTING_FLIP_CEIL = 10.0      # even flipped-expert logits stay O(1)


@pytest.mark.parametrize("arch", [a for a in C.list_archs()
                                  if not C.get_smoke_config(a).is_encoder])
def test_decode_matches_forward(arch):
    cfg = C.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_PRE + S_DEC)),
                       jnp.int32)
    batch = {"tokens": toks[:, :S_PRE]}
    if cfg.frontend == "vision":
        batch["images"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    full = dict(batch)
    full["tokens"] = toks
    ref = jax.jit(m.forward_logits)(params, full)
    cache = m.init_cache(B, 128)
    cache, logits, pos = jax.jit(m.prefill)(params, batch, cache)
    off = cfg.num_patches if cfg.frontend == "vision" else 0
    tol = TOL.get(arch, 3e-2)
    # per-(batch, position) max-abs error, so discrete routing flips can be
    # told apart from systematic cache bugs
    errs = [np.asarray(jnp.abs(logits - ref[:, off + S_PRE - 1]).max(-1))]
    dstep = jax.jit(m.decode_step)
    for t in range(S_DEC):
        logits, cache = dstep(params, cache, toks[:, S_PRE + t], pos)
        pos = pos + 1
        errs.append(np.asarray(
            jnp.abs(logits - ref[:, off + S_PRE + t]).max(-1)))
    cells = np.stack(errs)                       # [S_DEC + 1, B]
    has_moe = any(k.mlp == "moe" for k in cfg.layer_kinds())
    if not has_moe:
        assert cells.max() < tol, (arch, cells.max())
        return
    # MoE: routing is discrete — compare where routing agrees (the robust
    # quantile), and bound the near-tie flips instead of failing on them
    agree = float(np.quantile(cells, ROUTING_FLIP_QUANTILE))
    assert agree < tol, (arch, "routing-agreeing cells diverge", agree)
    assert cells.max() < ROUTING_FLIP_CEIL, (arch, cells.max())


def test_mla_absorbed_decode_exact_in_f32():
    import dataclasses
    cfg = dataclasses.replace(C.get_smoke_config("deepseek-v2-lite-16b"),
                              activation_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 48)), jnp.int32)
    ref = jax.jit(m.forward_logits)(params, {"tokens": toks})
    cache = m.init_cache(B, 128, dtype=jnp.float32)
    cache, logits, pos = jax.jit(m.prefill)(
        params, {"tokens": toks[:, :40]}, cache)
    errs = [float(jnp.abs(logits - ref[:, 39]).max())]
    dstep = jax.jit(m.decode_step)
    for t in range(8):
        logits, cache = dstep(params, cache, toks[:, 40 + t], pos)
        pos = pos + 1
        errs.append(float(jnp.abs(logits - ref[:, 40 + t]).max()))
    assert max(errs) < 1e-4, errs


def test_ring_buffer_positions():
    from repro.models.attention import _ring_positions
    pos = jnp.asarray([5, 8, 40])
    kp = np.asarray(_ring_positions(pos, 8))
    # slot s holds newest p ≤ pos with p ≡ s (mod 8); unwritten → negative
    assert kp[0, 5] == 5 and kp[0, 6] == -2  # pos 5: slot 6 unwritten
    assert kp[1, 0] == 8 and kp[1, 1] == 1
    assert (kp[2] > 32).all()                # full window at pos 40
    for s in range(8):
        assert kp[2, s] % 8 == s
