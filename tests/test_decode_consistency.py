"""Decode path ≡ parallel forward: the strongest cache/RoPE/ring/SSD check.

Per-arch tolerance: bf16 activations; MLA's absorbed decode is a different
(mathematically equal) contraction order, so its bf16 rounding differs more
(verified exact in f32 — see EXPERIMENTS.md §Validation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model

TOL = {"deepseek-v2-lite-16b": 1e-1, "phi-3-vision-4.2b": 5e-2}
B, S_PRE, S_DEC = 2, 40, 20  # decode crosses the smoke window (32)


@pytest.mark.parametrize("arch", [a for a in C.list_archs()
                                  if not C.get_smoke_config(a).is_encoder])
def test_decode_matches_forward(arch):
    cfg = C.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_PRE + S_DEC)),
                       jnp.int32)
    batch = {"tokens": toks[:, :S_PRE]}
    if cfg.frontend == "vision":
        batch["images"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    full = dict(batch)
    full["tokens"] = toks
    ref = jax.jit(m.forward_logits)(params, full)
    cache = m.init_cache(B, 128)
    cache, logits, pos = jax.jit(m.prefill)(params, batch, cache)
    off = cfg.num_patches if cfg.frontend == "vision" else 0
    tol = TOL.get(arch, 3e-2)
    errs = [float(jnp.abs(logits - ref[:, off + S_PRE - 1]).max())]
    dstep = jax.jit(m.decode_step)
    for t in range(S_DEC):
        logits, cache = dstep(params, cache, toks[:, S_PRE + t], pos)
        pos = pos + 1
        errs.append(float(jnp.abs(logits - ref[:, off + S_PRE + t]).max()))
    assert max(errs) < tol, (arch, max(errs))


def test_mla_absorbed_decode_exact_in_f32():
    import dataclasses
    cfg = dataclasses.replace(C.get_smoke_config("deepseek-v2-lite-16b"),
                              activation_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 48)), jnp.int32)
    ref = jax.jit(m.forward_logits)(params, {"tokens": toks})
    cache = m.init_cache(B, 128, dtype=jnp.float32)
    cache, logits, pos = jax.jit(m.prefill)(
        params, {"tokens": toks[:, :40]}, cache)
    errs = [float(jnp.abs(logits - ref[:, 39]).max())]
    dstep = jax.jit(m.decode_step)
    for t in range(8):
        logits, cache = dstep(params, cache, toks[:, 40 + t], pos)
        pos = pos + 1
        errs.append(float(jnp.abs(logits - ref[:, 40 + t]).max()))
    assert max(errs) < 1e-4, errs


def test_ring_buffer_positions():
    from repro.models.attention import _ring_positions
    pos = jnp.asarray([5, 8, 40])
    kp = np.asarray(_ring_positions(pos, 8))
    # slot s holds newest p ≤ pos with p ≡ s (mod 8); unwritten → negative
    assert kp[0, 5] == 5 and kp[0, 6] == -2  # pos 5: slot 6 unwritten
    assert kp[1, 0] == 8 and kp[1, 1] == 1
    assert (kp[2] > 32).all()                # full window at pos 40
    for s in range(8):
        assert kp[2, s] % 8 == s
