"""Fused dequant+paged-attention kernel vs. oracle + int8 page round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import build_model
from repro.models.attention import _kv_dequant, _kv_quantize
from repro.serving.kv_pager import commit_prefill


@pytest.mark.parametrize("b,hkv,g,hd,page,nblk,npages", [
    (3, 2, 4, 64, 8, 4, 12),     # GQA, several pages
    (2, 1, 1, 128, 16, 2, 6),    # MQA, single group
    (4, 2, 9, 64, 8, 3, 20),     # group dim not a sublane multiple (pad)
    (1, 4, 2, 64, 16, 5, 40),
])
def test_kernel_matches_oracle(b, hkv, g, hd, page, nblk, npages):
    rng = np.random.default_rng(hash((b, hkv, g)) % 2**31)
    kf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)) * 2,
                     jnp.float32)
    vf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    k, ks = _kv_quantize(kf)
    v, vs = _kv_quantize(vf)
    q = jnp.asarray(rng.normal(size=(b, hkv, g, hd)), jnp.float32)
    table = jnp.asarray(rng.integers(1, npages, (b, nblk)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, page * nblk, (b,)), jnp.int32)
    out = paged_attention(q, k, ks, v, vs, table, pos, interpret=True)
    ref = paged_attention_ref(q, k, ks, v, vs, table, pos)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_kernel_masks_stale_table_entries():
    """Table slots past the valid range point at the scratch page; their
    positions exceed pos so they must never leak into the softmax."""
    rng = np.random.default_rng(0)
    npages, page, hkv, hd, nblk = 8, 8, 2, 64, 4
    kf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    k, ks = _kv_quantize(kf)
    v, vs = _kv_quantize(vf)
    q = jnp.asarray(rng.normal(size=(1, hkv, 2, hd)), jnp.float32)
    pos = jnp.asarray([5], jnp.int32)                    # page 0 only
    t_clean = jnp.asarray([[3, 0, 0, 0]], jnp.int32)
    t_stale = jnp.asarray([[3, 7, 6, 5]], jnp.int32)     # garbage beyond pos
    a = paged_attention(q, k, ks, v, vs, t_clean, pos, interpret=True)
    b = paged_attention(q, k, ks, v, vs, t_stale, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_paged_jnp_path_matches_kernel():
    """The module's gather+dequant fallback ≡ the fused kernel (same math,
    online-softmax reassociation only)."""
    from repro.models import attention as attn_mod

    cfg = C.get_smoke_config("qwen25-05b")
    rng = np.random.default_rng(3)
    npages, page, nblk = 9, 8, 3
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    g = cfg.num_heads // hkv
    kf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    k, ks = _kv_quantize(kf)
    v, vs = _kv_quantize(vf)
    q = jnp.asarray(rng.normal(size=(2, hkv, g, hd)), jnp.float32)
    table = jnp.asarray(rng.integers(1, npages, (2, nblk)), jnp.int32)
    pos = jnp.asarray([7, 19], jnp.int32)
    out = paged_attention(q, k, ks, v, vs, table, pos, interpret=True)
    # reproduce the fallback's gather math
    s_slot = nblk * page
    ck = _kv_dequant(k[table].reshape(2, s_slot, hkv, hd),
                     ks[table].reshape(2, s_slot, hkv), jnp.float32)
    cv = _kv_dequant(v[table].reshape(2, s_slot, hkv, hd),
                     vs[table].reshape(2, s_slot, hkv), jnp.float32)
    k_pos = jnp.where(jnp.arange(s_slot)[None, :] <= pos[:, None],
                      jnp.arange(s_slot)[None, :], -1)
    ref = attn_mod._sdpa(q[:, None].reshape(2, 1, hkv, g, hd), ck, cv,
                         pos[:, None], k_pos, causal=False, window=0,
                         scale=hd ** -0.5)
    assert float(jnp.abs(out - ref[:, 0]).max()) < 1e-5


# ---------------------------------------------------------------------------
# Int8 page round-trips through commit_prefill (quantize-on-commit)
# ---------------------------------------------------------------------------

def _int8_pool(layers, n_pages, page, heads, hd):
    return {"k": jnp.zeros((layers, n_pages, page, heads, hd), jnp.int8),
            "v": jnp.zeros((layers, n_pages, page, heads, hd), jnp.int8),
            "ks": jnp.zeros((layers, n_pages, page, heads), jnp.float32),
            "vs": jnp.zeros((layers, n_pages, page, heads), jnp.float32)}


def test_commit_quantizes_float_prefill_into_int8_pages():
    """bf16 prefill cache → int8 pool: per-(pos, head) round-trip error is
    bounded by half the absmax scale, zero rows stay exact."""
    layers, page, heads, hd, s = 2, 4, 2, 8, 10   # 2 full pages + 2-tok tail
    rng = np.random.default_rng(1)
    k = rng.normal(size=(layers, 1, s, heads, hd)).astype(np.float32) * 4
    k[0, 0, 3] = 0.0                              # a zero row
    v = rng.normal(size=(layers, 1, s, heads, hd)).astype(np.float32)
    cache = {"seg_0": {"kv_pool": _int8_pool(layers, 7, page, heads, hd)}}
    prefill = {"seg_0": {"kv": {"k": jnp.asarray(k), "v": jnp.asarray(v)}}}
    phys = jnp.asarray([4, 2, 6], jnp.int32)
    out = commit_prefill(cache, prefill, jnp.int32(0), phys, page_size=page)
    pool = out["seg_0"]["kv_pool"]
    table = np.asarray([4, 2, 6])
    for name, scale_name, ref in (("k", "ks", k), ("v", "vs", v)):
        codes = np.asarray(pool[name])[:, table].reshape(layers, -1, heads, hd)
        scales = np.asarray(pool[scale_name])[:, table].reshape(layers, -1,
                                                                heads)
        deq = codes.astype(np.float32) * scales[..., None]
        err = np.abs(deq[:, :s] - ref[:, 0])
        bound = scales[:, :s, :, None] * 0.5 + 1e-6
        assert (err <= bound).all(), (name, err.max())
    # the zero row round-trips exactly
    deq_k = (np.asarray(pool["k"])[0, 4, 3].astype(np.float32)
             * np.asarray(pool["ks"])[0, 4, 3][..., None])
    assert np.abs(deq_k).max() == 0.0


def test_commit_matches_decode_write_codec():
    """Quantize-on-commit and the decode write path use the same codec: a
    token committed by prefill equals the same token written by decode."""
    x = jnp.asarray(np.random.default_rng(2).normal(size=(1, 1, 4, 2, 8)),
                    jnp.float32)
    q_commit, s_commit = _kv_quantize(x)
    q_tok, s_tok = _kv_quantize(x[0, 0])
    np.testing.assert_array_equal(np.asarray(q_commit)[0, 0],
                                  np.asarray(q_tok))
    np.testing.assert_array_equal(np.asarray(s_commit)[0, 0],
                                  np.asarray(s_tok))


def test_int8_engine_decode_close_to_bf16():
    """Serving with int8 pages degrades logit fidelity gracefully: greedy
    streams run end-to-end and the first sampled token (prefill, float
    path) is identical; decode tokens may differ only via quantization."""
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    from repro.serving import GenerationEngine
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 12, 9)]
    outs = {}
    for quant in ("none", "int8"):
        eng = GenerationEngine(m, params, max_seq=64, num_slots=4,
                               page_size=8, kv_quant=quant)
        rids = [eng.submit(p, 6) for p in prompts]
        out = eng.drain()
        outs[quant] = [list(out[r]) for r in rids]
        assert all(len(o) == 6 for o in outs[quant])
        assert eng._scheduler.pager.pages_in_use == 0
    # first token comes from the float prefill logits in both regimes
    for a, b in zip(outs["none"], outs["int8"]):
        assert a[0] == b[0]
    # int8 serving is deterministic: a second run reproduces the streams
    eng = GenerationEngine(m, params, max_seq=64, num_slots=4,
                           page_size=8, kv_quant="int8")
    rids = [eng.submit(p, 6) for p in prompts]
    out = eng.drain()
    assert [list(out[r]) for r in rids] == outs["int8"]
