"""Activation-aware scale search: must beat RTN on salient channels."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.awq import AWQConfig, fold_into_norm, search_awq_scale
from repro.core.quantize import QuantConfig, fake_quantize


def _salient_setup(seed=0, k=256, n=128, boost=40.0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(kw, (k, n)) * 0.1
    x = jax.random.normal(kx, (512, k))
    x = x.at[:, :8].mul(boost)  # 8 salient input channels (paper Fig. 2)
    return x, w


def test_awq_beats_rtn_on_salient_activations():
    x, w = _salient_setup()
    cfg = AWQConfig(quant=QuantConfig(group_size=64))
    s, _ = search_awq_scale(x, w, cfg)
    y = x @ w
    err_awq = float(jnp.mean(
        (y - (x / s) @ fake_quantize(w * s[:, None], cfg.quant)) ** 2))
    err_rtn = float(jnp.mean((y - x @ fake_quantize(w, cfg.quant)) ** 2))
    assert err_awq < 0.75 * err_rtn


def test_scale_protects_salient_channels():
    x, w = _salient_setup()
    cfg = AWQConfig(quant=QuantConfig(group_size=64))
    s, _ = search_awq_scale(x, w, cfg)
    s = np.asarray(s)
    # salient channels get scaled up relative to the rest
    assert s[:8].mean() > s[8:].mean()


def test_gs64_beats_gs128_on_grouped_outliers():
    """The paper picks GS=64 over 128 (better WNLI). Construct weights with
    128-row-scale variation: finer groups must quantize better."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (256, 64)) * 0.02
    w = w.at[64:128].mul(30.0)  # an outlier band inside a 128-group
    e64 = float(jnp.mean(
        (fake_quantize(w, QuantConfig(group_size=64)) - w) ** 2))
    e128 = float(jnp.mean(
        (fake_quantize(w, QuantConfig(group_size=128)) - w) ** 2))
    assert e64 < e128


def test_fold_into_norm_identity():
    k = 64
    gamma = jax.random.normal(jax.random.PRNGKey(4), (k,))
    inv_s = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (k,))) + 0.1
    x = jax.random.normal(jax.random.PRNGKey(6), (8, k))
    lhs = (x * gamma[None]) * inv_s[None]
    rhs = x * fold_into_norm(gamma, inv_s)[None]
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-6)
