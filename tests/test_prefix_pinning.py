"""Cross-burst prefix pinning: index entries survive their last owner,
pages free exactly once, prefill FLOPs are skipped across bursts."""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine
from repro.serving.kv_pager import KVPager, PagerConfig


def _pager(num_pages=17, page_size=4, num_slots=4, pages_per_slot=4):
    return KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                               num_slots=num_slots,
                               pages_per_slot=pages_per_slot))


def _toks(*vals):
    return np.asarray(vals, np.int32)


# ---------------------------------------------------------------------------
# Pager-level invariants
# ---------------------------------------------------------------------------

def test_pin_keeps_index_alive_past_last_owner():
    p = _pager()
    prompt = _toks(*range(10))                  # 2 full pages + tail
    s_a, pages_a = p.alloc_slot(10, 3)
    p.register_prefix(s_a, prompt, "sys")
    assert p.pin_prefix("sys") == 2

    p.free_slot(s_a)                            # last REQUEST owner gone
    assert p.match_prefix(prompt, "sys") == pages_a[:2]  # index survives
    assert p.pages_in_use == 2                  # pinned pages stay drawn
    assert (p.page_ref[pages_a[:2]] == 1).all()

    # a second burst aliases the pinned pages without recomputing them
    s_b, pages_b = p.alloc_slot(10, 3, shared_pages=pages_a[:2])
    assert pages_b[:2] == pages_a[:2]
    assert p.slot_committed[s_b] == 8           # 2 aliased pages pre-committed
    p.free_slot(s_b)
    assert p.match_prefix(prompt, "sys") == pages_a[:2]

    assert p.unpin_prefix("sys") == 2           # last owner: freed exactly once
    assert p.pages_in_use == 0
    assert (p.page_ref == 0).all()
    assert not p.prefix_index
    assert len(set(p.free_pages)) == len(p.free_pages)


def test_pin_is_sticky_for_later_registrations():
    p = _pager()
    assert p.pin_prefix("sys") == 0             # nothing indexed yet
    s_a, pages_a = p.alloc_slot(8, 2)
    p.register_prefix(s_a, _toks(*range(8)), "sys")
    p.free_slot(s_a)                            # pin (taken at register) holds
    assert p.match_prefix(_toks(*range(8)), "sys") == pages_a[:2]
    assert p.unpin_prefix("sys") == 2
    assert p.pages_in_use == 0 and (p.page_ref == 0).all()


def test_pin_namespaces_are_independent():
    p = _pager()
    s_a, _ = p.alloc_slot(4, 1)
    p.register_prefix(s_a, _toks(*range(4)), "alice")
    s_b, _ = p.alloc_slot(4, 1)
    p.register_prefix(s_b, _toks(*range(4)), "bob")
    p.pin_prefix("alice")
    p.free_slot(s_a)
    p.free_slot(s_b)
    assert p.match_prefix(_toks(*range(4)), "alice")    # pinned: survives
    assert p.match_prefix(_toks(*range(4)), "bob") == []  # unpinned: died
    p.unpin_prefix("alice")
    assert p.pages_in_use == 0 and (p.page_ref == 0).all()


def test_unpin_unknown_is_noop_and_double_unpin_safe():
    p = _pager()
    assert p.unpin_prefix("ghost") == 0
    s_a, _ = p.alloc_slot(4, 1)
    p.register_prefix(s_a, _toks(*range(4)), "sys")
    p.pin_prefix("sys")
    p.free_slot(s_a)
    assert p.unpin_prefix("sys") == 1
    assert p.unpin_prefix("sys") == 0           # second unpin: nothing held
    assert p.pages_in_use == 0 and (p.page_ref == 0).all()


def test_pinned_pages_count_against_admission():
    # 5 usable pages, P=4: a pinned 2-page prefix leaves 3 free pages
    p = _pager(num_pages=6, page_size=4, num_slots=2, pages_per_slot=4)
    s_a, _ = p.alloc_slot(8, 1)
    p.register_prefix(s_a, _toks(*range(8)), "sys")
    p.pin_prefix("sys")
    p.free_slot(s_a)
    assert not p.can_admit(12, 2)               # 4 fresh pages: too big
    assert p.can_admit(12, 2, n_shared=2)       # aliasing the pin: fits
    p.unpin_prefix("sys")
    assert p.can_admit(12, 2)


# ---------------------------------------------------------------------------
# Engine-level: FLOPs skipped across bursts
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_pin_skips_prefill_flops_across_bursts(model_and_params):
    cfg, m, params = model_and_params
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)

    def burst(eng, seed):
        r = np.random.default_rng(seed)
        prompts = [np.concatenate([prefix,
                                   r.integers(0, cfg.vocab_size, (5,)
                                              ).astype(np.int32)])
                   for _ in range(3)]
        rids = [eng.submit(p, 4, prefix_id="sys") for p in prompts]
        out = eng.drain()
        return [list(out[r_]) for r_ in rids], prompts

    eng = GenerationEngine(m, params, max_seq=64, num_slots=4, page_size=8,
                           prefill_chunk=8)
    eng.pin_prefix("sys")       # sticky: pre-declare the hot prefix — pages
    burst(eng, 0)               # auto-pin as the first burst registers them
    pager = eng._scheduler.pager
    assert pager.pages_in_use == 2              # only the pinned prefix
    skipped_before = eng.scheduler_stats.prefill_tokens_skipped

    streams, prompts = burst(eng, 1)            # second burst: all alias
    # every request skipped the whole 2-page prefix — cross-burst FLOP reuse
    assert (eng.scheduler_stats.prefill_tokens_skipped - skipped_before
            == 3 * 16)
    # pinned serving stays token-identical to a cold unpinned engine
    cold = GenerationEngine(m, params, max_seq=64, num_slots=4, page_size=8,
                            prefill_chunk=8)
    rids = [cold.submit(p, 4) for p in prompts]
    ref = cold.drain()
    assert streams == [list(ref[r_]) for r_ in rids]

    eng.unpin_prefix("sys")
    assert pager.pages_in_use == 0
    assert (pager.page_ref == 0).all()
