"""Chunked prefill: token identity vs one-shot prefill and sequential
generate() across chunk sizes, multi-query kernel vs oracle, scheduler
token-budget semantics against a fake executor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.kernels.paged_attention import paged_attention_chunk
from repro.kernels.ref import paged_attention_chunk_ref
from repro.models import build_model
from repro.models.attention import _kv_quantize
from repro.serving import GenerationEngine
from repro.serving.kv_pager import KVPager, PagerConfig
from repro.serving.scheduler import Request, Scheduler


@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _engine(m, params, **kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("num_slots", 4)
    kw.setdefault("page_size", 8)
    return GenerationEngine(m, params, **kw)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


# ---------------------------------------------------------------------------
# End-to-end identity: chunked ≡ one-shot ≡ sequential generate()
# ---------------------------------------------------------------------------

# page_size=8: page-aligned chunk, two non-aligned chunks, chunk > prompt
@pytest.mark.parametrize("chunk", [8, 3, 5, 64])
def test_chunked_matches_oneshot_and_generate(model_and_params, chunk):
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 12, 9, 17, 7, 21), seed=1)

    def serve(**kw):
        eng = _engine(m, params, **kw)
        rids = [eng.submit(p, 8) for p in prompts]
        out = eng.drain()
        assert eng._scheduler.pager.pages_in_use == 0
        return [list(out[r]) for r in rids], eng

    chunked, eng_c = serve(prefill_chunk=chunk)
    oneshot, eng_o = serve(chunked_prefill=False)
    assert chunked == oneshot
    assert eng_c._scheduler.chunked and not eng_o._scheduler.chunked
    # every prompt token ran through the model exactly once (no sharing)
    assert eng_c._scheduler.stats.prefill_tokens == sum(map(len, prompts))
    assert eng_c._scheduler.stats.prefill_tokens_skipped == 0
    for p, stream in zip(prompts, chunked):
        ref = eng_o.generate({"tokens": jnp.asarray(p)[None, :]}, 8)[0]
        np.testing.assert_array_equal(stream, ref[: len(stream)])


def test_chunked_shared_prefix_identical_and_skips_flops(model_and_params):
    """Chunks straddling the shared-prefix boundary: the follower starts
    mid-page after its aliased pages and its streams stay token-identical
    to unshared chunked and to one-shot serving."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, (19,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (t,)
                                            ).astype(np.int32)])
               for t in (6, 3, 9, 5)]

    def serve(prefix_id, **kw):
        eng = _engine(m, params, **kw)
        rids = [eng.submit(p, 6, prefix_id=prefix_id) for p in prompts]
        out = eng.drain()
        return [list(out[r]) for r in rids], eng._scheduler.stats

    # chunk 5 with page 8: chunk boundaries straddle both page boundaries
    # and the 16-token (2-page) shared-prefix boundary
    shared, st_s = serve("sys", prefill_chunk=5)
    unshared, st_u = serve(None, prefill_chunk=5)
    oneshot, _ = serve("sys", chunked_prefill=False)
    assert shared == unshared == oneshot
    # the 3 followers each alias 2 full pages = 16 tokens of prefill FLOPs
    assert st_s.prefix_shared_pages == 6
    assert st_s.prefill_tokens_skipped == 3 * 16
    assert st_u.prefill_tokens_skipped == 0
    assert st_s.prefill_tokens < st_u.prefill_tokens


def test_fully_aliased_page_aligned_prompt(model_and_params):
    """A page-aligned prompt fully covered by the prefix index still
    samples its first token (the final prompt token re-runs, writing
    identical bytes into the shared page)."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)  # 2 pages
    eng = _engine(m, params, prefill_chunk=6)
    r0 = eng.submit(prompt, 4, prefix_id="sys")
    r1 = eng.submit(prompt.copy(), 4, prefix_id="sys")
    out = eng.drain()
    assert list(out[r0]) == list(out[r1])
    st = eng._scheduler.stats
    assert st.prefix_shared_pages == 2
    assert st.prefill_tokens_skipped == 15      # all but the final token
    ref = eng.generate({"tokens": jnp.asarray(prompt)[None, :]}, 4)[0]
    np.testing.assert_array_equal(out[r0], ref)


def test_chunked_int8_deterministic(model_and_params):
    """Int8 chunked serving: deterministic run-to-run; chunk size does not
    change the committed pages (the per-(pos, head) codec is
    chunk-invariant), so streams agree across chunk sizes."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 12, 9), seed=5)

    def serve(chunk):
        eng = _engine(m, params, kv_quant="int8", prefill_chunk=chunk)
        rids = [eng.submit(p, 6) for p in prompts]
        out = eng.drain()
        return [list(out[r]) for r in rids]

    a, b = serve(4), serve(4)
    assert a == b                       # deterministic
    assert serve(16) == a               # chunk-size invariant


def test_chunked_mixes_prefill_and_decode_in_one_dispatch(model_and_params):
    """A long prompt admitted next to decoding requests must not stall
    them: dispatches interleave its chunks with their decode tokens."""
    cfg, m, params = model_and_params
    eng = _engine(m, params, max_seq=64, prefill_chunk=4)
    short = _prompts(cfg, (4, 3), seed=6)
    long_p = _prompts(cfg, (33,), seed=7)[0]
    r_a = eng.submit(short[0], 12)
    r_b = eng.submit(short[1], 12)
    eng.step()                          # shorts finish prefill, start decode
    r_c = eng.submit(long_p, 4)
    mixed_steps = 0
    while not eng.idle:
        ev = eng.step()
        rids = {r for r, _ in ev}
        if r_c not in rids and eng.num_active == 3 and ev:
            mixed_steps += 1            # decode progressed mid-prefill
    # 33 tokens at 2 free rows × chunk 4 = 8/step → 4 mid-prefill steps,
    # each of which also decoded the two short requests
    assert mixed_steps >= 4
    out = eng.collect()
    ref = eng.generate({"tokens": jnp.asarray(long_p)[None, :]}, 4)[0]
    np.testing.assert_array_equal(out[r_c], ref)
    for rid, p in zip((r_a, r_b), short):
        ref = eng.generate({"tokens": jnp.asarray(p)[None, :]}, 12)[0]
        np.testing.assert_array_equal(out[rid], ref)


def test_bounded_compile_family_for_all_prompt_lengths(model_and_params):
    """The chunked path compiles one step function per context bucket ×
    width bucket — independent of the prompt-length mix (the
    jit-per-prompt-length family is gone). At max_seq 64 there is a
    single 8-page context bucket, so the compile count is bounded by the
    run-length packer's width family ({1, 2, 4, 8} at chunk 8) for any
    number of prompt lengths."""
    cfg, m, params = model_and_params
    eng = _engine(m, params, prefill_chunk=8)
    for p in _prompts(cfg, (3, 7, 11, 19, 26), seed=8):
        eng.submit(p, 2)
    eng.drain()
    assert eng._scheduler.width_buckets == [1, 2, 4, 8]
    assert 2 <= eng._chunk_greedy._cache_size() \
        <= len(eng._scheduler.width_buckets)
    assert not hasattr(eng, "_prefill_fused")   # the per-length family


# ---------------------------------------------------------------------------
# Multi-query kernel vs oracle (interpret mode, TPU-shaped inputs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,c,hkv,g,hd,page,nblk,npages", [
    (2, 5, 2, 4, 64, 8, 4, 12),     # GQA chunk, several pages
    (1, 16, 1, 1, 128, 16, 3, 8),   # MQA, page-sized chunk
    (3, 3, 2, 9, 64, 8, 5, 20),     # row dim not a sublane multiple (pad)
    (2, 1, 4, 2, 64, 16, 2, 40),    # decode form (C = 1)
])
def test_chunk_kernel_matches_oracle(b, c, hkv, g, hd, page, nblk, npages):
    rng = np.random.default_rng(hash((b, c, hkv, g)) % 2**31)
    kf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)) * 2,
                     jnp.float32)
    vf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    k, ks = _kv_quantize(kf)
    v, vs = _kv_quantize(vf)
    q = jnp.asarray(rng.normal(size=(b, c, hkv, g, hd)), jnp.float32)
    table = jnp.asarray(rng.integers(1, npages, (b, nblk)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, page * nblk, (b, c)), jnp.int32)
    if c > 1:                          # padding queries must output zero
        pos = pos.at[:, -1].set(-1)
    out = paged_attention_chunk(q, k, ks, v, vs, table, pos, interpret=True)
    ref = paged_attention_chunk_ref(q, k, ks, v, vs, table, pos)
    assert float(jnp.abs(out - ref).max()) < 1e-5
    if c > 1:
        assert float(jnp.abs(out[:, -1]).max()) == 0.0


def test_chunk_kernel_causal_within_chunk():
    """Intra-chunk causality: query at position p must ignore chunk
    tokens at positions > p even though their KV is already written."""
    rng = np.random.default_rng(0)
    npages, page, hkv, g, hd, nblk = 6, 8, 2, 2, 64, 2
    kf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    k, ks = _kv_quantize(kf)
    v, vs = _kv_quantize(vf)
    table = jnp.asarray([[2, 4]], jnp.int32)
    q = jnp.asarray(rng.normal(size=(1, 3, hkv, g, hd)), jnp.float32)
    pos = jnp.asarray([[4, 5, 6]], jnp.int32)
    out = paged_attention_chunk(q, k, ks, v, vs, table, pos, interpret=True)
    # each query must equal its own single-query call (same mask)
    for i in range(3):
        solo = paged_attention_chunk(q[:, i:i + 1], k, ks, v, vs, table,
                                     pos[:, i:i + 1], interpret=True)
        np.testing.assert_allclose(np.asarray(out[:, i]),
                                   np.asarray(solo[:, 0]), atol=1e-6)


# ---------------------------------------------------------------------------
# Ancestor-mask edge cases (tree-speculation mask semantics)
# ---------------------------------------------------------------------------

def _rand_pool(rng, npages, page, hkv, hd):
    kf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)) * 2,
                     jnp.float32)
    vf = jnp.asarray(rng.normal(size=(npages, page, hkv, hd)), jnp.float32)
    k, ks = _kv_quantize(kf)
    v, vs = _kv_quantize(vf)
    return k, ks, v, vs


def test_chunk_kernel_all_masked_row_exact_zero():
    """A valid (non-padding) query whose ancestor-mask row is empty and
    that sits at watermark 0 (no committed span) sees nothing — the
    kernel's l == 0 flush must produce exactly 0, not NaN or softmax
    garbage, and other rows in the batch are unaffected."""
    rng = np.random.default_rng(20)
    npages, page, hkv, g, hd, nblk = 6, 8, 2, 2, 64, 2
    k, ks, v, vs = _rand_pool(rng, npages, page, hkv, hd)
    c = 4
    q = jnp.asarray(rng.normal(size=(2, c, hkv, g, hd)), jnp.float32)
    table = jnp.asarray([[2, 3], [4, 5]], jnp.int32)
    # row 0: fresh slot at watermark 0, all-false amask → nothing visible
    # row 1: ordinary causal chunk at watermark 4 → unaffected control
    pos = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    amask = np.zeros((2, c, c), bool)
    amask[1] = np.tril(np.ones((c, c), bool))
    out = paged_attention_chunk(q, k, ks, v, vs, table, pos,
                                amask=jnp.asarray(amask), interpret=True)
    ref = paged_attention_chunk_ref(q, k, ks, v, vs, table, pos,
                                    amask=jnp.asarray(amask))
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(ref[0]).max()) == 0.0
    assert np.isfinite(np.asarray(out)).all()
    plain = paged_attention_chunk(q[1:], k, ks, v, vs, table[1:], pos[1:],
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(plain[0]),
                               atol=1e-6)


def test_chunk_kernel_tree_mask_straddles_page_boundary():
    """A speculation tree whose in-span slots straddle a page boundary:
    the ancestor mask must keep following node indices while the keys
    come from two different physical pages. Kernel ≡ oracle, and each
    node attends exactly to its ancestor chain."""
    rng = np.random.default_rng(21)
    npages, page, hkv, g, hd, nblk = 8, 8, 2, 2, 64, 2
    k, ks, v, vs = _rand_pool(rng, npages, page, hkv, hd)
    c = 5                                   # root + 4 tree nodes
    q = jnp.asarray(rng.normal(size=(1, c, hkv, g, hd)), jnp.float32)
    table = jnp.asarray([[3, 6]], jnp.int32)
    # watermark 6 → slots 6..10 span page 3 (slots 6, 7) and page 6 (8..10)
    pos = jnp.asarray([[6, 7, 8, 9, 10]], jnp.int32)
    # tree: root → a → (b, c_sib); b → d   (two siblings share depth 2)
    #   in-row:    0     1    2  3       4
    parents = [-1, 0, 1, 1, 2]
    depth = [0, 1, 2, 2, 3]
    rpos = jnp.asarray([[6 + d for d in depth]], jnp.int32)
    amask = np.zeros((1, c, c), bool)
    for i, par in enumerate(parents):
        amask[0, i, i] = True
        j = par
        while j >= 0:
            amask[0, i, j] = True
            j = parents[j]
    out = paged_attention_chunk(q, k, ks, v, vs, table, pos,
                                rpos=rpos, amask=jnp.asarray(amask),
                                interpret=True)
    ref = paged_attention_chunk_ref(q, k, ks, v, vs, table, pos,
                                    rpos=rpos, amask=jnp.asarray(amask))
    assert float(jnp.abs(out - ref).max()) < 1e-5
    # corrupt sibling b's KV slot (slot 8 = page 6, offset 0, the first
    # slot past the page boundary): only b itself (node 2) and its child d
    # (node 4) may change — sibling c_sib (node 3) and the b-free prefix
    # must be bit-identical, proving the ancestor mask holds across pages
    k2 = k.at[6, 0].set(127)
    v2 = v.at[6, 0].set(127)
    ks2 = ks.at[6, 0].set(50.0)
    vs2 = vs.at[6, 0].set(50.0)
    out2 = paged_attention_chunk(q, k2, ks2, v2, vs2, table, pos,
                                 rpos=rpos, amask=jnp.asarray(amask),
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out[:, :2]),
                                  np.asarray(out2[:, :2]))
    np.testing.assert_array_equal(np.asarray(out[:, 3]),
                                  np.asarray(out2[:, 3]))
    assert float(jnp.abs(out[:, 2] - out2[:, 2]).max()) > 1e-3
    assert float(jnp.abs(out[:, 4] - out2[:, 4]).max()) > 1e-3


def test_chunk_kernel_single_node_tree_equals_linear():
    """A degenerate tree (every node's parent is its predecessor — one
    chain) with rpos == pos and a lower-triangular ancestor mask is
    bit-for-bit the plain linear speculation row (amask=None)."""
    rng = np.random.default_rng(22)
    npages, page, hkv, g, hd, nblk = 8, 8, 2, 4, 64, 3
    k, ks, v, vs = _rand_pool(rng, npages, page, hkv, hd)
    c = 6
    q = jnp.asarray(rng.normal(size=(2, c, hkv, g, hd)), jnp.float32)
    table = jnp.asarray(rng.integers(1, npages, (2, nblk)), jnp.int32)
    pos = np.stack([np.arange(5, 5 + c), np.arange(12, 12 + c)]).astype(
        np.int32)
    pos[1, -2:] = -1                        # padding tail on one row
    pos = jnp.asarray(pos)
    tri = np.broadcast_to(np.tril(np.ones((c, c), bool)), (2, c, c)).copy()
    tri[1, :, -2:] = False                  # padding is never an ancestor
    out_tree = paged_attention_chunk(q, k, ks, v, vs, table, pos,
                                     rpos=pos, amask=jnp.asarray(tri),
                                     interpret=True)
    out_lin = paged_attention_chunk(q, k, ks, v, vs, table, pos,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(out_tree), np.asarray(out_lin))


# ---------------------------------------------------------------------------
# Scheduler token-budget semantics against a fake executor
# ---------------------------------------------------------------------------

class _FakeChunkExec:
    """Echo executor: sampled token = 100 + the row's sample-index token."""

    def __init__(self):
        self.dispatches = []           # (valid_token_count, rows_used)

    def run_batch(self, tokens, pos, row_slots, sample_idx, temps, topks):
        valid = (pos >= 0)
        self.dispatches.append((int(valid.sum()),
                                int((valid.any(axis=1)).sum())))
        out = np.zeros(tokens.shape[0], np.int32)
        for r in range(tokens.shape[0]):
            out[r] = 100 + tokens[r, sample_idx[r]]
        return out


def _sched(num_slots=2, pages_per_slot=4, page_size=4, chunk=3):
    ex = _FakeChunkExec()
    pager = KVPager(PagerConfig(num_pages=num_slots * pages_per_slot + 1,
                                page_size=page_size, num_slots=num_slots,
                                pages_per_slot=pages_per_slot))
    return Scheduler(pager, run_batch=ex.run_batch, chunk_size=chunk), ex


def test_chunked_scheduler_prefills_in_chunks_then_decodes():
    sched, ex = _sched(chunk=3)
    sched.submit(Request(rid=0, tokens=np.arange(7, dtype=np.int32),
                         max_new_tokens=3))
    # both idle rows go to the lone prefilling request: 2 chunks × 3 tokens
    ev = sched.step()                  # chunks [0,3) + [3,6): mid-prefill
    assert ev == []
    assert sched.slots[0].committed == 6
    assert ex.dispatches[-1] == (6, 2)
    ev = sched.step()                  # final chunk [6,7) → first token
    assert ev == [(0, 106)]            # 100 + last prompt token (6)
    out = sched.run()
    assert list(out[0]) == [106, 206, 306]   # decode echoes 100+prev
    assert sched.stats.prefill_chunks == 3
    assert sched.stats.prefill_tokens == 7
    assert sched.pager.pages_in_use == 0


def test_chunked_scheduler_packs_mixed_rows():
    sched, ex = _sched(num_slots=2, chunk=4)
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=4))
    sched.step()                       # rid 0 finishes prefill (4 ≤ chunk)
    sched.submit(Request(rid=1, tokens=np.arange(9, dtype=np.int32),
                         max_new_tokens=2))
    ev = sched.step()                  # rid 0 decodes + rid 1 chunk 1
    assert (1 + 4, 2) == ex.dispatches[-1]   # 5 valid tokens on 2 rows
    assert [r for r, _ in ev] == [0]
    out = sched.run()
    assert len(out[0]) == 4 and len(out[1]) == 2


def test_chunked_scheduler_first_token_eos_finishes_at_prefill_end():
    sched, ex = _sched(chunk=8)
    sched.submit(Request(rid=0, tokens=np.asarray([1, 2], np.int32),
                         max_new_tokens=8, eos_id=102))
    out = sched.run()
    assert list(out[0]) == [102]       # first sampled token is its eos
    assert sched.pager.pages_in_use == 0
    assert sched.stats.finished == 1
