"""AWQ W4 serving end-to-end: a quantized engine must stream greedy
tokens IDENTICALLY under the Pallas kernel (interpret mode) and the pure
jnp ``ref`` oracle, through the whole serving feature matrix — chunked
prefill × int8 KV pages × prefix sharing × ngram speculative decoding —
and through a 2-way tensor-parallel mesh, with the packed weight stream
actually smaller than the float one.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=4 so the
main pytest process keeps its single real device (same pattern as
test_sharded_serving)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import json
import jax
import jax.numpy as jnp
import numpy as np
import repro.configs as C
from repro.core import quantize_params
from repro.core.qlinear import set_execution_config
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.serving import GenerationEngine

# Hkv = 4 divides the 2-way mesh; head_dim=16 keeps every attention linear
# above the quantizer's min-size floor.
cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                          num_heads=8, num_kv_heads=4, head_dim=16)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
qp, report = quantize_params(params)
out = {"device_count": jax.device_count(),
       "quantized_layers": len(report.quantized)}

rng = np.random.default_rng(0)
prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
prompts = [np.concatenate([prefix,
                           rng.integers(0, cfg.vocab_size, (t,)
                                        ).astype(np.int32)])
           for t in (5, 12, 9, 3)]


def serve(pp, mesh=None, **kw):
    eng = GenerationEngine(m, pp, max_seq=64, num_slots=4, page_size=8,
                           prefill_chunk=4, mesh=mesh, **kw)
    rids = [eng.submit(p, 10, prefix_id="sys") for p in prompts]
    while not eng.idle:
        eng.step()
    done = eng.collect()
    return [[int(t) for t in done[r]] for r in rids], eng.stats()


FULL = dict(kv_quant="int8", spec_decode="ngram", spec_k=4)
MATRIX = {"plain": {}, "int8": {"kv_quant": "int8"},
          "spec": {"spec_decode": "ngram", "spec_k": 4}, "full": FULL}

ref_streams = {}
for tag, kw in MATRIX.items():
    set_execution_config(impl="ref", compute_dtype=jnp.float32)
    ref_s, st = serve(qp, **kw)
    set_execution_config(impl="kernel_interpret", compute_dtype=jnp.float32)
    ker_s, _ = serve(qp, **kw)
    ref_streams[tag] = ref_s
    out[f"nonempty_{tag}"] = all(len(s) == 10 for s in ref_s)
    out[f"identical_{tag}"] = ker_s == ref_s
out["spec_fired"] = st.draft_tokens > 0            # st is the FULL run's
out["prefix_fired"] = st.prefix_shared_pages > 0

# --- 2-way mesh, quantized params, full feature stack -------------------
set_execution_config(impl="ref", compute_dtype=jnp.float32)
sh_s, st_sh = serve(qp, mesh=serving_mesh(2), **FULL)
out["identical_sharded"] = sh_s == ref_streams["full"]
out["model_axis"] = st_sh.model_axis

# --- weight stream accounting -------------------------------------------
_, st_q = serve(qp)
_, st_f = serve(params)
out["weight_bytes_float"] = st_f.weight_bytes
out["weight_bytes_awq"] = st_q.weight_bytes
out["wbpt_positive"] = st_q.weight_bytes_per_token > 0

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_quantizer_covered_the_model(result):
    assert result["device_count"] == 4
    assert result["quantized_layers"] > 0


def test_awq_kernel_streams_match_ref_across_matrix(result):
    """Greedy kernel-vs-ref identity for every serving feature cell."""
    for tag in ("plain", "int8", "spec", "full"):
        assert result[f"nonempty_{tag}"], f"{tag}: short stream"
        assert result[f"identical_{tag}"], f"{tag}: kernel diverged from ref"
    assert result["spec_fired"] and result["prefix_fired"]


def test_awq_sharded_stream_identical(result):
    """Quantized params through the 2-way mesh: packed leaves shard and
    the greedy stream stays identical to the unsharded engine."""
    assert result["model_axis"] == 2
    assert result["identical_sharded"]


def test_awq_weight_stream_shrinks(result):
    """The per-token weight stream the paper targets actually shrinks."""
    assert result["wbpt_positive"]
    assert result["weight_bytes_awq"] < 0.6 * result["weight_bytes_float"]
