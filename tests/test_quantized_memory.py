"""Quantized MoE / MLA apply must dequantize lazily, never materializing
the full dense weight stack (the W4 bandwidth win on the decode path).

Two assertions per path:
  * jaxpr-level — no intermediate with the full dense-stack shape exists
    anywhere in the lowered program (the eager bug produced an
    ``[E, K, N]`` f32 stack / the full MLA up-projection every step);
  * peak live bytes — when the backend reports a compiled memory
    analysis, the lazy program's temp bytes must not exceed an
    eagerly-dequantizing reference of the same computation.
Plus allclose vs the eager oracle, so laziness never changes the math.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import PackedLinear, quantize_params
from repro.core.packing import dequantize_packed
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod


def _all_avals(jaxpr):
    """Every intermediate aval, recursing into nested jaxprs (scan/map)."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for val in eqn.params.values():
            yield from _sub(val)


def _sub(val):
    if hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):  # ClosedJaxpr
        yield from _all_avals(val.jaxpr)
    elif hasattr(val, "eqns"):                                # Jaxpr
        yield from _all_avals(val)
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _sub(v)


def _assert_no_shape(jaxpr, forbidden: set):
    hits = [a for a in _all_avals(jaxpr)
            if getattr(a, "shape", None) in forbidden]
    assert not hits, f"full dense weight materialized: {hits[:3]}"


def _temp_bytes(fn, *args):
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0) or 0)
    except Exception:
        return 0


def test_moe_packed_never_materializes_expert_stack():
    cfg = C.get_smoke_config("qwen2-moe-a2.7b")
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    qp, rep = quantize_params(p)
    assert isinstance(qp["experts"]["gate"], PackedLinear)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model))

    def lazy(pp, xx):
        return moe_mod.moe_apply(pp, xx, cfg)[0]

    def eager(pp, xx):
        dense = dict(pp)
        dense["experts"] = {
            n: {"w": moe_mod._expert_weight(pp["experts"], n)}
            for n in ("gate", "up", "down")}
        return moe_mod.moe_apply(dense, xx, cfg)[0]

    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    _assert_no_shape(jax.make_jaxpr(lazy)(qp, x).jaxpr,
                     {(e, d, f), (e, f, d)})

    y_lazy = lazy(qp, x)
    y_eager = eager(qp, x)
    np.testing.assert_allclose(np.asarray(y_lazy), np.asarray(y_eager),
                               rtol=2e-5, atol=2e-5)

    t_lazy, t_eager = _temp_bytes(lazy, qp, x), _temp_bytes(eager, qp, x)
    if t_lazy and t_eager:
        assert t_lazy <= t_eager, (t_lazy, t_eager)


def test_mla_packed_dequantizes_per_block():
    cfg = C.get_smoke_config("deepseek-v2-lite-16b")
    p = mla_mod.mla_init(jax.random.PRNGKey(0), cfg)
    qp, rep = quantize_params(p)
    assert isinstance(qp["kv_up"], PackedLinear), rep.skipped
    b = 2
    cache = mla_mod.init_mla_cache(cfg, b, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.d_model))
    pos = jnp.array([3, 5], jnp.int32)

    def lazy(pp, cc, xx):
        return mla_mod.mla_decode(pp, cc, xx, cfg, pos=pos)[0]

    def eager(pp, cc, xx):
        dense = dict(pp)
        dense["kv_up"] = {"w": dequantize_packed(pp["kv_up"], jnp.float32)
                          * pp["kv_up"].input_scale[:, None]}
        return mla_mod.mla_decode(dense, cc, xx, cfg, pos=pos)[0]

    h, r = cfg.num_heads, cfg.kv_lora_rank
    full = cfg.qk_nope_head_dim + cfg.v_head_dim
    _assert_no_shape(jax.make_jaxpr(lazy)(qp, cache, x).jaxpr,
                     {(r, h * full), (r, h, full)})

    y_lazy = lazy(qp, cache, x)
    y_eager = eager(qp, cache, x)
    np.testing.assert_allclose(np.asarray(y_lazy), np.asarray(y_eager),
                               rtol=2e-5, atol=2e-5)

    t_lazy, t_eager = (_temp_bytes(lazy, qp, cache, x),
                       _temp_bytes(eager, qp, cache, x))
    if t_lazy and t_eager:
        assert t_lazy <= t_eager, (t_lazy, t_eager)
