"""Whole-model PTQ pipeline: structure, naming, serving equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import (CalibrationCapture,
                        quantize_params)
from repro.core.packing import PackedLinear
from repro.core.pipeline import model_size_bytes
from repro.core.qlinear import set_execution_config
from repro.models import build_model
from tests.conftest import make_batch


def _setup(arch="qwen25-05b"):
    cfg = C.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_quantize_replaces_linears_with_packed():
    cfg, m, params = _setup()
    qp, report = quantize_params(params)
    leaves = jax.tree.leaves(qp, is_leaf=lambda x: isinstance(x, PackedLinear))
    n_packed = sum(isinstance(l, PackedLinear) for l in leaves)
    assert n_packed == len(report.quantized) > 0
    # embeddings and norms survive untouched
    assert qp["embed"]["table"].shape == params["embed"]["table"].shape


def test_calibrated_names_resolve():
    cfg, m, params = _setup()
    with CalibrationCapture() as cap:
        m.loss(params, make_batch(cfg))
    assert len(cap.stats) > 0
    qp, report = quantize_params(params, cap.stats)
    # every quantized stacked linear found its per-layer stats
    assert set(report.calibrated) == set(report.quantized)


def test_compression_ratio_is_4p5_bits():
    cfg, m, params = _setup()
    qp, report = quantize_params(params)
    assert abs(report.compression_ratio - 4.5 / 16) < 1e-9


def test_model_size_bytes_quantized_vs_baseline():
    cfg, m, params = _setup()
    base = model_size_bytes(params, quantized=False)
    packed = model_size_bytes(params, quantized=True)
    assert packed < base
    qp, _ = quantize_params(params)
    packed2 = model_size_bytes(qp, quantized=True)
    assert packed2 == packed  # same accounting pre/post actual packing


def test_quantized_forward_close_to_fake_quant():
    """PTQ'd serving path ≡ fake-quantized float model (same numerics)."""
    cfg, m, params = _setup()
    set_execution_config(impl="ref", compute_dtype=jnp.float32)
    with CalibrationCapture() as cap:
        m.loss(params, make_batch(cfg))
    qp, _ = quantize_params(params, cap.stats)
    batch = make_batch(cfg, seed=7, labels=False)
    lq = jax.jit(m.forward_logits)(qp, batch)
    lf = jax.jit(m.forward_logits)(params, batch)
    # quantization error is bounded; logits stay correlated and finite
    # (random-init logits have tiny dynamic range, so the bar is RMS error
    # well below the logit scale + strong correlation)
    assert np.isfinite(np.asarray(lq)).all()
    lqf, lff = np.asarray(lq).ravel(), np.asarray(lf).ravel()
    corr = np.corrcoef(lqf, lff)[0, 1]
    assert corr > 0.9
    assert np.sqrt(np.mean((lqf - lff) ** 2)) < 0.5 * lff.std()


def test_kernel_vs_ref_impl_identical_on_model():
    cfg, m, params = _setup()
    qp, _ = quantize_params(params)
    batch = make_batch(cfg, b=1, s=16, labels=False)
    set_execution_config(impl="ref", compute_dtype=jnp.float32)
    l_ref = m.forward_logits(qp, batch)
    set_execution_config(impl="kernel_interpret", compute_dtype=jnp.float32,
                         offload_min_flops=0)
    l_k = m.forward_logits(qp, batch)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_expert_stacked_quantization():
    cfg, m, params = _setup("qwen2-moe-a2.7b")
    qp, report = quantize_params(params)
    experts = qp["segments"]["seg_0"]["moe"]["experts"]["gate"]
    assert isinstance(experts, PackedLinear)
    # stacked dims preserved: [L, E, K/8, N]
    assert experts.qweight.ndim == 4
