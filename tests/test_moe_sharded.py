"""Quantized MoE under a real mesh: the shard_map packed-expert path
(§Perf B4) must match the meshless reference numerically."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
import repro.configs as C
from repro.core import quantize_params
from repro.core.qlinear import set_execution_config
from repro.distributed import sharding as shd
from repro.models import build_model

set_execution_config(impl="ref", compute_dtype=jnp.float32)
out = {}
import dataclasses
for arch in ("qwen2-moe-a2.7b", "deepseek-v2-lite-16b"):
    # f32 activations: the packed shard_map path must be numerically exact
    # (bf16 differs only by rounding order + near-tie routing flips)
    cfg = dataclasses.replace(C.get_smoke_config(arch),
                              activation_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    qp, _ = quantize_params(params)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 24)), jnp.int32)

    # reference: no mesh (fallback dispatch path)
    ref = jax.jit(m.forward_logits)(qp, {"tokens": toks})

    # sharded: 2x4 mesh → packed shard_map dispatch (body_q)
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    with shd.use_mesh(mesh):
        qp_s = jax.tree.map(jax.device_put, qp,
                            shd.make_sharding(qp, mesh, shd.param_pspec, cfg))
        got = jax.jit(m.forward_logits)(qp_s, {"tokens": toks})
    err = float(jnp.abs(got - ref).max())
    out[arch] = err
    assert err < 1e-4, (arch, err)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_packed_moe_shardmap_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULT:"):])
    assert all(v < 1e-4 for v in res.values()), res
