"""Mamba-2 SSD: chunked algorithm ≡ naive recurrence oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import ssm


def _naive_ssd(x, bh, ch, dt, a_log, d_skip):
    """Direct per-step recurrence (the definition, O(S) python loop)."""
    b, s, nh, hd = x.shape
    ds = bh.shape[-1]
    h = np.zeros((b, nh, hd, ds), np.float64)
    y = np.zeros_like(np.asarray(x, np.float64))
    a = -np.exp(np.asarray(a_log, np.float64))
    for t in range(s):
        da = np.exp(np.asarray(dt[:, t], np.float64) * a)      # [B,nh]
        h = h * da[:, :, None, None] + np.einsum(
            "bh,bhs,bhd->bhds", np.asarray(dt[:, t], np.float64),
            np.asarray(bh[:, t], np.float64), np.asarray(x[:, t], np.float64))
        y[:, t] = np.einsum("bhds,bhs->bhd", h, np.asarray(ch[:, t],
                                                           np.float64))
    y += np.asarray(x, np.float64) * np.asarray(d_skip)[None, None, :, None]
    return y


def test_ssd_chunked_matches_naive_recurrence():
    cfg = dataclasses.replace(C.get_smoke_config("mamba2-130m"), ssm_chunk=8)
    b, s = 2, 32
    nh, hd, ds = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    bh = jax.random.normal(ks[1], (b, s, nh, ds)) * 0.5
    ch = jax.random.normal(ks[2], (b, s, nh, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, nh)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, nh))
    d_skip = jnp.ones((nh,))

    # run the chunked path by calling the mixer internals directly
    q = cfg.ssm_chunk
    nc = s // q
    da = dt * (-jnp.exp(a_log))[None, None, :]
    xc = x.reshape(b, nc, q, nh, hd)
    bc = bh.reshape(b, nc, q, nh, ds)
    cc = ch.reshape(b, nc, q, nh, ds)
    dac = da.reshape(b, nc, q, nh)
    dtc = dt.reshape(b, nc, q, nh)
    seg = jnp.cumsum(dac, axis=2)
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((q, q), bool))
    li = jnp.where(causal[None, None, :, :, None], li, -1e30)
    scores = jnp.einsum("bnihs,bnjhs->bnijh", cc, bc) * jnp.exp(li) \
        * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", scores, xc)
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)
    state_c = jnp.einsum("bnjhs,bnjh,bnjhd->bnhds", bc, dtc * decay_to_end,
                         xc)
    chunk_decay = jnp.exp(seg[:, :, -1, :])

    def scan_body(h, inp):
        st, dec = inp
        return h * dec[:, :, None, None] + st, h

    h0 = jnp.zeros((b, nh, hd, ds))
    _, h_prev = jax.lax.scan(scan_body, h0,
                             (jnp.moveaxis(state_c, 1, 0),
                              jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)
    y_inter = jnp.einsum("bnihs,bnhds->bnihd",
                         cc * jnp.exp(seg)[..., None], h_prev)
    y = (y_intra + y_inter).reshape(b, s, nh, hd) \
        + x * d_skip[None, None, :, None]

    y_ref = _naive_ssd(x, bh, ch, dt, a_log, d_skip)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                               rtol=2e-4, atol=2e-4)


def test_ssm_decode_matches_mixer():
    """One-token recurrent decode ≡ last step of the full mixer."""
    cfg = C.get_smoke_config("mamba2-130m")
    p = ssm.ssm_init(jax.random.PRNGKey(0), cfg)
    x_seq = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.float32) * 0.5
    y_full = ssm.ssm_mixer(p, x_seq, cfg)
    cache = ssm.init_ssm_cache(cfg, 2)
    for t in range(16):
        y_t, cache = ssm.ssm_decode(p, cache, x_seq[:, t], cfg)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               rtol=5e-3, atol=5e-3)
