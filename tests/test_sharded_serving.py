"""Mesh-sharded serving: greedy tensor-parallel streams must be
token-identical to the single-device engine across the full feature
matrix (chunked prefill × int8 KV pools × prefix sharing × ngram
speculative decoding), per-device pool bytes must shrink linearly with
the ``model`` axis, and indivisible head counts must fail at engine
construction with a clear error.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=4 so the
main pytest process keeps its single real device (same pattern as
test_distributed / test_moe_sharded)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import json
import jax
import numpy as np
import repro.configs as C
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.serving import GenerationEngine

# Hkv = 4 divides both the 2- and 4-way mesh; the stock smoke config's
# Hkv = 1 is the indivisible error-path fixture further down.
cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                          num_heads=8, num_kv_heads=4, head_dim=16)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
out = {"device_count": jax.device_count()}

rng = np.random.default_rng(0)
prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
prompts = [np.concatenate([prefix,
                           rng.integers(0, cfg.vocab_size, (t,)
                                        ).astype(np.int32)])
           for t in (5, 12, 9, 3)]


def serve(mesh, **kw):
    eng = GenerationEngine(m, params, max_seq=64, num_slots=4, page_size=8,
                           prefill_chunk=4, mesh=mesh, **kw)
    rids = [eng.submit(p, 10, prefix_id="sys") for p in prompts]
    streams = {}
    while not eng.idle:
        eng.step()
    done = eng.collect()
    streams = [[int(t) for t in done[r]] for r in rids]
    st = eng.stats()
    assert st.pager.pages_used == 0          # everything freed
    return streams, st


# --- full feature matrix: chunked × int8 × prefix sharing × ngram spec ---
FULL = dict(kv_quant="int8", spec_decode="ngram", spec_k=4)
ref, st_ref = serve(None, **FULL)
out["ref_nonempty"] = all(len(s) == 10 for s in ref)
out["spec_fired"] = st_ref.draft_tokens > 0
out["prefix_fired"] = st_ref.prefix_shared_pages > 0
out["bytes_per_dev"] = {}
for size in (1, 2, 4):
    got, st = serve(serving_mesh(size), **FULL)
    out[f"identical_{size}"] = got == ref
    out[f"model_axis_{size}"] = st.model_axis
    out["bytes_per_dev"][str(size)] = st.kv_pool_bytes_per_device
    if size == 1:
        # the degenerate mesh must also match the unsharded byte layout
        out["size1_bytes_match"] = (st.kv_pool_bytes_per_device
                                    == st_ref.kv_pool_bytes_per_device)

# --- bf16 pools, no speculation: the plain chunked path sharded ---------
ref_plain, _ = serve(None)
got_plain, _ = serve(serving_mesh(2))
out["identical_plain_2"] = got_plain == ref_plain

# --- error paths --------------------------------------------------------
def err(fn):
    try:
        fn()
        return ""
    except ValueError as e:
        return str(e)

out["err_indivisible"] = err(lambda: GenerationEngine(
    build_model(C.get_smoke_config("qwen25-05b")), params,
    mesh=serving_mesh(2)))                       # Hkv = 1, axis 2
out["err_no_model_axis"] = err(lambda: GenerationEngine(
    m, params, mesh=jax.sharding.Mesh(
        np.asarray(jax.devices()[:2]), ("data",))))
def oneshot():
    eng = GenerationEngine(m, params, max_seq=64, num_slots=2, page_size=8,
                           chunked_prefill=False, mesh=serving_mesh(2))
    eng.submit(prompts[0], 4)
out["err_oneshot"] = err(oneshot)

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_forced_multi_device_backend(result):
    assert result["device_count"] == 4


def test_sharded_streams_token_identical(result):
    """Greedy sharded ≡ single-device across chunked prefill, int8 KV,
    prefix sharing and ngram spec decode — for 2- and 4-way meshes and
    the degenerate size-1 mesh."""
    assert result["ref_nonempty"]
    assert result["spec_fired"] and result["prefix_fired"]
    for size in (1, 2, 4):
        assert result[f"identical_{size}"], f"mesh size {size} diverged"
        assert result[f"model_axis_{size}"] == size
    assert result["identical_plain_2"]


def test_per_device_pool_bytes_shrink_linearly(result):
    b = {int(k): v for k, v in result["bytes_per_dev"].items()}
    assert result["size1_bytes_match"]
    assert b[2] == b[1] // 2
    assert b[4] == b[1] // 4


def test_construction_time_errors(result):
    assert "num_kv_heads=1" in result["err_indivisible"]
    assert "divisible" in result["err_indivisible"]
    assert "'model' axis" in result["err_no_model_axis"]
    assert "chunked" in result["err_oneshot"]
