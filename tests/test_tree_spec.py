"""Tree speculative decoding + parallel sampling: greedy token identity
vs sequential generate() across drafters (ngram / draft model / custom
tree draft_fn), int8 KV pools, prefix sharing, mid-stream preemption and
a forced 2-way mesh; scheduler tree packing (ancestor closure, depth
positions, path-based emission) against a fake executor; `submit(n=...)`
prompt-page sharing and sampled-marginal equivalence."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig
from repro.serving.kv_pager import KVPager, PagerConfig
from repro.serving.scheduler import (Request, Scheduler, ngram_propose,
                                     ngram_propose_tree)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _engine(m, params, **kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("num_slots", 4)
    kw.setdefault("page_size", 8)
    return GenerationEngine(m, params, **kw)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


def _refs(eng, prompts, max_new):
    return [np.asarray(eng.generate({"tokens": jnp.asarray(p)[None, :]},
                                    max_new)[0]) for p in prompts]


def _pager_invariants(pager):
    free = set(pager.free_pages)
    assert len(free) == len(pager.free_pages)
    for pg in range(1, pager.cfg.num_pages):
        if pg in free:
            assert pager.page_ref[pg] == 0, pg
        else:
            assert pager.page_ref[pg] >= 1, pg
    assert pager.pages_in_use == pager.cfg.num_pages - 1 - len(free)


# ---------------------------------------------------------------------------
# n-gram tree drafter (host-side, no model)
# ---------------------------------------------------------------------------

def test_ngram_tree_chain_plus_alternates():
    # suffix [5] occurs earlier at sites continuing with 8 (older: 6, 9)
    ctx = np.array([5, 6, 1, 5, 9, 2, 5, 8, 3, 5], np.int32)
    nodes = ngram_propose_tree(ctx, budget=5, fanout=3, max_n=3)
    toks = [t for t, _ in nodes]
    pars = [p for _, p in nodes]
    # primary chain from the MOST RECENT site: [8, 3, 5] at depth 1..3
    assert toks[:3] == [8, 3, 5] and pars[:3] == [-1, 0, 1]
    # alternates from older sites, distinct first tokens, branching root
    assert sorted(toks[3:]) == [6, 9] and pars[3:] == [-1, -1]
    # topological: every parent precedes its child
    assert all(p < i for i, p in enumerate(pars))


def test_ngram_tree_budget_and_fallbacks():
    ctx = np.array([5, 6, 1, 5, 9, 2, 5, 8, 3, 5], np.int32)
    # budget 2 with fanout 3: chain keeps at least one node, one alternate
    nodes = ngram_propose_tree(ctx, budget=2, fanout=3, max_n=3)
    assert len(nodes) == 2 and nodes[0] == (8, -1) and nodes[1][1] == -1
    # fanout 1 degenerates to the linear proposal
    lin = ngram_propose(ctx, 4, max_n=3)
    nodes = ngram_propose_tree(ctx, budget=4, fanout=1, max_n=3)
    assert [t for t, _ in nodes] == lin
    assert [p for _, p in nodes] == list(range(-1, len(nodes) - 1))
    # no match → empty
    assert ngram_propose_tree(np.array([1, 2, 3, 4], np.int32), 4, 2) == []


# ---------------------------------------------------------------------------
# Scheduler tree packing against a fake executor (no model)
# ---------------------------------------------------------------------------

class _FakeTreeExec:
    """Scripted tree verifier: records the packed rpos/amask/parents and
    accepts a scripted path per call."""

    def __init__(self, script):
        self.script = script           # list of (n_acc, path row) per call
        self.calls = []

    def run_batch(self, tokens, pos, row_slots, sample_idx, temps, topks,
                  n_draft=None, tree=None):
        b = tokens.shape[0]
        if tree is None:
            if n_draft is None:
                return np.full(b, 100, np.int32)
            return (np.full(b, 100, np.int32), np.zeros(b, np.int32))
        self.calls.append({k: v.copy() for k, v in tree.items()}
                          | {"tokens": tokens.copy(), "pos": pos.copy(),
                             "n_draft": n_draft.copy()})
        n_acc = np.zeros(b, np.int32)
        path = np.zeros((b, tokens.shape[1]), np.int32)
        na, prow = self.script.pop(0)
        n_acc[0] = na
        path[0, :len(prow)] = prow
        return np.full(b, 100, np.int32), n_acc, path


def _tree_sched(draft, script, k=4, fanout=2):
    ex = _FakeTreeExec(script)
    pager = KVPager(PagerConfig(num_pages=9, page_size=4, num_slots=2,
                                pages_per_slot=4))
    sched = Scheduler(pager, run_batch=ex.run_batch, chunk_size=4,
                      spec_decode="draft_fn", spec_k=k, draft_fn=draft,
                      spec_tree=True, spec_tree_fanout=fanout)
    return sched, ex


def test_fake_tree_packs_ancestor_closure_and_walks_path():
    """A chain 7→8 plus alternate 9: the packed row must carry depth
    rpos, the ancestor closure and in-row parents; a scripted acceptance
    of the ALTERNATE emits via the path, then rolls the rest back."""
    def draft(reqs):
        return {slot: [(7, -1), (8, 0), (9, -1)]
                for slot, _r, _c, _q, _k, _f in reqs}

    sched, ex = _tree_sched(draft, script=[(1, [3])])
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=8))
    sched.step()                                  # prefill → first token
    ev = sched.step()                             # tree verify
    # alternate (node 2, in-row 3) accepted, then the corrected token
    assert [t for _r, t in ev] == [9, 100]
    call = ex.calls[0]
    q = 4                                         # root position
    np.testing.assert_array_equal(call["pos"][0, :4], [q, q + 1, q + 2,
                                                       q + 3])
    np.testing.assert_array_equal(call["rpos"][0, :4],
                                  [q, q + 1, q + 2, q + 1])   # 9 at depth 1
    np.testing.assert_array_equal(call["parents"][0, :4], [-1, 0, 1, 0])
    am = call["amask"][0]
    np.testing.assert_array_equal(
        am[:4, :4], np.array([[1, 0, 0, 0], [1, 1, 0, 0],
                              [1, 1, 1, 0], [1, 0, 0, 1]], bool))
    assert not am[4:].any() and not am[:, 4:].any()
    assert call["n_draft"][0] == 3
    # rollback kept root + the one accepted node: watermark q + 2
    assert int(sched.pager.slot_len[0]) == q + 2
    assert sched.stats.accepted_tokens == 1
    assert sched.stats.draft_tokens == 3
    assert sched.stats.rollbacks == 1
    _pager_invariants(sched.pager)


def test_fake_tree_rejects_non_topological_draft():
    def draft(reqs):
        return {slot: [(7, 1), (8, -1)] for slot, *_ in reqs}

    sched, _ = _tree_sched(draft, script=[])
    sched.submit(Request(rid=0, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=8))
    sched.step()
    with pytest.raises(ValueError, match="topological"):
        sched.step()


def test_adaptive_fanout_hedges_on_rejection():
    """The tree-shape EMA: sustained rejection WIDENS the root fanout
    (hedging), sustained acceptance narrows it back to 1 so the budget
    buys depth."""
    def draft(reqs):
        return {slot: [(7, -1)] for slot, *_ in reqs}

    sched, _ = _tree_sched(draft, script=[], fanout=4)
    sched.adaptive_spec_k = True
    assert sched.fanout_cur == 2
    for _ in range(4):
        sched._adapt_spec_k(0.0)
    assert sched.fanout_cur == 4                  # grew to the cap
    for _ in range(6):
        sched._adapt_spec_k(1.0)
    assert sched.fanout_cur == 1


def test_tree_config_validation(model_and_params):
    cfg, m, params = model_and_params
    with pytest.raises(ValueError, match="spec_tree"):
        _engine(m, params, spec_tree=True)        # no drafter
    with pytest.raises(ValueError, match="fanout"):
        _engine(m, params, spec_decode="ngram", spec_tree=True,
                spec_tree_fanout=0)


# ---------------------------------------------------------------------------
# End-to-end greedy identity: tree-spec streams ≡ sequential generate()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout", [1, 2, 3])
def test_greedy_ngram_tree_identity_across_fanout(model_and_params, fanout):
    cfg, m, params = model_and_params
    rng = np.random.default_rng(2)
    pats = [rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
            for _ in range(2)]
    prompts = [np.tile(p, 5) for p in pats] + _prompts(cfg, (9, 13), seed=3)

    eng = _engine(m, params, spec_decode="ngram", spec_k=4, spec_tree=True,
                  spec_tree_fanout=fanout)
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.drain()
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)
    refs = _refs(eng, prompts, 10)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    st = eng.scheduler_stats
    assert st.draft_tokens > 0
    assert 0 <= st.accepted_tokens <= st.draft_tokens


def test_greedy_draft_model_tree_identity(model_and_params):
    """Draft model = the target: the primary chain matches the argmax
    chain, so acceptance walks deep while alternates are rejected and
    rolled back — streams stay identical."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 12, 9), seed=9)
    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  spec_tree=True, spec_tree_fanout=2,
                  draft_model=m, draft_params=params)
    rids = [eng.submit(p, 12) for p in prompts]
    out = eng.drain()
    st = eng.scheduler_stats
    assert st.accepted_tokens > 0
    assert st.spec_tokens_per_row > 2.0
    refs = _refs(eng, prompts, 12)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    assert eng._scheduler.pager.pages_in_use == 0


def test_oracle_tree_draft_accepts_chain_rejects_alternates(model_and_params):
    """A custom tree draft_fn whose chain is the true greedy continuation
    and whose alternates are deliberately wrong: every step accepts the
    full chain (never an alternate), the bonus token rides along, and
    the stream is identical."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (6, 11), seed=4)
    eng0 = _engine(m, params)
    refs = _refs(eng0, prompts, 9)
    oracle = {}

    def draft(reqs):
        out = {}
        for slot, rid, ctx, _q, k, fanout in reqs:
            ref, plen = oracle[rid]
            done = len(ctx) - plen
            chain = [int(t) for t in ref[done:done + max(1, k - 1)]]
            nodes = [(chain[0], -1)]
            nodes += [(t, i) for i, t in enumerate(chain[1:])]
            if len(nodes) < k:                     # one wrong alternate
                nodes.append(((chain[0] + 1) % cfg.vocab_size, -1))
            out[slot] = nodes
        return out

    eng = _engine(m, params, spec_decode="draft_model", spec_k=4,
                  spec_tree=True, spec_tree_fanout=2, draft_fn=draft)
    rids = [eng.submit(p, 9) for p in prompts]
    for rid, p, ref in zip(rids, prompts, refs):
        oracle[rid] = (ref, len(p))
    out = eng.drain()
    st = eng.scheduler_stats
    assert st.accepted_tokens > 0
    assert st.rollbacks > 0                        # alternates always lose
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)


def test_tree_int8_kv_matches_plain_chunked_int8(model_and_params):
    """Int8 pools: tree verify writes draft KV through the same
    quantize-on-write codec and compaction moves raw codes, so greedy
    tree-spec streams equal the no-spec chunked engine's int8 streams."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (5, 9, 12), seed=5)

    def serve(**kw):
        eng = _engine(m, params, kv_quant="int8", **kw)
        rids = [eng.submit(p, 8) for p in prompts]
        out = eng.drain()
        assert eng._scheduler.pager.pages_in_use == 0
        return [list(out[r]) for r in rids], eng

    plain, _ = serve()
    tree, eng_t = serve(spec_decode="ngram", spec_k=4, spec_tree=True)
    assert tree == plain
    # deterministic: a second tree run reproduces the streams
    tree2, _ = serve(spec_decode="ngram", spec_k=4, spec_tree=True)
    assert tree2 == tree


def test_tree_with_prefix_sharing(model_and_params):
    cfg, m, params = model_and_params
    rng = np.random.default_rng(10)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (t,)
                                            ).astype(np.int32)])
               for t in (4, 7, 3)]

    def serve(prefix_id):
        eng = _engine(m, params, spec_decode="ngram", spec_k=4,
                      spec_tree=True)
        rids = [eng.submit(p, 8, prefix_id=prefix_id) for p in prompts]
        out = eng.drain()
        assert eng._scheduler.pager.pages_in_use == 0
        _pager_invariants(eng._scheduler.pager)
        return [list(out[r]) for r in rids], eng._scheduler.stats

    shared, st_s = serve("sys")
    unshared, st_u = serve(None)
    assert shared == unshared
    assert st_s.prefix_shared_pages > 0


def test_tree_mid_stream_preemption_identity(model_and_params):
    """Preempting a slot between tree-verify steps spills its pages and
    restores them later with zero recompute — the stream still equals
    sequential generate()."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (7, 6), seed=6)
    eng = _engine(m, params, num_slots=2, preemption=True,
                  spec_decode="ngram", spec_k=4, spec_tree=True)
    reps = [np.tile(p[:3], 4)[:len(p)] for p in prompts]
    refs = _refs(eng, reps, 10)
    rids = [eng.submit(p, 10) for p in reps]
    eng.step()
    eng.step()
    assert eng.preempt(rids[0])                    # spill mid-stream
    out = eng.drain()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)
    sst = eng.scheduler_stats
    assert sst.preemptions >= 1 and sst.restores == sst.preemptions
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)


def test_sampled_tree_deterministic_and_greedy_rows_exact(model_and_params):
    """Sampled rows ride the tree dispatch (one-hot reduction keeps
    greedy rows exact); per-seed streams are reproducible."""
    cfg, m, params = model_and_params
    prompts = _prompts(cfg, (6, 9), seed=11)

    def serve():
        eng = _engine(m, params, spec_decode="ngram", spec_k=3,
                      spec_tree=True, seed=5)
        r_g = eng.submit(np.tile(prompts[0][:3], 4), 10,
                         sampler=SamplerConfig(0.0))
        r_h = eng.submit(prompts[1], 10,
                         sampler=SamplerConfig(temperature=1.2, top_k=8))
        out = eng.drain()
        assert eng._scheduler.pager.pages_in_use == 0
        return {"g": list(out[r_g]), "h": list(out[r_h])}, eng

    a, eng = serve()
    b, _ = serve()
    assert a == b
    ref = eng.generate({"tokens": jnp.asarray(
        np.tile(prompts[0][:3], 4))[None, :]}, 10)[0]
    np.testing.assert_array_equal(a["g"], np.asarray(ref))
    assert len(a["h"]) == 10


# ---------------------------------------------------------------------------
# Parallel sampling: submit(n=...)
# ---------------------------------------------------------------------------

def test_parallel_greedy_identical_streams_and_page_sharing(model_and_params):
    """Greedy n=3 siblings emit identical streams while the prompt's full
    KV pages are written once and aliased (refcount > 1 while alive)."""
    cfg, m, params = model_and_params
    prompt = _prompts(cfg, (20,), seed=7)[0]      # 2 full pages at page 8
    eng = _engine(m, params)
    ref = _refs(eng, [prompt], 8)[0]
    rids = eng.submit(prompt, 8, n=3)
    assert isinstance(rids, list) and len(rids) == 3
    out = eng.drain()
    for r in rids:
        np.testing.assert_array_equal(out[r], ref)
    st = eng.scheduler_stats
    assert st.prefix_shared_pages >= 4            # 2 pages × 2 siblings
    assert st.prefill_tokens_skipped > 0          # chunks actually skipped
    assert eng._scheduler.pager.pages_in_use == 0
    _pager_invariants(eng._scheduler.pager)


def test_parallel_submit_shapes_and_validation(model_and_params):
    cfg, m, params = model_and_params
    eng = _engine(m, params)
    rid = eng.submit(np.arange(4, dtype=np.int32), 2)
    assert isinstance(rid, int)                   # n=1 keeps the scalar form
    with pytest.raises(ValueError, match="n must be"):
        eng.submit(np.arange(4, dtype=np.int32), 2, n=0)
    # explicit prefix_id is respected for the sibling group
    rids = eng.submit(np.arange(20, dtype=np.int32), 2, n=2,
                      prefix_id="sys")
    assert len(rids) == 2
    eng.drain()
    assert eng._scheduler.pager.pages_in_use == 0


def test_parallel_sampled_marginals_match_independent_runs(model_and_params):
    """The first sampled token of `submit(n=2)` siblings is distributed
    like two independent single submissions: empirical first-token
    distributions agree within a loose total-variation bound."""
    cfg, m, params = model_and_params
    prompt = _prompts(cfg, (20,), seed=8)[0]
    samp = SamplerConfig(temperature=1.0, top_k=4)

    def first_tokens(n_mode, reps, seed):
        eng = _engine(m, params, seed=seed)
        firsts = []
        for _ in range(reps):
            if n_mode:
                rids = eng.submit(prompt, 1, sampler=samp, n=2)
            else:
                rids = [eng.submit(prompt, 1, sampler=samp)
                        for _ in range(2)]
            out = eng.drain()
            firsts += [int(out[r][0]) for r in rids]
        assert eng._scheduler.pager.pages_in_use == 0
        return firsts

    a = first_tokens(True, 40, seed=1)
    b = first_tokens(False, 40, seed=2)
    support = sorted(set(a) | set(b))
    assert len(support) <= 4                      # top_k bounds the support
    pa = np.array([a.count(t) for t in support], float) / len(a)
    pb = np.array([b.count(t) for t in support], float) / len(b)
    assert 0.5 * np.abs(pa - pb).sum() < 0.25     # TV distance, n=80 each
    # siblings draw independently: with 40 pairs over a non-degenerate
    # support, at least one pair must differ
    if len(support) > 1 and pa.max() < 0.85:
        assert any(a[2 * i] != a[2 * i + 1] for i in range(40))


# ---------------------------------------------------------------------------
# Forced 2-way mesh: tree spec + parallel sampling sharded ≡ unsharded
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import json
import jax
import numpy as np
import repro.configs as C
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.serving import GenerationEngine

cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                          num_heads=8, num_kv_heads=4, head_dim=16)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
out = {"device_count": jax.device_count()}

rng = np.random.default_rng(0)
pat = rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
prompts = [np.tile(pat, 6),
           rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)]


def serve(mesh):
    eng = GenerationEngine(m, params, max_seq=64, num_slots=4, page_size=8,
                           mesh=mesh, spec_decode="ngram", spec_k=4,
                           spec_tree=True, spec_tree_fanout=2,
                           kv_quant="int8")
    rids = [eng.submit(p, 10) for p in prompts]
    rids += eng.submit(prompts[0], 10, n=2)
    out = eng.drain()
    st = eng.scheduler_stats
    assert eng._scheduler.pager.pages_in_use == 0
    return [[int(t) for t in out[r]] for r in rids], st


ref, st_ref = serve(None)
got, st = serve(serving_mesh(2))
out["spec_fired"] = st_ref.draft_tokens > 0 and st.draft_tokens > 0
out["identical_2"] = got == ref
out["parallel_identical"] = got[2] == got[3] == got[0]
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_tree_sharded_streams_token_identical(mesh_result):
    assert mesh_result["device_count"] == 2
    assert mesh_result["spec_fired"]
    assert mesh_result["identical_2"]
    assert mesh_result["parallel_identical"]
