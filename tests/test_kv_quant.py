"""INT8 KV-cache quantization (§Perf A4): correctness + cost accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import build_model
from repro.models.attention import _kv_dequant, _kv_quantize


def test_kv_quantize_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64)) * 3.0
    q, s = _kv_quantize(x)
    xr = _kv_dequant(q, s, jnp.float32)
    err = jnp.abs(xr - x)
    # per-row error ≤ scale/2
    assert bool(jnp.all(err <= s[..., None] * 0.5 + 1e-6))
    # zero rows stay exact
    q0, s0 = _kv_quantize(jnp.zeros((2, 2, 8)))
    assert float(jnp.abs(_kv_dequant(q0, s0, jnp.float32)).max()) == 0.0


def test_kv_int8_decode_matches_forward():
    cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                              kv_quant="int8")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 56)), jnp.int32)
    ref = jax.jit(m.forward_logits)(params, {"tokens": toks})
    cache = m.init_cache(2, 128)
    assert cache["seg_0"]["kv"]["k"].dtype == jnp.int8
    cache, logits, pos = jax.jit(m.prefill)(
        params, {"tokens": toks[:, :40]}, cache)
    errs = [float(jnp.abs(logits - ref[:, 39]).max())]
    dstep = jax.jit(m.decode_step)
    for t in range(16):
        logits, cache = dstep(params, cache, toks[:, 40 + t], pos)
        pos = pos + 1
        errs.append(float(jnp.abs(logits - ref[:, 40 + t]).max()))
    assert max(errs) < 6e-2, errs


def test_kv_int8_costmodel_reduction():
    from repro.configs import SHAPES
    from repro.roofline.costmodel import cell_costs
    cfg = C.get_config("qwen25-05b")
    cfg8 = dataclasses.replace(cfg, kv_quant="int8")
    a = cell_costs(cfg, SHAPES["decode_32k"], quant=True)
    b = cell_costs(cfg8, SHAPES["decode_32k"], quant=True)
    assert b.cache_bytes < 0.55 * a.cache_bytes  # ~1.9× fewer cache bytes
    assert b.weight_bytes == a.weight_bytes
