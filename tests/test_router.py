"""Multi-replica serving fleet: the prefix-affinity `Router`.

Covers the placement policy (deterministic scoring, the affinity /
load / SLO trade-offs), session stickiness across elastic drain +
re-join, the 1-replica-fleet ≡ bare-engine identity, zero-loss
`drain_replica` under load, the `SchedulerStats.zero()` in-place reset
regression, and — in a forced-4-device subprocess (same pattern as
test_sharded_serving) — two TP-2 replicas behind the Router streaming
token-identical to one unsharded engine.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine, Router
from repro.serving.scheduler import SchedulerStats

KW = dict(max_seq=96, num_slots=4, page_size=8, prefill_chunk=8)


@pytest.fixture(scope="module")
def mp():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0)), cfg


def _prompts(cfg, n, prefix_len=32, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    return prefix, [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)])
        for _ in range(n)]


# ---------------------------------------------------------------- placement

def test_placement_scores_deterministic(mp):
    """Scoring is pure in the fleet state: same request, same scores,
    same argmax — twice in a row, no mutation between calls."""
    m, params, cfg = mp
    router = Router([GenerationEngine(m, params, **KW) for _ in range(2)])
    _, prompts = _prompts(cfg, 1)
    s1 = router.placement_scores(prompts[0], prefix_id="sys")
    s2 = router.placement_scores(prompts[0], prefix_id="sys")
    assert s1 == s2
    assert router.place(prompts[0], prefix_id="sys") \
        == router.place(prompts[0], prefix_id="sys")
    # empty fleet, no affinity: ties break toward the lowest index
    assert router.place(prompts[0], prefix_id="sys") == 0


def test_affinity_beats_load_only_above_threshold(mp):
    """Replica 0 holds the prefix pages but also carries load; replica 1
    is empty. With the resident-page count at or above the threshold the
    affinity term dominates the load penalty (place on 0); raising the
    threshold past the page count suppresses the term and pure
    load-balancing wins (place on 1)."""
    m, params, cfg = mp
    warm = GenerationEngine(m, params, **KW)
    cold = GenerationEngine(m, params, **KW)
    prefix, prompts = _prompts(cfg, 3)
    warm.pin_prefix("sys")                     # sticky: warm run joins it
    warm.submit(prompts[0], 2, prefix_id="sys")
    warm.drain()
    pages = warm.prefix_reuse_pages(prompts[1], "sys")
    assert pages == len(prefix) // KW["page_size"]      # 4 full pages
    warm.submit(prompts[1], 16, prefix_id="sys")        # load, not stepped
    warm.submit(prompts[2], 16, prefix_id="sys")

    low = Router([warm, cold], affinity_threshold=pages)
    assert low.place(prompts[1], prefix_id="sys") == 0
    high = Router([warm, cold], affinity_threshold=pages + 1)
    assert high.place(prompts[1], prefix_id="sys") == 1
    warm.drain()


def test_interactive_avoids_batch_heavy_replica(mp):
    """SLO scoring: an interactive (priority>0) request must not land
    behind a batch backlog even when that replica holds its prefix."""
    m, params, cfg = mp
    warm = GenerationEngine(m, params, **KW)
    cold = GenerationEngine(m, params, **KW)
    _, prompts = _prompts(cfg, 1)
    router = Router([warm, cold])
    warm.pin_prefix("sys")
    router.submit(prompts[0], 2, prefix_id="sys")       # lands on 0 (tie)
    router.drain()
    # pile batch (priority 0) work onto replica 0 through the router so
    # the router's own ledger sees the backlog
    for _ in range(6):
        router.submit(prompts[0], 16, prefix_id="sys")
    assert router.place(prompts[0], prefix_id="sys") == 0   # batch: affinity
    assert router.place(prompts[0], prefix_id="sys",
                        priority=1) == 1                    # interactive
    router.drain()


# ------------------------------------------------- identity + drain / join

def test_one_replica_fleet_matches_bare_engine(mp):
    m, params, cfg = mp
    _, prompts = _prompts(cfg, 4)
    eng = GenerationEngine(m, params, **KW)
    refs = [eng.submit(p, 8, prefix_id="sys") for p in prompts]
    rout = eng.drain()
    want = [list(rout[r]) for r in refs]

    fleet = Router([GenerationEngine(m, params, **KW)])
    rids = [fleet.submit(p, 8, prefix_id="sys") for p in prompts]
    out = fleet.drain()
    assert [list(out[r]) for r in rids] == want


def test_drain_under_load_loses_nothing(mp):
    """`drain_replica` mid-flight: queued requests reroute under their
    original global rids, in-flight ones finish in place, and every
    stream comes back exactly once, byte-equal to bare-engine
    references."""
    m, params, cfg = mp
    _, prompts = _prompts(cfg, 6)
    eng = GenerationEngine(m, params, **KW)
    refs = [eng.submit(p, 8, prefix_id="sys") for p in prompts]
    rout = eng.drain()
    want = [list(rout[r]) for r in refs]

    fleet = Router([GenerationEngine(m, params, **KW) for _ in range(2)])
    # 12 requests > 2 fleets x 4 slots: some must queue
    rids = [fleet.submit(p, 8, prefix_id="sys") for p in prompts * 2]
    for _ in range(2):
        fleet.step()
    fleet.drain_replica(0)
    assert fleet._replicas[0].idle
    out = fleet.drain()
    assert sorted(out) == sorted(rids)          # exactly once, no extras
    assert [list(out[r]) for r in rids] == want + want
    assert fleet.router_stats.drains == 1
    assert fleet.router_stats.reroutes >= 1


def test_session_stickiness_survives_drain_and_rejoin(mp):
    """A session follows its replica until that replica drains, then
    re-homes; re-joining the drained replica must NOT steal the session
    back — its pages now live at the new home."""
    m, params, cfg = mp
    _, prompts = _prompts(cfg, 1)
    fleet = Router([GenerationEngine(m, params, **KW) for _ in range(2)])
    p = prompts[0]
    fleet.submit(p, 4, prefix_id="sys", session_id="alice")
    fleet.drain()
    home = fleet._sessions["alice"]
    i_home = next(i for i, r in enumerate(fleet._replicas) if r is home)
    assert fleet.place(p, session_id="alice") == i_home

    fleet.drain_replica(i_home)
    i_new = fleet.place(p, session_id="alice")
    assert i_new != i_home                      # draining replica avoided
    fleet.submit(p, 4, prefix_id="sys", session_id="alice")
    fleet.drain()
    assert fleet._sessions["alice"] is fleet._replicas[i_new]

    fleet.add_replica(fleet._replicas[i_home])  # re-join, pages warm
    assert fleet.place(p, session_id="alice") == i_new   # stays re-homed


def test_add_remove_replica_guards(mp):
    m, params, cfg = mp
    _, prompts = _prompts(cfg, 1)
    fleet = Router([GenerationEngine(m, params, **KW) for _ in range(2)])
    rid = fleet.submit(prompts[0], 4)           # tie-break: replica 0
    with pytest.raises(RuntimeError, match="not idle"):
        fleet.remove_replica(0)
    while not fleet.idle:                       # finish, but don't collect
        fleet.step()
    fleet.drain_replica(0)                      # already idle: no-op wait
    dropped = fleet.remove_replica(0)
    assert fleet.num_replicas == 1
    with pytest.raises(RuntimeError, match="last replica"):
        fleet.remove_replica(0)
    # the removed replica's finished stream was buffered on removal
    assert rid in fleet.collect()
    assert fleet.add_replica(dropped) == 1
    assert fleet.num_replicas == 2


# ----------------------------------------------------- stats reset (PR 10)

def test_reset_stats_zeroes_in_place(mp):
    """`reset_stats` must zero the live `SchedulerStats` object, not
    replace it: references taken before the reset keep seeing the live
    counters."""
    m, params, cfg = mp
    _, prompts = _prompts(cfg, 2)
    eng = GenerationEngine(m, params, **KW)
    for p in prompts:
        eng.submit(p, 4, prefix_id="sys")
    eng.drain()
    live = eng._scheduler.stats
    assert live.decode_steps > 0
    eng.reset_stats()
    assert eng._scheduler.stats is live         # identity preserved
    assert live.decode_steps == 0 and live.admitted == 0
    eng.submit(prompts[0], 2, prefix_id="sys")
    eng.drain()
    assert live.decode_steps > 0                # reference still live


def test_stats_zero_spares_no_default_fields():
    """The in-place reset only touches counters with declared defaults —
    a subclass binding live state at construction survives `zero()`,
    where the old ``type(stats)()`` rebuild would TypeError."""
    @dataclasses.dataclass
    class BoundStats(SchedulerStats):
        owner: object = dataclasses.field(kw_only=True)   # no default

    s = BoundStats(owner="engine-7")
    s.admitted, s.decode_steps, s.restore_time_s = 3, 11, 0.5
    s.zero()
    assert (s.admitted, s.decode_steps, s.restore_time_s) == (0, 0, 0.0)
    assert s.owner == "engine-7"                # untouched: no default
    with pytest.raises(TypeError):
        type(s)()                               # the rebuild the reset
        #                                         used to do would crash


# ------------------------------------------- forced-4-device sharded fleet

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import json
import jax
import numpy as np
import repro.configs as C
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.serving import GenerationEngine, Router

cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                          num_heads=8, num_kv_heads=4, head_dim=16)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
out = {"device_count": jax.device_count()}

KW = dict(max_seq=64, num_slots=4, page_size=8, prefill_chunk=4)
rng = np.random.default_rng(0)
prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
prompts = [np.concatenate([prefix,
                           rng.integers(0, cfg.vocab_size, (t,)
                                        ).astype(np.int32)])
           for t in (5, 12, 9, 3)]

ref_eng = GenerationEngine(m, params, **KW)
refs = [ref_eng.submit(p, 10, prefix_id="sys") for p in prompts]
rout = ref_eng.drain()
want = [[int(t) for t in rout[r]] for r in refs]

# two TP-2 replicas: each owns half the forced-4-device pool's devices
fleet = Router([GenerationEngine(m, params, mesh=serving_mesh(2), **KW)
                for _ in range(2)])
out["model_axes"] = [s.model_axis for s in fleet.stats()]
rids = [fleet.submit(p, 10, prefix_id="sys") for p in prompts]
fout = fleet.drain()
out["identical"] = [[int(t) for t in fout[r]] for r in rids] == want
out["spread"] = fleet.router_stats.placements >= len(prompts)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_two_tp2_replicas_match_unsharded_engine(sharded_result):
    """Two TP-2 replicas behind the Router stream token-identical to one
    unsharded engine on the forced-4-device host."""
    assert sharded_result["device_count"] == 4
    assert sharded_result["model_axes"] == [2, 2]
    assert sharded_result["identical"]
    assert sharded_result["spread"]
