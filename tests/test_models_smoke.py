"""Per-arch smoke: reduced config, one loss + prefill + decode step on CPU,
output shapes + finiteness (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from tests.conftest import make_batch

ARCHS = list(C.list_archs())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_finite(arch):
    cfg = C.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(m.loss)(params, make_batch(cfg))
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = C.get_smoke_config(arch)
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, labels=False, s=32)
    cache = m.init_cache(2, 128)
    cache, logits, pos = jax.jit(m.prefill)(params, batch, cache)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(m.decode_step)(params, cache, tok, pos)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_finite(arch):
    cfg = C.get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    g = jax.jit(jax.grad(lambda p, b: m.loss(p, b)[0]))(
        params, make_batch(cfg, s=32))
    gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                            for x in jax.tree.leaves(g))))
    assert np.isfinite(gn) and gn > 0


def test_layer_kind_segments():
    cfg = C.get_config("gemma3-4b")
    segs = cfg.segments()
    # 5:1 pattern: [(local,5),(global,1)]×5 + (local,4) = 11 segments
    assert len(segs) == 11
    assert sum(n for _, n in segs) == 34
    assert segs[1][0].is_global and segs[1][1] == 1
    cfg = C.get_config("hymba-1.5b")
    segs = cfg.segments()
    assert [n for _, n in segs] == [1, 14, 1, 15, 1]
    cfg = C.get_config("deepseek-v2-lite-16b")
    assert [k.mlp for k, _ in cfg.segments()] == ["glu", "moe"]


def test_param_counts_match_published_scale():
    """Analytic n_params within tolerance of the published sizes."""
    expected = {
        "gemma-2b": 2.5e9, "gemma3-4b": 4.3e9, "glm4-9b": 9.4e9,
        "smollm-360m": 3.6e8, "qwen2-moe-a2.7b": 14.3e9,
        "deepseek-v2-lite-16b": 15.7e9,  # model-card total (the "-16b")
        "hymba-1.5b": 1.5e9, "hubert-xlarge": 9.6e8, "mamba2-130m": 1.3e8,
        "phi-3-vision-4.2b": 3.8e9, "qwen25-05b": 4.9e8,
    }
    for arch, exp in expected.items():
        n = C.get_config(arch).n_params()
        assert 0.5 * exp < n < 1.6 * exp, (arch, n, exp)
