"""Pallas kernel allclose sweeps vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import pack_linear
from repro.core.quantize import QuantConfig, quantize_groupwise
from repro.kernels.ops import awq_gateup, awq_matmul, choose_blocks
from repro.kernels.ref import awq_gateup_ref, awq_matmul_ref


def _packed(k, n, gs, seed=0, scale=0.1):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    cfg = QuantConfig(group_size=gs)
    q, s, z = quantize_groupwise(w, cfg)
    return pack_linear(q, s, z, None, None, cfg)


# shape sweep: decode GEMV (m small), prefill GEMM, non-128 N, multi-group K
SHAPES = [
    (1, 128, 128, 64),     # single-token GEMV
    (8, 256, 384, 64),
    (24, 448, 136, 64),    # N % 128 != 0 (bn=8 path), K=7 groups
    (128, 512, 256, 128),  # GS=128
    (100, 256, 128, 64),   # M needs padding
]


@pytest.mark.parametrize("m,k,n,gs", SHAPES)
def test_awq_matmul_matches_ref(m, k, n, gs):
    p = _packed(k, n, gs)
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    ref = awq_matmul_ref(x, p.qweight, p.scales, p.zeros, gs)
    out = awq_matmul(x, p, compute_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_awq_matmul_dtypes(dtype, rtol):
    p = _packed(256, 256, 64)
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 256))
    ref = awq_matmul_ref(x, p.qweight, p.scales, p.zeros, 64,
                         compute_dtype=dtype)
    out = awq_matmul(x, p, compute_dtype=dtype, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=rtol, atol=rtol)


def test_awq_gateup_matches_ref():
    g = _packed(256, 384, 64, seed=1)
    u = _packed(256, 384, 64, seed=2)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 256))
    ref = awq_gateup_ref(x, g.qweight, g.scales, g.zeros, u.qweight,
                         u.scales, u.zeros, 64)
    out = awq_gateup(x, g, u, compute_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_choose_blocks_invariants():
    for m, k, n, gs in [(1, 896, 4864, 64), (128, 4096, 13696, 64),
                        (8, 2048, 16384, 128), (300, 448, 136, 64)]:
        bm, bn, bk = choose_blocks(m, k, n, gs)
        assert bk % gs == 0 and k % bk == 0
        assert n % bn == 0
        assert bm % 8 == 0
        # VMEM budget: one grid step's working set under 8 MB
        vmem = bm * bk * 4 + bk // 8 * bn * 4 + 2 * bk // gs * bn * 4 \
            + bm * bn * 4
        assert vmem < 8 * 2 ** 20


def test_scheduler_emitted_block_shapes_match_ref():
    """Every M the serving scheduler can emit — width · num_slots for
    width in ``width_family(chunk, spec_k)`` ({1, 2, 4, …, chunk} plus
    the k+1 spec-verify widths) — through the wrapper vs the jnp oracle:
    GEMV (m ≤ 8), exact GEMM tiling, and padded odd widths."""
    from repro.serving.scheduler import width_family
    k, n, gs = 256, 384, 64
    p = _packed(k, n, gs, seed=5)
    widths = width_family(16, 4)
    assert 5 in widths            # the spec_k + 1 verify-run width
    for num_slots in (1, 4):
        for c in widths:
            m = c * num_slots
            x = jax.random.normal(jax.random.PRNGKey(100 + m), (m, k))
            ref = awq_matmul_ref(x, p.qweight, p.scales, p.zeros, gs)
            out = awq_matmul(x, p, compute_dtype=jnp.float32,
                             interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)


def test_scheduler_emitted_shapes_gateup():
    """The fused gate/up kernel over the same scheduler-emitted widths."""
    from repro.serving.scheduler import width_family
    k, n, gs = 256, 384, 64
    g = _packed(k, n, gs, seed=6)
    u = _packed(k, n, gs, seed=7)
    for c in width_family(16, 4):
        m = c * 4
        x = jax.random.normal(jax.random.PRNGKey(200 + m), (m, k))
        ref = awq_gateup_ref(x, g.qweight, g.scales, g.zeros, u.qweight,
                             u.scales, u.zeros, gs)
        out = awq_gateup(x, g, u, compute_dtype=jnp.float32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_choose_blocks_padded_rows():
    """Non-8-multiple M (spec verify rows, odd slot counts) pads to ONE
    GEMM block when ≤ 256 instead of degrading to an 8-row grid walk."""
    for m in (12, 20, 33, 100, 300):
        bm, _, _ = choose_blocks(m, 896, 4864, 64)
        padded = -(-m // 8) * 8
        assert bm % 8 == 0
        if padded <= 256:
            assert bm == max(padded, 8), (m, bm)
        else:
            assert padded % bm == 0, (m, bm)


def test_kernel_grid_covers_multiple_k_blocks():
    # K = 2048 with bk ≤ 1024 forces accumulation across the K grid axis
    p = _packed(2048, 128, 64, scale=0.05)
    x = jax.random.normal(jax.random.PRNGKey(11), (8, 2048)) * 0.3
    ref = awq_matmul_ref(x, p.qweight, p.scales, p.zeros, 64)
    out = awq_matmul(x, p, compute_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
