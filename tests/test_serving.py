"""Serving engine: greedy determinism, scan≡host-loop, EOS, sampling."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine, SamplerConfig
from repro.serving.engine import sample


def _engine(temperature=0.0):
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, GenerationEngine(m, params, max_seq=128,
                                 sampler=SamplerConfig(temperature))


def test_greedy_matches_manual_loop():
    cfg, eng = _engine()
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    out = eng.generate({"tokens": toks}, 8)
    # manual: prefill + argmax loop
    m, params = eng.model, eng.params
    cache = m.init_cache(2, 128)
    cache, logits, pos = jax.jit(m.prefill)(params, {"tokens": toks}, cache)
    ref = [np.asarray(jnp.argmax(logits, -1))]
    for t in range(7):
        tok = jnp.asarray(ref[-1], jnp.int32)
        logits, cache = jax.jit(m.decode_step)(params, cache, tok, pos)
        pos = pos + 1
        ref.append(np.asarray(jnp.argmax(logits, -1)))
    np.testing.assert_array_equal(out, np.stack(ref, 1))


def test_scan_equals_host_loop():
    cfg, eng = _engine()
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    a = eng.generate({"tokens": toks}, 6)
    b = eng.generate_scan({"tokens": toks}, 6)
    np.testing.assert_array_equal(a, b)


def test_eos_early_stop():
    cfg, eng = _engine()
    eng.eos_id = 0
    toks = jnp.zeros((2, 4), jnp.int32)
    out = eng.generate({"tokens": toks}, 16)
    # rows stay eos after first eos
    for row in out:
        seen = False
        for t in row:
            if seen:
                assert t == 0
            seen = seen or t == 0


def test_sampler_topk_and_temperature():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 10.0]])
    assert int(sample(logits, SamplerConfig(0.0), None)[0]) == 3
    key = jax.random.PRNGKey(0)
    s = sample(jnp.tile(logits, (256, 1)),
               SamplerConfig(temperature=1.0, top_k=2), key)
    assert set(np.asarray(s)) <= {2, 3}
