"""Flash-attention kernel vs oracle: shape/mask/GQA sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref

CASES = [
    # b, h, hkv, s, hd, causal, window, bq, bk
    (1, 2, 2, 256, 64, True, 0, 128, 128),
    (2, 4, 2, 256, 64, True, 0, 128, 128),      # GQA g=2
    (1, 8, 1, 128, 128, True, 0, 64, 64),       # MQA
    (1, 2, 2, 256, 64, False, 0, 128, 128),     # bidirectional (encoder)
    (1, 2, 2, 512, 64, True, 128, 128, 128),    # sliding window
    (2, 3, 1, 384, 64, True, 0, 128, 128),      # odd head count, g=3
]


@pytest.mark.parametrize("b,h,hkv,s,hd,causal,window,bq,bk", CASES)
def test_flash_matches_ref(b, h, hkv, s, hd, causal, window, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_matches_model_attention_math():
    """The kernel computes the same function as models/attention._sdpa."""
    from repro.models.attention import _sdpa
    b, hkv, g, s, hd = 1, 2, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, hkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = _sdpa(q, k, v, pos, pos, causal=True, window=0, scale=hd ** -0.5)
    # kernel layout: q [B, H, S, hd] with h = kv*g + j
    qk = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(b, hkv * g, s, hd)
    kk = jnp.transpose(k, (0, 2, 1, 3))
    vk = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_attention(qk, kk, vk, causal=True, block_q=128, block_k=128,
                          interpret=True)
    out = out.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
