"""int8+EF gradient compression: unit properties + multi-device parity.

The multi-device test runs in a subprocess with 8 placeholder devices
(same pattern as test_distributed.py).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import quantize_ef


def test_quantize_ef_residual_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    ef = jnp.zeros((256,))
    scale = jnp.max(jnp.abs(g)) / 127.0
    q, ef1 = quantize_ef(g, ef, scale)
    # residual per element ≤ scale/2; codes in range
    assert float(jnp.abs(ef1).max()) <= float(scale) / 2 + 1e-7
    assert int(jnp.abs(q).max()) <= 127


def test_error_feedback_accumulates_unbiased():
    """Repeatedly sending the same gradient: mean of dequantized sends →
    the true gradient (EF cancels systematic rounding)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 1e-3
    scale = jnp.asarray(0.01)  # coarse scale: heavy quantization
    ef = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, ef = quantize_ef(g, ef, scale)
        sent += q.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(sent / n), np.asarray(g),
                               atol=float(scale) / 2 / n + 1e-6)


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
import repro.configs as C
from repro.models import build_model
from repro.data import make_dataset
from repro.training.optim import AdamWConfig
from repro.training.dp_compressed import (init_dp_state, make_dp_train_step)

cfg = C.get_smoke_config("qwen25-05b")
m = build_model(cfg)
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
opt = AdamWConfig(lr=3e-3, warmup_steps=2, decay_steps=60, weight_decay=0.0)
ds = make_dataset(cfg, 8, 64)

results = {}
for compress in (False, True):
    state, ef = init_dp_state(m, jax.random.PRNGKey(0), mesh)
    step = make_dp_train_step(m, mesh, opt, compress=compress)
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, ef, metrics = step(state, ef, batch)
        losses.append(float(metrics["loss"]))
    results["int8ef" if compress else "f32"] = losses
    if compress:
        efn = float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(ef)))
        results["ef_nonzero"] = efn > 0

f32, q8 = results["f32"], results["int8ef"]
assert f32[-1] < f32[0] - 0.3, f32
assert q8[-1] < q8[0] - 0.3, q8
assert abs(q8[-1] - f32[-1]) < 0.15, (q8[-1], f32[-1])
assert results["ef_nonzero"]
print("RESULT:" + json.dumps({"f32_last": f32[-1], "int8_last": q8[-1]}))
"""


@pytest.mark.slow
def test_int8_ef_training_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULT:"):])
    assert res["int8_last"] < 6.1
