"""Training substrate: descent, schedule, clipping, bf16 grad-comm parity."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data import make_dataset
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, make_train_step
from repro.training.optim import clip_by_global_norm, lr_at
from repro.training.train_step import init_train_state


def test_loss_descends_on_markov_stream():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    state = init_train_state(m, jax.random.PRNGKey(0))
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=2,
                                           decay_steps=200,
                                           weight_decay=0.0))
    step = jax.jit(make_train_step(m, tc))
    ds = make_dataset(cfg, 8, 64)
    losses = []
    for i in range(20):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in ds.batch_at(i).items()})
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr_at(cfg, jnp.asarray(55))) < 1e-3
    assert abs(float(lr_at(cfg, jnp.asarray(100))) - 1e-4) < 1e-8


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert abs(float(norm) - np.sqrt(10) * 100) < 1e-2


def test_bf16_grad_comm_close_to_f32():
    """bf16 gradient communication (compression) stays close to the f32
    baseline over a few steps."""
    cfg = C.get_smoke_config("smollm-360m")
    m = build_model(cfg)
    ds = make_dataset(cfg, 4, 32)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=50,
                      weight_decay=0.0)
    outs = {}
    for dt in ("float32", "bfloat16"):
        state = init_train_state(m, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(m, TrainConfig(
            optimizer=opt, grad_comm_dtype=dt)))
        for i in range(5):
            state, metrics = step(state, {k: jnp.asarray(v) for k, v in
                                          ds.batch_at(i).items()})
        outs[dt] = float(metrics["loss"])
    assert abs(outs["bfloat16"] - outs["float32"]) < 0.05


def test_zero1_pspec_adds_data_axis():
    # needs a multi-device mesh — covered in test_distributed.py; here just
    # check the pure function against a fake mesh via jax.sharding API
    from repro.distributed.sharding import zero1_pspec
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 4, "model": 2}
        axis_names = ("data", "model")
    spec = zero1_pspec(P(None, "model"), (64, 8), FakeMesh())
    assert spec == P("data", "model")
    spec = zero1_pspec(P(None, None), (3, 8), FakeMesh())  # 3 % 4 != 0
    assert spec == P(None, "data")
