"""Checkpointing: atomic save/restore, async writer, GC, exact resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import make_dataset
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, make_train_step
from repro.training.train_step import init_train_state
from repro.utils.tree import flatten_with_paths


@pytest.fixture
def state_and_step():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    state = init_train_state(m, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10,
                              weight_decay=0.0))))
    ds = make_dataset(cfg, 4, 32)
    return state, step, ds


def test_save_restore_exact(tmp_path, state_and_step):
    state, step, ds = state_and_step
    save(str(tmp_path), 3, state)
    tpl = jax.eval_shape(lambda: state)
    state2, got = restore(str(tmp_path), tpl)
    assert got == 3
    for (p1, a), (p2, b) in zip(flatten_with_paths(state),
                                flatten_with_paths(state2)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_bitexact(tmp_path, state_and_step):
    state, step, ds = state_and_step
    for i in range(3):
        state, _ = step(state, {k: jnp.asarray(v)
                                for k, v in ds.batch_at(i).items()})
    save(str(tmp_path), 3, state)
    state2, _ = restore(str(tmp_path), jax.eval_shape(lambda: state))
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(3).items()}
    _, m1 = step(state, b)
    _, m2 = step(state2, b)
    assert float(m1["loss"]) == float(m2["loss"])


def test_latest_pointer_written_after_data(tmp_path, state_and_step):
    state, _, _ = state_and_step
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    assert os.path.exists(tmp_path / "step_00000007.npz")


def test_async_checkpointer_and_gc(tmp_path, state_and_step):
    state, _, _ = state_and_step
    ac = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ac.save(s, state)
    ac.close()
    assert latest_step(str(tmp_path)) == 4
    files = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert files == ["step_00000003.npz", "step_00000004.npz"]


def test_restore_quantized_params(tmp_path):
    """PackedLinear pytrees roundtrip through the checkpoint format."""
    from repro.core import quantize_params
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    qp, _ = quantize_params(params)
    save(str(tmp_path), 0, qp)
    qp2, _ = restore(str(tmp_path), jax.eval_shape(lambda: qp))
    for (p1, a), (_, b) in zip(flatten_with_paths(qp),
                               flatten_with_paths(qp2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_launcher_failure_recovery(tmp_path):
    """End-to-end node-failure path through the launcher."""
    from repro.launch.train import main
    out = main(["--arch", "qwen25-05b", "--smoke", "--steps", "12",
                "--batch", "4", "--seq", "32", "--ckpt-dir",
                str(tmp_path / "ck"), "--ckpt-every", "5",
                "--simulate-failure-at", "7", "--lr", "1e-3"])
    assert out["steps"] >= 12 - 5  # recovered and finished
