"""Disaggregated prefill/decode serving (serving.disagg, ROADMAP #5).

Four layers, mirroring the handoff stack:

  * pager-level `export_slot`/`adopt` accounting — cross-pool placement,
    prefix-key aliasing (a hot prefix is never duplicated in the decode
    pool), capacity rejection without mutation, invariants on both pools;
  * engine-level round-trips through the REAL jit'd gather/scatter
    movers — byte-exact pool content after handoff for bf16 AND int8
    pools (codes and scale strips), page-boundary-straddling watermarks,
    and the wire-bytes claim (int8 handoffs ~2× smaller);
  * controller identity — `DisaggController` greedy streams are
    token-identical to the unified `GenerationEngine` across int8 KV ×
    prefix sharing × ngram speculation, plus routing-threshold behavior;
  * a forced-4-device subprocess proving identity when the prefill and
    decode engines run *different* meshes (the replicated wire image is
    the load-bearing property — see distributed.sharding.handoff_sharding).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine
from repro.serving.disagg import (DecodeEngine, DisaggController,
                                  PrefillEngine)
from repro.serving.kv_pager import KVPager, PageAllocationError, PagerConfig


# ---------------------------------------------------------------------------
# Pager-level export/adopt accounting (no device arrays)
# ---------------------------------------------------------------------------

def _pager(num_pages=17, page_size=4, num_slots=2, pages_per_slot=6):
    return KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                               num_slots=num_slots,
                               pages_per_slot=pages_per_slot))


def test_export_adopt_accounting_roundtrip():
    src, dst = _pager(), _pager()
    slot, pages = src.alloc_slot(prompt_len=10, max_new_tokens=5)
    src.commit_chunk(slot, 0, 10)
    rec, phys = src.export_slot(slot)
    assert phys == pages and rec.n_pages == 3       # 10 tokens / P=4
    assert rec.slot_len == 10 and rec.committed == 10
    src.verify_invariants()                          # export is read-only
    dslot, scatter = dst.adopt(rec, max_new_tokens=5)
    # no prefix keys shipped → every page scatters fresh
    assert [i for i, _ in scatter] == [0, 1, 2]
    assert int(dst.slot_len[dslot]) == 10
    assert dst.slot_committed[dslot] == 10
    # decode-tail reservation matches what alloc_slot would have taken:
    # pages_for(10 + 5 - 1) - pages_for(10) = 4 - 3
    assert dst.slot_reserved[dslot] == 1
    dst.verify_invariants()
    src.free_slot(slot)
    src.verify_invariants()
    dst.extend(dslot, 14)                            # reservation is real
    dst.verify_invariants()


def test_adopt_rejects_without_mutation_then_retries():
    src = _pager()
    dst = _pager(num_pages=4)                        # 3 usable pages
    slot, _ = src.alloc_slot(prompt_len=10, max_new_tokens=8)
    src.commit_chunk(slot, 0, 10)
    rec, _ = src.export_slot(slot)
    before = (list(dst.free_pages), dict(dst.slot_pages))
    with pytest.raises(PageAllocationError):
        dst.adopt(rec, max_new_tokens=8)             # needs 3 + 2 reserve
    assert (list(dst.free_pages), dict(dst.slot_pages)) == before
    assert not dst.can_adopt(rec, max_new_tokens=8)
    assert dst.can_adopt(rec, max_new_tokens=1)      # prompt alone fits
    dslot, scatter = dst.adopt(rec, max_new_tokens=1)
    assert len(scatter) == 3
    dst.verify_invariants()


def test_adopt_aliases_prefix_pages_and_registers_once():
    """Two handoffs carrying the same prefix: the first registers its
    pages in the decode pool's index, the second aliases them — shipped
    bytes for those pages are never duplicated."""
    page = 4
    toks = np.arange(12, dtype=np.int32)             # 3 full pages
    src, dst = _pager(page_size=page), _pager(page_size=page)
    s1, _ = src.alloc_slot(prompt_len=12, max_new_tokens=3)
    src.commit_chunk(s1, 0, 12)
    src.register_prefix(s1, toks, "sys")
    rec1, _ = src.export_slot(s1)
    assert all(m is not None for m in rec1.page_meta)
    d1, sc1 = dst.adopt(rec1, max_new_tokens=3)
    assert len(sc1) == 3                             # all fresh first time
    assert len(dst.prefix_index) == 3                # re-registered here
    used_after_first = dst.pages_in_use
    d2, sc2 = dst.adopt(rec1, max_new_tokens=3)      # same prefix again
    assert sc2 == []                                 # fully aliased
    assert len(dst.prefix_index) == 3                # no duplicates
    assert dst.pages_in_use == used_after_first
    assert all(int(dst.page_ref[pg]) == 2
               for pg in dst.slot_pages[d2])
    dst.verify_invariants()
    dst.free_slot(d1)
    dst.free_slot(d2)
    dst.verify_invariants()


def test_adopt_joins_decode_side_pin():
    """A pinned namespace on the decode side sticky-pins pages arriving
    by handoff, exactly like register_prefix would."""
    toks = np.arange(8, dtype=np.int32)
    src, dst = _pager(), _pager()
    dst.pin_prefix("sys")                            # pin BEFORE arrival
    s1, _ = src.alloc_slot(prompt_len=8, max_new_tokens=2)
    src.commit_chunk(s1, 0, 8)
    src.register_prefix(s1, toks, "sys")
    rec, _ = src.export_slot(s1)
    dslot, scatter = dst.adopt(rec, max_new_tokens=2)
    assert len(scatter) == 2
    dst.verify_invariants()
    dst.free_slot(dslot)                             # pin keeps pages
    assert len(dst.prefix_index) == 2
    dst.verify_invariants()
    assert dst.unpin_prefix("sys") == 2
    assert dst.pages_in_use == 0


# ---------------------------------------------------------------------------
# Engine-level: byte-exact round-trips through the real movers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


_KW = dict(max_seq=64, num_slots=2, page_size=8, prefill_chunk=8)


def _one_handoff(m, params, prompt, max_new=6, **kw):
    """Drive a PrefillEngine to the park point and wire the handoff."""
    pe = PrefillEngine(m, params, **{**_KW, **kw})
    rid = pe.submit(prompt, max_new)
    sched = pe.engine._scheduler
    for _ in range(64):
        pe.step()
        if sched.ready_handoffs:
            break
    hs = pe.collect_handoffs()
    assert len(hs) == 1 and hs[0].request.rid == rid
    return pe, pe.wire(hs[0])


@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_handoff_pool_bytes_exact(model_and_params, kv_quant):
    """After adopt, the decode pool's pages hold byte-identical content
    to the wire image — for int8 pools that means codes AND the ks/vs
    scale strips. The prompt straddles a page boundary (13 tokens,
    page 8), so the partially-filled tail page round-trips too."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (13,)).astype(np.int32)
    pe, h = _one_handoff(m, params, prompt, kv_quant=kv_quant)
    assert h.record.slot_len == 13 and h.record.committed == 13
    assert h.record.n_pages == 2
    leaves = {k for seg in h.strips.values() for k in seg}
    if kv_quant == "int8":
        assert {"k", "v", "ks", "vs"} <= leaves
    de = DecodeEngine(m, params, **{**_KW, "kv_quant": kv_quant})
    drid, n_fresh = de.adopt(h)
    assert n_fresh == 2
    sched = de.engine._scheduler
    (dslot,) = sched.slots
    ids = sched.pager.slot_pages[dslot]
    back, _ = de.engine.handoff_wire(de.engine.handoff_gather(ids))
    for seg in h.strips:
        for k in h.strips[seg]:
            np.testing.assert_array_equal(
                np.asarray(back[seg][k]), np.asarray(h.strips[seg][k]),
                err_msg=f"{seg}/{k} not byte-exact after handoff")
    sched.pager.verify_invariants()
    # the adopted request still decodes to completion
    out = de.engine.drain()
    assert len(out[drid]) == 6


def test_handoff_wire_bytes_int8_half(model_and_params):
    """int8 pools ship codes + f32 scale strips: ~(1 + 4/hd)/2 of the
    bf16 bytes — comfortably under 0.6× for the smoke head_dim."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, (24,)).astype(np.int32)
    _, h_fp = _one_handoff(m, params, prompt, kv_quant=None)
    _, h_q = _one_handoff(m, params, prompt, kv_quant="int8")
    assert h_fp.wire_bytes > 0 and h_q.wire_bytes > 0
    ratio = h_q.wire_bytes / h_fp.wire_bytes
    assert ratio < 0.6, f"int8 wire ratio {ratio:.2f} not ~2× smaller"


def test_adopt_requires_wired_handoff(model_and_params):
    cfg, m, params = model_and_params
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    pe = PrefillEngine(m, params, **_KW)
    pe.submit(prompt, 4)
    sched = pe.engine._scheduler
    for _ in range(64):
        pe.step()
        if sched.ready_handoffs:
            break
    (h,) = pe.collect_handoffs()                    # NOT wired
    de = DecodeEngine(m, params, **_KW)
    with pytest.raises(ValueError, match="not wired"):
        de.adopt(h)


# ---------------------------------------------------------------------------
# Controller identity vs the unified engine
# ---------------------------------------------------------------------------

def _unified_streams(m, params, prompts, max_new, prefix_id, **feats):
    eng = GenerationEngine(m, params, **{**_KW, **feats})
    rids = [eng.submit(p, max_new, prefix_id=prefix_id) for p in prompts]
    out = eng.drain()
    return [[int(t) for t in out[r]] for r in rids]


@pytest.mark.parametrize("feats", [
    dict(),
    dict(kv_quant="int8", spec_decode="ngram", spec_k=4),
], ids=["plain", "int8_prefix_ngram"])
def test_controller_streams_identical_to_unified(model_and_params, feats):
    cfg, m, params = model_and_params
    rng = np.random.default_rng(8)
    prefix_id = "sys" if feats else None
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate([
        prefix, rng.integers(0, cfg.vocab_size, (t,)).astype(np.int32)])
        for t in (5, 12, 9)]
    ref = _unified_streams(m, params, prompts, 8, prefix_id, **feats)
    ctrl = DisaggController(m, params, handoff_min_tokens=1,
                            **{**_KW, **feats})
    crids = [ctrl.submit(p, 8, prefix_id=prefix_id) for p in prompts]
    out = ctrl.drain()
    got = [[int(t) for t in out[r]] for r in crids]
    assert got == ref, "disagg streams diverged from unified"
    st = ctrl.stats()
    assert st.handoffs == len(prompts) and st.direct == 0
    assert st.wire_bytes > 0 and st.adopt_time_s > 0.0
    if prefix_id is not None:
        # later handoffs alias the prefix pages the first one registered
        assert st.aliased_pages > 0
    for side in (ctrl.prefill.engine, ctrl.decode.engine):
        side._scheduler.pager.verify_invariants()


def test_controller_routing_threshold(model_and_params):
    """Prompts under the threshold are served whole by the decode engine
    (unified-style); past it they take the handoff path. Streams match
    the unified reference either way."""
    cfg, m, params = model_and_params
    rng = np.random.default_rng(9)
    short = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    long_ = rng.integers(0, cfg.vocab_size, (24,)).astype(np.int32)
    ref = _unified_streams(m, params, [short, long_], 6, None)
    ctrl = DisaggController(m, params, handoff_min_tokens=16, **_KW)
    crids = [ctrl.submit(p, 6) for p in (short, long_)]
    out = ctrl.drain()
    assert [[int(t) for t in out[r]] for r in crids] == ref
    st = ctrl.stats()
    assert st.direct == 1 and st.handoffs == 1
    # max_new_tokens == 1 never hands off (nothing left to decode)
    crid = ctrl.submit(long_, 1)
    out = ctrl.drain()
    assert len(out[crid]) == 1 and ctrl.stats().handoffs == 1


def test_controller_auto_threshold_builds(model_and_params):
    """handoff_min_tokens='auto' derives the split from the roofline
    report without crashing; the report carries the policy fields."""
    cfg, m, params = model_and_params
    ctrl = DisaggController(m, params, **_KW)
    assert ctrl.handoff_min_tokens >= 1
    rep = ctrl.split_report
    assert rep is not None and "crossover_prompt_tokens" in rep
    assert rep["prefill_bound"] in ("compute", "memory")
    assert rep["decode_bound"] in ("compute", "memory")


def test_roofline_disagg_report_full_config():
    """The split policy is internally consistent: decode at batch is
    firmly memory-bound, prefill runs at much higher arithmetic
    intensity, and `disaggregate` is exactly the compute/memory-bound
    conjunction. (For this 0.5 B on-device model the attention-score
    traffic keeps even prefill under the machine balance — the report
    says so honestly instead of parroting the datacenter answer.)"""
    from repro.roofline.costmodel import disagg_report
    cfg = C.get_config("qwen25-05b")
    rep = disagg_report(cfg, decode_batch=128, context=4096)
    assert rep["decode_bound"] == "memory"
    assert rep["prefill_intensity"] > 4 * rep["decode_intensity"]
    assert rep["disaggregate"] == (rep["prefill_bound"] == "compute"
                                   and rep["decode_bound"] == "memory")
    cross = rep["crossover_prompt_tokens"]
    assert cross is not None and 16 <= cross <= 4096
    # crossover: one prefill of that size outweighs a full decode step
    assert rep["prefill_time_s"] > 0 and rep["decode_step_time_s"] > 0


# ---------------------------------------------------------------------------
# Cross-mesh: prefill mesh ≠ decode mesh (forced-4-device subprocess)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import dataclasses, json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import numpy as np
import repro.configs as C
from repro.distributed import serving_mesh
from repro.models import build_model
from repro.serving import GenerationEngine
from repro.serving.disagg import DisaggController

cfg = dataclasses.replace(C.get_smoke_config("qwen25-05b"),
                          num_heads=8, num_kv_heads=4, head_dim=16)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
prompts = [np.concatenate([prefix, rng.integers(
    0, cfg.vocab_size, (t,)).astype(np.int32)]) for t in (5, 12, 9)]
KW = dict(max_seq=64, num_slots=2, page_size=8, prefill_chunk=8,
          kv_quant="int8", spec_decode="ngram", spec_k=4)

eng = GenerationEngine(m, params, **KW)
rids = [eng.submit(p, 8, prefix_id="sys") for p in prompts]
out = eng.drain()
ref = [[int(t) for t in out[r]] for r in rids]

ctrl = DisaggController(m, params, handoff_min_tokens=1,
                        prefill_mesh=serving_mesh(4),
                        decode_mesh=serving_mesh(2), **KW)
crids = [ctrl.submit(p, 8, prefix_id="sys") for p in prompts]
out = ctrl.drain()
got = [[int(t) for t in out[r]] for r in crids]
st = ctrl.stats()
print("RESULT " + json.dumps({
    "device_count": jax.device_count(),
    "identical": got == ref,
    "handoffs": st.handoffs,
    "aliased": st.aliased_pages,
    "wire_bytes": st.wire_bytes}))
"""


@pytest.fixture(scope="module")
def mesh_result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_cross_mesh_handoff_streams_identical(mesh_result):
    assert mesh_result["device_count"] == 4
    assert mesh_result["handoffs"] == 3
    assert mesh_result["aliased"] > 0
    assert mesh_result["wire_bytes"] > 0
    assert mesh_result["identical"], \
        "4-way prefill → 2-way decode streams diverged from unified"
