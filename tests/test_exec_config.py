"""`set_execution_config` must take effect AFTER the engine compiled.

Regression for the trace-time-global bug: `qlinear_apply` reads the
execution config when a dispatch is TRACED, so a plain ``jax.jit`` baked
in whatever was active at the first call and silently ignored every
later flip. The engine now keys every compiled dispatch on the active
config (`GenerationEngine._exec_jit`) — flipping ``impl`` retraces on
the next step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import quantize_params
from repro.core.qlinear import (ExecutionConfig, qlinear_apply,
                                set_execution_config)
from repro.kernels import ops as kops
from repro.models import build_model
from repro.serving import GenerationEngine


@pytest.fixture(autouse=True)
def _restore_exec_config():
    import repro.core.qlinear as Q
    prev = Q.get_execution_config()
    yield
    Q._EXEC = prev


@pytest.fixture(scope="module")
def quantized_model():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    qp, report = quantize_params(params)
    assert report.quantized, "smoke config must have quantizable linears"
    return cfg, m, qp


def _drain(eng, prompt, new_tokens=6):
    rid = eng.submit(prompt, new_tokens)
    while not eng.idle:
        eng.step()
    return [int(t) for t in eng.collect()[rid]]


def _count_kernel_calls(monkeypatch, calls):
    orig = kops.awq_matmul

    def counting(*a, **kw):
        calls.append(kw.get("interpret"))
        return orig(*a, **kw)

    monkeypatch.setattr(kops, "awq_matmul", counting)


def test_flip_impl_after_compile_chunked(monkeypatch, quantized_model):
    """The chunked serving dispatches observe a post-compile impl flip."""
    cfg, m, qp = quantized_model
    set_execution_config(impl="ref", compute_dtype=jnp.float32)
    eng = GenerationEngine(m, qp, max_seq=32, num_slots=2, page_size=8,
                           prefill_chunk=4)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (6,)).astype(np.int32)
    ref_stream = _drain(eng, prompt)          # compiles with impl="ref"
    assert len(ref_stream) == 6

    calls = []
    _count_kernel_calls(monkeypatch, calls)
    assert _drain(eng, prompt) == ref_stream  # still ref: kernel untouched
    assert calls == []

    set_execution_config(impl="kernel_interpret")
    kernel_stream = _drain(eng, prompt)       # ALREADY-compiled engine
    assert calls, "impl flip after compile was silently ignored"
    assert all(calls), "kernel_interpret must request interpret mode"
    assert kernel_stream == ref_stream        # greedy identity across impls

    calls.clear()
    set_execution_config(impl="ref")
    assert _drain(eng, prompt) == ref_stream  # flip back: kernel idle again
    assert calls == []


def test_flip_impl_after_compile_oneshot(monkeypatch, quantized_model):
    """The one-shot (non-chunked) path threads the config too."""
    cfg, m, qp = quantized_model
    set_execution_config(impl="ref", compute_dtype=jnp.float32)
    eng = GenerationEngine(m, qp, max_seq=32, num_slots=2, page_size=8,
                           chunked_prefill=False)
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (5,)).astype(np.int32)
    ref_stream = _drain(eng, prompt)

    calls = []
    _count_kernel_calls(monkeypatch, calls)
    set_execution_config(impl="kernel_interpret")
    assert _drain(eng, prompt) == ref_stream
    assert calls, "one-shot dispatches ignored the impl flip"


def test_qlinear_apply_explicit_cfg():
    """``cfg=`` bypasses the ambient global entirely (jit-static use)."""
    from repro.core.packing import pack_linear
    from repro.core.quantize import QuantConfig, quantize_groupwise
    qc = QuantConfig(group_size=64)
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 64)) * 0.1
    q, s, z = quantize_groupwise(w, qc)
    p = pack_linear(q, s, z, jnp.ones((128,), jnp.float32), None, qc)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 128))
    y_ref = qlinear_apply(p, x, cfg=ExecutionConfig(
        impl="ref", compute_dtype=jnp.float32))
    y_ker = qlinear_apply(p, x, cfg=ExecutionConfig(
        impl="kernel_interpret", compute_dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker),
                               rtol=2e-5, atol=2e-5)
