"""Refcounted prefix sharing: pager invariants + end-to-end token identity."""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.serving import GenerationEngine
from repro.serving.kv_pager import KVPager, PageAllocationError, PagerConfig


def _pager(num_pages=17, page_size=4, num_slots=4, pages_per_slot=4):
    return KVPager(PagerConfig(num_pages=num_pages, page_size=page_size,
                               num_slots=num_slots,
                               pages_per_slot=pages_per_slot))


def _toks(*vals):
    return np.asarray(vals, np.int32)


# ---------------------------------------------------------------------------
# Pager-level refcount / index invariants
# ---------------------------------------------------------------------------

def test_alias_refcount_and_exactly_once_free():
    p = _pager()
    prompt = _toks(*range(10))                  # 2 full pages + 2-token tail
    s_a, pages_a = p.alloc_slot(10, 3)
    p.register_prefix(s_a, prompt, "sys")
    shared = p.match_prefix(prompt, "sys")
    assert shared == pages_a[:2]                # full pages only, in order

    s_b, pages_b = p.alloc_slot(10, 3, shared_pages=shared)
    assert pages_b[:2] == pages_a[:2]           # aliased
    assert pages_b[2] != pages_a[2]             # COW tail page is private
    assert p.page_ref[pages_a[0]] == 2 and p.page_ref[pages_a[1]] == 2
    assert p.shared_pages == 2
    # physical vs logical accounting: 4 physical pages back 6 logical ones
    assert p.pages_in_use == 4
    assert p.logical_pages_in_use == 6

    free_before = p.num_free_pages
    p.free_slot(s_a)                            # B still holds the prefix
    assert p.page_ref[pages_a[0]] == 1
    assert p.num_free_pages == free_before + 1  # only A's tail page returned
    assert p.match_prefix(prompt, "sys") == shared  # index survives

    p.free_slot(s_b)                            # last owner: pages freed once
    assert p.pages_in_use == 0
    assert (p.page_ref == 0).all()
    assert len(set(p.free_pages)) == len(p.free_pages)  # no double entries
    assert not p.prefix_index                   # index died with the pages
    assert p.match_prefix(prompt, "sys") == []


def test_prefix_id_namespaces_do_not_cross_match():
    p = _pager()
    prompt = _toks(*range(8))
    s_a, _ = p.alloc_slot(8, 2)
    p.register_prefix(s_a, prompt, "alice")
    assert p.match_prefix(prompt, "alice")
    assert p.match_prefix(prompt, "bob") == []
    assert p.match_prefix(prompt, None) == []


def test_match_is_content_addressed():
    p = _pager()
    s_a, _ = p.alloc_slot(8, 2)
    p.register_prefix(s_a, _toks(*range(8)), "sys")
    # same id, different tokens → chain key diverges at page 0
    assert p.match_prefix(_toks(*range(1, 9)), "sys") == []
    # shared first page, different second page → partial match
    mixed = _toks(0, 1, 2, 3, 9, 9, 9, 9)
    assert len(p.match_prefix(mixed, "sys")) == 1


def test_partial_tail_never_shared():
    p = _pager()
    s_a, pages_a = p.alloc_slot(6, 2)           # 1 full + 1 partial page
    p.register_prefix(s_a, _toks(*range(6)), "sys")
    shared = p.match_prefix(_toks(*range(6)), "sys")
    assert shared == pages_a[:1]                # the 2-token tail page is not
    assert pages_a[1] not in shared


def test_admission_accounts_for_aliased_pages():
    # 5 usable pages, P=4: two 16-token requests cannot coexist unshared,
    # but CAN when 3 of the 4 pages alias
    p = _pager(num_pages=6, page_size=4, num_slots=2, pages_per_slot=4)
    prompt = _toks(*range(16))
    s_a, _ = p.alloc_slot(16, 1)
    p.register_prefix(s_a, prompt, "sys")
    assert not p.can_admit(16, 1)                       # 4 fresh: impossible
    shared = p.match_prefix(prompt, "sys")
    assert len(shared) == 4
    assert p.can_admit(16, 1, n_shared=len(shared))     # 0 fresh: fits
    s_b, pages_b = p.alloc_slot(16, 1, shared_pages=shared)
    assert p.pages_in_use == 4                          # still only 4 physical
    p.free_slot(s_a)
    p.free_slot(s_b)
    assert p.pages_in_use == 0 and (p.page_ref == 0).all()


def test_alias_of_unowned_page_rejected():
    p = _pager()
    s_a, pages_a = p.alloc_slot(4, 1)
    with pytest.raises(PageAllocationError):
        # first page owned, second never allocated — rejected atomically
        p.alloc_slot(8, 2, shared_pages=[pages_a[0], 3])
    # the failed alloc leaked nothing: no slot, no refcounts, no pages
    assert p.num_free_slots == p.cfg.num_slots - 1
    assert p.page_ref[pages_a[0]] == 1 and p.page_ref[3] == 0
    p.free_slot(s_a)
    assert p.pages_in_use == 0 and (p.page_ref == 0).all()


def test_extend_pages_are_private():
    p = _pager()
    prompt = _toks(*range(8))
    s_a, _ = p.alloc_slot(8, 6)                 # reserves a decode page
    p.register_prefix(s_a, prompt, "sys")
    p.extend(s_a, 12)                           # decode grows past the prompt
    grown = p.slot_pages[s_a][-1]
    assert p.page_ref[grown] == 1
    # the grown page is not in the prefix index — only committed prompt
    # pages are shareable
    assert grown not in p._page_key


# ---------------------------------------------------------------------------
# End-to-end: shared-prefix greedy streams ≡ unshared streams
# ---------------------------------------------------------------------------

def _engine(m, params, **kw):
    return GenerationEngine(m, params, max_seq=64, num_slots=4,
                            page_size=8, **kw)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = C.get_smoke_config("qwen25-05b")
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _shared_workload(cfg, prefix_len=16, tail_len=6, n=4, seed=7):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (tail_len,)
                                         ).astype(np.int32)])
            for _ in range(n)]


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_shared_prefix_streams_token_identical(model_and_params, kv_quant):
    cfg, m, params = model_and_params
    prompts = _shared_workload(cfg)

    def run(prefix_id):
        eng = _engine(m, params, kv_quant=kv_quant)
        rids = [eng.submit(p, 8, prefix_id=prefix_id) for p in prompts]
        out = eng.drain()
        return [list(out[r]) for r in rids], eng._scheduler

    shared, sched_s = run("sys")
    unshared, sched_u = run(None)
    assert shared == unshared
    assert sched_s.stats.prefix_shared_pages > 0
    assert sched_u.stats.prefix_shared_pages == 0
    # all pages returned exactly once after drain
    for sched in (sched_s, sched_u):
        assert sched.pager.pages_in_use == 0
        assert (sched.pager.page_ref == 0).all()


def test_shared_prefix_matches_sequential_generate(model_and_params):
    import jax.numpy as jnp
    cfg, m, params = model_and_params
    prompts = _shared_workload(cfg, prefix_len=16, tail_len=5, n=3, seed=9)
    eng = _engine(m, params)
    rids = [eng.submit(p, 8, prefix_id="sys") for p in prompts]
    out = eng.drain()
    for p, rid in zip(prompts, rids):
        ref = eng.generate({"tokens": jnp.asarray(p)[None, :]}, 8)[0]
        np.testing.assert_array_equal(out[rid], ref[: len(out[rid])])


def test_sharing_raises_concurrency_at_fixed_budget(model_and_params):
    """The capacity claim: with a page pool sized so that unshared requests
    queue, prefix sharing admits the whole burst at once."""
    cfg, m, params = model_and_params
    prompts = _shared_workload(cfg, prefix_len=16, tail_len=6, n=4)
    # each request: 22+7 tokens ⇒ 4 pages worst case (P=8). Pool of 11
    # usable pages fits 2 unshared requests (8 pages) but 4 shared ones
    # (2 aliased + 2 private each ⇒ 2 + 4·2 = 10 pages).
    def peak_active(prefix_id):
        eng = GenerationEngine(m, params, max_seq=32, num_slots=4,
                               page_size=8, num_pages=12)
        for p in prompts:
            eng.submit(p, 8, prefix_id=prefix_id)
        peak = 0
        while not eng.idle:
            eng.step()
            peak = max(peak, eng.num_active)
        return peak

    assert peak_active(None) <= 2
    assert peak_active("sys") == 4
