"""MoE dispatch: dropless small batches, capacity dropping, determinism."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import moe


def _setup():
    cfg = C.get_smoke_config("qwen2-moe-a2.7b")
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    return cfg, p


def test_dropless_small_batch_equals_dense_computation():
    """With cap=T (dropless), grouped dispatch must equal the naive
    per-token expert sum."""
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    y, aux = moe.moe_apply(p, x, cfg)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    wg = p["experts"]["gate"]["w"]
    wu = p["experts"]["up"]["w"]
    wd = p["experts"]["down"]["w"]
    y_ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ wg[e]) * (xt[t] @ wu[e])
            y_ref = y_ref.at[t].add(gates[t, j] * (h @ wd[e]))
    # shared experts
    sh = p["shared"]
    g = jax.nn.silu(xt @ sh["gate"]["w"]) * (xt @ sh["up"]["w"])
    s_out = g @ sh["down"]["w"]
    s_out = s_out * jax.nn.sigmoid(xt @ p["shared_gate"]["w"])
    y_ref = y_ref + s_out
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(y_ref), rtol=2e-2, atol=2e-2)


def test_capacity_dropping_large_batch():
    """Above the dropless threshold, overflow tokens are dropped, not
    mis-routed."""
    import dataclasses
    cfg, p = _setup()
    cfg = dataclasses.replace(cfg, capacity_factor=0.5)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 512, cfg.d_model))
    y, aux = moe.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_capacity_formula():
    cfg, _ = _setup()
    assert moe.capacity(cfg, 100) == 100          # dropless region
    c = moe.capacity(cfg, 100_000)                # formula region
    assert c % 8 == 0
    assert c >= 100_000 * cfg.top_k / cfg.num_experts


def test_aux_loss_decreases_when_balanced():
    cfg, p = _setup()
    t, e = 512, cfg.num_experts
    xt = jax.random.normal(jax.random.PRNGKey(3), (t, cfg.d_model))
    # balanced router vs collapsed router
    _, aux_rand = moe.moe_apply(p, xt, cfg)
    p_bad = jax.tree.map(lambda a: a, p)
    p_bad["router"]["w"] = p["router"]["w"].at[:, 0].add(100.0)  # collapse
    _, aux_bad = moe.moe_apply(p_bad, xt, cfg)
    assert float(aux_bad) > float(aux_rand)
