"""Roofline analyzer: HLO text parsing on synthetic modules."""

from repro.roofline.analysis import (RooflineTerms, _loop_multipliers,
                                     _split_computations, _type_bytes,
                                     collective_bytes_from_hlo, hlo_costs)

SYNTH = """\
HloModule jit_step, is_scheduled=true

%cond.1 (p0: (s32[], f32[8,8])) -> pred[] {
  %p0 = (s32[], f32[8,8]) parameter(0)
  %gte = s32[] get-tuple-element(%p0), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body.1 (p0: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p0 = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%p0), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), channel_id=1
  %d = f32[8,8]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p0), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

ENTRY %main (a: f32[16,32], b: f32[32,8]) -> f32[8,8] {
  %a = f32[16,32]{1,0} parameter(0)
  %b = f32[32,8]{1,0} parameter(1)
  %ag = f32[32,8]{1,0} all-gather(%b), channel_id=2, dimensions={0}
  %d0 = f32[16,8]{1,0} dot(%a, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %init = (s32[], f32[8,8]) tuple-thing(%d0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_type_bytes():
    assert _type_bytes("f32[16,32]{1,0}") == 16 * 32 * 4
    assert _type_bytes("bf16[8]") == 16
    assert _type_bytes("s8[4,4]") == 16
    assert _type_bytes("pred[]") == 1


def test_split_and_multipliers():
    comps = _split_computations(SYNTH)
    assert set(comps) == {"cond.1", "body.1", "main"}
    mult = _loop_multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["body.1"] == 12.0


def test_collective_bytes_trip_weighted():
    coll = collective_bytes_from_hlo(SYNTH)
    # all-gather operand f32[32,8] = 1024 B once; all-reduce f32[8,8]=256 B
    # × 12 trips
    assert coll["all-gather"] == 1024
    assert coll["all-reduce"] == 256 * 12
    assert coll["total"] == 1024 + 256 * 12


def test_dot_flops_trip_weighted():
    costs = hlo_costs(SYNTH)
    # entry dot: 2*16*8*32 = 8192; body dot: 2*8*8*8 × 12 = 12288
    assert costs["flops"] == 8192 + 12288


def test_roofline_terms_dominance():
    t = RooflineTerms(flops=197e12, bytes_accessed=819e9 * 2,
                      collective_bytes=50e9 * 0.5, chips=1,
                      model_flops=197e12 / 2)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 2.0) < 1e-9
    assert t.dominant == "memory"
    assert abs(t.roofline_fraction - 0.25) < 1e-9


def test_analytic_costmodel_sanity():
    import repro.configs as C
    from repro.roofline.costmodel import cell_costs
    from repro.configs import SHAPES
    cfg = C.get_config("qwen25-05b")
    cc_q = cell_costs(cfg, SHAPES["decode_32k"], quant=True)
    cc_f = cell_costs(cfg, SHAPES["decode_32k"], quant=False)
    # quantization cuts weight traffic ≈ 16/4.5 on quantizable linears; the
    # fp16 embedding stays (paper Table III: overall ≈ 55% reduction)
    assert cc_q.weight_bytes < 0.55 * cc_f.weight_bytes
    # decode is cache+weight bound, not flop bound
    assert cc_q.total_bytes / 819e9 > cc_q.flops / 197e12
    # train flops >> decode flops
    cc_t = cell_costs(cfg, SHAPES["train_4k"], quant=False)
    assert cc_t.flops > 1000 * cc_f.flops
