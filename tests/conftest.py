import jax.numpy as jnp
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests run on the single real device;
# only launch/dryrun.py (and the dedicated subprocess in test_distributed)
# request placeholder devices.
#
# Determinism audit (PR 1): every random draw in the suite goes through an
# explicitly seeded generator — `np.random.default_rng(<literal>)` or
# `jax.random.PRNGKey(<literal or parametrize value>)`. The fixture below
# additionally pins numpy's legacy global state so any future accidental
# `np.random.*` call is at least reproducible rather than flaky.


@pytest.fixture(autouse=True)
def _pin_global_numpy_seed():
    np.random.seed(0)
    yield


def make_batch(cfg, b=2, s=64, seed=0, labels=True):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        out = {"features": jnp.asarray(
            rng.normal(size=(b, s, cfg.frontend_dim)), jnp.float32)}
        if labels:
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        return out
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if labels:
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.frontend == "vision":
        out["images"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    return out


@pytest.fixture(autouse=True)
def _reset_exec_config():
    from repro.core.qlinear import set_execution_config
    set_execution_config(impl="auto", compute_dtype=jnp.bfloat16,
                         offload_min_flops=2 ** 20)
    yield
    set_execution_config(impl="auto", compute_dtype=jnp.bfloat16,
                         offload_min_flops=2 ** 20)
