"""Unit + property tests for the AWQ quantization numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property tests fall back to parametrized samples
    HAVE_HYPOTHESIS = False

from repro.core.quantize import (QuantConfig, dequantize_groupwise,
                                 fake_quantize, quantize_groupwise)


def test_roundtrip_error_bound():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    cfg = QuantConfig(group_size=64)
    q, s, z = quantize_groupwise(w, cfg)
    wd = dequantize_groupwise(q, s, z, cfg)
    # RTN error per element ≤ scale/2 within its group
    err = jnp.abs(wd - w)
    bound = jnp.repeat(s, cfg.group_size, axis=0) * 0.5 + 1e-6
    assert bool(jnp.all(err <= bound))


def test_codes_in_range():
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32)) * 5
    q, s, z = quantize_groupwise(w, QuantConfig(group_size=64))
    assert int(q.min()) >= 0 and int(q.max()) <= 15
    assert int(z.min()) >= 0 and int(z.max()) <= 15


def test_symmetric_mode():
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 16))
    cfg = QuantConfig(group_size=64, sym=True)
    q, s, z = quantize_groupwise(w, cfg)
    assert bool(jnp.all(z == 8))


def test_group_size_divisibility_check():
    with pytest.raises(ValueError):
        quantize_groupwise(jnp.zeros((100, 8)), QuantConfig(group_size=64))


def test_constant_rows_stable():
    # zero-width range → fallback scale 1.0 (AutoAWQ convention): error ≤ 0.5
    w = jnp.ones((64, 8)) * 3.7
    wq = fake_quantize(w, QuantConfig(group_size=64))
    assert float(jnp.abs(wq - w).max()) <= 0.5
    # all-zero rows are exact
    wz = fake_quantize(jnp.zeros((64, 8)), QuantConfig(group_size=64))
    assert float(jnp.abs(wz).max()) == 0.0


def _check_quant_error_bound(groups, n_over_8, scale, seed):
    """∀ w: |dequant(quant(w)) − w| ≤ scale/2 per group."""
    gs = 64
    k, n = groups * gs, n_over_8 * 8
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * scale
    cfg = QuantConfig(group_size=gs)
    q, s, z = quantize_groupwise(w, cfg)
    wd = dequantize_groupwise(q, s, z, cfg)
    err = np.asarray(jnp.abs(wd - w))
    bound = np.repeat(np.asarray(s), gs, axis=0) * 0.5 + 1e-5
    assert (err <= bound).all()


def _check_fake_quant_idempotent(seed):
    """Quantizing an already-quantized weight is exact (fixed point)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 8))
    cfg = QuantConfig(group_size=64)
    w1 = fake_quantize(w, cfg)
    w2 = fake_quantize(w1, cfg)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 3), st.floats(0.01, 10.0),
           st.integers(0, 2 ** 31 - 1))
    def test_property_quant_error_bound(groups, n_over_8, scale, seed):
        _check_quant_error_bound(groups, n_over_8, scale, seed)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_property_fake_quant_idempotent(seed):
        _check_fake_quant_idempotent(seed)
else:
    @pytest.mark.parametrize("groups,n_over_8,scale,seed", [
        (1, 1, 0.01, 0), (2, 2, 1.0, 7), (4, 3, 10.0, 1234),
        (3, 1, 0.5, 2 ** 31 - 1), (1, 3, 3.3, 99),
    ])
    def test_property_quant_error_bound(groups, n_over_8, scale, seed):
        _check_quant_error_bound(groups, n_over_8, scale, seed)

    @pytest.mark.parametrize("seed", [0, 1, 17, 4096, 2 ** 31 - 1])
    def test_property_fake_quant_idempotent(seed):
        _check_fake_quant_idempotent(seed)
